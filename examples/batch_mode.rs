//! The paper's future-work parameter-reuse modes, implemented as
//! first-class compiler/simulator features:
//!
//! * multi-token mode — all prompt tokens share each weight stream
//!   (summarization/prefill speedup);
//! * batch mode — multiple requests share each weight stream
//!   (throughput for high-traffic datacenters), with 1..4 SXE/VXE sets.
//!
//!     cargo run --release --example batch_mode

use lpu::compiler::{compile, CompileOpts, ParallelMode};
use lpu::config::LpuConfig;
use lpu::model::by_name;
use lpu::sim::{simulate_prefill, CoreSim};
use lpu::util::table::Table;

fn main() -> Result<(), String> {
    let cfg = LpuConfig::asic_3_28tbs();
    let model = by_name("opt-1.3b").unwrap();

    // --- multi-token (summarization) mode ---
    let mut t = Table::new(
        "Multi-token mode — 32-token prompt summarization (OPT-1.3B)",
        &["mode", "SXE sets", "total ms", "ms/token", "speedup"],
    );
    let serial = {
        let opts = CompileOpts { position: 16, ..Default::default() };
        let c = compile(&model, &cfg, &opts).map_err(|e| e.to_string())?;
        let step = CoreSim::new(&cfg).run(&c.program).unwrap().time_s();
        32.0 * step
    };
    t.row(&[
        "serial decode".into(),
        "1".into(),
        format!("{:.2}", serial * 1e3),
        format!("{:.3}", serial / 32.0 * 1e3),
        "1.00x".into(),
    ]);
    for sets in [1usize, 2, 4] {
        let (total, per_tok) =
            simulate_prefill(&model, &cfg, 1, 32, sets).map_err(|e| e.to_string())?;
        t.row(&[
            "multi-token".into(),
            sets.to_string(),
            format!("{:.2}", total * 1e3),
            format!("{:.3}", per_tok * 1e3),
            format!("{:.2}x", serial / total),
        ]);
    }
    t.note("paper: \"multi-token mode ... would speedup the initial summarization stage\"");
    t.print();

    // --- batch mode ---
    let mut b = Table::new(
        "Batch mode — concurrent requests sharing weight streams (OPT-1.3B)",
        &["batch", "SXE sets", "ms/pass", "ms/token effective", "throughput gain"],
    );
    let single = {
        let opts = CompileOpts { position: 1000, ..Default::default() };
        let c = compile(&model, &cfg, &opts).map_err(|e| e.to_string())?;
        CoreSim::new(&cfg).run(&c.program).unwrap().time_s()
    };
    b.row(&[
        "1".into(),
        "1".into(),
        format!("{:.3}", single * 1e3),
        format!("{:.3}", single * 1e3),
        "1.00x".into(),
    ]);
    for (batch, sets) in [(2usize, 1usize), (4, 1), (4, 4), (8, 4)] {
        let opts = CompileOpts {
            position: 1000,
            mode: ParallelMode::Batch { batch },
            sxe_sets: sets,
            ..Default::default()
        };
        let c = compile(&model, &cfg, &opts).map_err(|e| e.to_string())?;
        let pass = CoreSim::new(&cfg).run(&c.program).unwrap().time_s();
        let eff = pass / batch as f64;
        b.row(&[
            batch.to_string(),
            sets.to_string(),
            format!("{:.3}", pass * 1e3),
            format!("{:.3}", eff * 1e3),
            format!("{:.2}x", single / eff),
        ]);
    }
    b.note("weights stream once per pass; KV/attention traffic stays per-request");
    b.note("paper: \"batch mode ... would greatly improve the throughput, which is essential in high-traffic datacenters\"");
    b.print();
    Ok(())
}
