//! Quickstart: compile a model with the HyperDex stack, simulate its
//! decode latency on the cycle-accurate LPU, and estimate the chip.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- opt-6.7b

use lpu::compiler::{compile, CompileOpts};
use lpu::config::LpuConfig;
use lpu::model::by_name;
use lpu::power::{chip_estimate, system_power_w};
use lpu::sim::simulate_generation;

fn main() -> Result<(), String> {
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "opt-1.3b".to_string());
    let model = by_name(&model_name).ok_or(format!("unknown model '{model_name}'"))?;
    let cfg = LpuConfig::asic_3_28tbs();
    let devices = model.devices_needed(cfg.hbm.capacity());

    println!("== model ==");
    println!(
        "{}: {:.2}B params, {:.1} GB FP16, needs {devices} device(s) of {:.0} GB",
        model.name,
        model.params() as f64 / 1e9,
        model.weight_bytes() as f64 / 1e9,
        cfg.hbm.capacity() as f64 / 1e9,
    );

    println!("\n== HyperDex compile (device 0 shard) ==");
    let opts = CompileOpts { n_devices: devices, position: 1024, ..Default::default() };
    let c = compile(&model, &cfg, &opts).map_err(|e| e.to_string())?;
    println!(
        "{} instructions, {} virtual regs -> peak {} physical, {} chains, map {:.2} GB",
        c.stats.instrs,
        c.stats.virtual_regs,
        c.stats.peak_live_regs,
        c.stats.chain.chains,
        c.map.total_bytes() as f64 / 1e9,
    );
    let hist = c.program.category_histogram();
    println!(
        "instruction mix: MEM {} / COMP {} / NET {} / CTRL {}",
        hist[0].1, hist[1].1, hist[2].1, hist[3].1
    );

    println!("\n== cycle-accurate simulation (in=32, out=2016) ==");
    let r = simulate_generation(&model, &cfg, devices, 32, 2016, true)
        .map_err(|e| e.to_string())?;
    println!(
        "{:.3} ms/token ({:.1} tokens/s), bandwidth utilization {:.1}%",
        r.ms_per_token,
        r.tokens_per_s,
        r.bandwidth_util * 100.0
    );
    println!("paper reference: OPT-1.3B 1.25 ms/token @63.3%, OPT-66B(x2) 22.2 ms @90.6%");

    println!("\n== ASIC estimate ({}) ==", cfg.name);
    let est = chip_estimate(&cfg);
    println!(
        "chip {:.3} mm^2 / {:.2} mW; system incl. {} HBM3 stacks: {:.0} W",
        est.total_area_mm2(),
        est.total_power_mw(),
        cfg.hbm.stacks,
        system_power_w(&cfg)
    );
    Ok(())
}
