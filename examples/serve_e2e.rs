//! End-to-end driver (the EXPERIMENTS.md E2E run): serve a real model
//! through the full stack and report latency/throughput.
//!
//! Composition proven here, end to end:
//!   L1 Pallas kernels → L2 JAX decoder → AOT HLO text (`make artifacts`)
//!   → rust PJRT runtime → coordinator (router/scheduler/sampler)
//!   → TCP JSON-lines server → client — python never on the request path.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!     cargo run --release --example serve_e2e -- opt-mini

use std::sync::Arc;
use std::time::Instant;

use lpu::coordinator::{BackendFactory, Coordinator, CoordinatorConfig, SchedulerPolicy};
use lpu::runtime::{default_artifacts_dir, Engine};
use lpu::server::{serve, Client};
use lpu::util::stats::Summary;

fn main() -> Result<(), String> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "opt-tiny".to_string());
    let dir = default_artifacts_dir();
    if !Engine::artifacts_present(&dir, &model) {
        return Err(format!("artifacts for '{model}' missing in {dir:?}; run `make artifacts`"));
    }

    // 0. Validate the bridge against the python golden vector first.
    println!("validating PJRT bridge for '{model}' ...");
    Engine::load(&dir, &model).map_err(|e| e.to_string())?.validate().map_err(|e| e.to_string())?;
    println!("bridge OK (rust logits == python/JAX reference)\n");

    // 1. Bring up the serving stack: 2 PJRT workers, token-interleaved.
    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
    });
    coord.add_pool(&model, 2, BackendFactory::pjrt(dir, &model));
    let server = serve(Arc::new(coord), "127.0.0.1:0").map_err(|e| e.to_string())?;
    println!("server on {}", server.addr);

    // 2. Drive a batched workload: 8 concurrent clients, mixed lengths.
    let n_clients = 8usize;
    let max_new = 24usize;
    let t0 = Instant::now();
    let addr = server.addr;
    let model2 = model.clone();
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let model = model2.clone();
            std::thread::spawn(move || -> Result<(usize, f64, f64), String> {
                let mut c = Client::connect(&addr).map_err(|e| e.to_string())?;
                let prompt: Vec<i64> = (0..4 + (i as i64 % 5)).map(|j| 3 + i as i64 * 7 + j).collect();
                let start = Instant::now();
                let r = c.generate(&model, &prompt, max_new, true)?;
                let total = start.elapsed().as_secs_f64();
                Ok((r.tokens.len(), total, total / r.tokens.len() as f64))
            })
        })
        .collect();

    let mut per_token = Vec::new();
    let mut total_tokens = 0usize;
    for h in handles {
        let (n, total_s, per_tok) = h.join().map_err(|_| "client panicked")??;
        total_tokens += n;
        per_token.push(per_tok);
        println!("client done: {n} tokens in {:.2}s ({:.1} ms/token)", total_s, per_tok * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();

    // 3. Report.
    let s = Summary::of(&per_token);
    println!("\n== E2E results ({model}, 2 PJRT workers, {n_clients} concurrent clients) ==");
    println!("total: {total_tokens} tokens in {wall:.2}s -> {:.1} tokens/s aggregate", total_tokens as f64 / wall);
    println!(
        "per-client per-token latency: mean {:.1} ms, p50 {:.1} ms, p99 {:.1} ms",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3
    );
    let mut c = Client::connect(&addr).map_err(|e| e.to_string())?;
    println!("server metrics: {}", c.metrics()?.to_string_pretty());

    server.stop();
    Ok(())
}
