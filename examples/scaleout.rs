//! Scale-out scenarios over the ESL interconnect (Fig 4 + Fig 7c):
//! strong scaling of one model 1→8 devices, the overlap ablation, and a
//! reconfigured 8-device server running two models on independent
//! 4-rings.
//!
//!     cargo run --release --example scaleout

use lpu::config::LpuConfig;
use lpu::esl::cluster::{multi_model_deployment, scaling_sweep, speedup_per_doubling};
use lpu::esl::{RingConfig, Router};
use lpu::model::by_name;
use lpu::util::table::Table;

fn main() -> Result<(), String> {
    let cfg = LpuConfig::asic_3_28tbs();
    let m = by_name("gpt3-20b").unwrap();

    // --- strong scaling, with vs without ESL latency hiding ---
    let with = scaling_sweep(&m, &cfg, 8, true, 32, 256).map_err(|e| e.to_string())?;
    let without = scaling_sweep(&m, &cfg, 8, false, 32, 256).map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "GPT3-20B strong scaling (ESL overlap vs blocking sync)",
        &["devices", "ESL ms/tok", "speedup", "blocking ms/tok", "speedup"],
    );
    for (a, b) in with.iter().zip(&without) {
        t.row(&[
            a.devices.to_string(),
            format!("{:.2}", a.ms_per_token),
            format!("{:.2}x", a.speedup),
            format!("{:.2}", b.ms_per_token),
            format!("{:.2}x", b.speedup),
        ]);
    }
    t.note(format!(
        "per doubling: ESL {:.2}x (paper: 1.75x) vs blocking {:.2}x (DGX A100: 1.38x)",
        speedup_per_doubling(&with),
        speedup_per_doubling(&without)
    ));
    t.print();

    // --- ring reconfiguration: 8 devices -> 2 independent 4-rings ---
    let rc = RingConfig::new(8, 4)?;
    rc.validate()?;
    println!(
        "\nreconfigured 8-device server into {} rings: {:?} and {:?}",
        rc.n_rings(),
        rc.members(0),
        rc.members(1)
    );
    let r = Router::new(0, rc);
    let (hops, dir) = r.route(2)?;
    println!("router: device 0 -> device 2 goes {hops} hops {dir:?}");
    assert!(r.route(5).is_err(), "rings must not intersect");
    println!("router: device 0 -> device 5 correctly rejected (different ring)");

    // --- two models served concurrently on the two 4-rings ---
    let m1 = by_name("opt-mini").unwrap();
    let m2 = by_name("opt-tiny").unwrap();
    let fpga = LpuConfig::fpga_u55c();
    let reports = multi_model_deployment(8, 4, &[&m1, &m2], &fpga, 128)?;
    let mut d = Table::new(
        "Fig 4(b) — two models on two independent 4-rings (orion-cloud)",
        &["ring", "model", "ms/token", "tokens/s"],
    );
    for (ring, r) in &reports {
        d.row(&[
            ring.to_string(),
            r.model.clone(),
            format!("{:.3}", r.ms_per_token),
            format!("{:.1}", r.tokens_per_s),
        ]);
    }
    d.note("no model switching overhead: rings run independently, links never shared");
    d.print();
    Ok(())
}
