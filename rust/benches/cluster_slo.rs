//! Cluster-scale SLO study over the virtual fleet: {arrival trace ×
//! offered load} sweeps of a 2-replica cluster with a 50/50
//! interactive/batch tier mix, reporting per-tier SLO attainment and
//! shed fraction per cell, plus two ablations:
//!
//! * **load shedding**: at 8x the fleet's sustainable rate, deadline-
//!   aware admission (projected queue delay vs the tier's TTFT budget)
//!   vs admit-everything — interactive attainment with shedding must
//!   land strictly above the no-shedding baseline (asserted; the
//!   no-shed fleet queues every arrival until nearly nothing meets its
//!   budget, while admission keeps the admitted set inside it);
//! * **autoscaling**: a flash-crowd trace over a min=1/max=4 fleet
//!   with a warm-up charge per activation — the controller must ride
//!   the burst up to >= 2 active replicas (asserted) and the full
//!   `(t, active)` timeline is emitted;
//! * **chaos**: a replica crash plus a network partition in the middle
//!   of a flash crowd on a 3-replica fleet — 100% completion, zero
//!   leaked KV blocks, every stream bit-identical fault-on vs
//!   fault-off, rerun-identical recovery (all asserted) on the virtual
//!   path AND a small threaded failover run; plus a hedging sub-cell
//!   (one 6x-slow replica, deadline-fraction hedges on) whose streams
//!   must match the unhedged run.
//!
//! The TTFT budget and rate grid are **self-calibrated**: a light-load
//! probe measures base TTFT (budget = 8x its p50) and a backlogged
//! probe measures one replica's sustainable request rate, so the sweep
//! lands in the same regimes on any step model. Every number is a pure
//! function of (seed, config); reruns are asserted bit-identical.
//! Results go to `../BENCH_cluster.json` (override with
//! `LPU_BENCH_CLUSTER_JSON=<path>`; schema pinned by
//! `tests/bench_schema.rs` and documented in README).
//!
//! `LPU_BENCH_FAST=1` shrinks the sweep for CI smoke runs.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_cluster_open_loop, run_virtual, run_virtual_cluster, ArrivalTrace,
    AutoscaleConfig, BackendFactory, Cluster, ClusterConfig, ClusterFaultPlan,
    ClusterReport, ClusterWorkload, Coordinator, CoordinatorConfig, LenDist,
    PartitionSpec, ReplicaCrashSpec, ReplicaSlowSpec, SchedulerPolicy, SloTier,
    StepModel, VirtualConfig, Workload,
};
use lpu::model::by_name;
use lpu::util::json::{obj, Json};
use lpu::util::table::Table;

fn base_workload(rate: f64, n: usize, seed: u64) -> Workload {
    Workload {
        model: "opt-1.3b".into(),
        rate,
        n_requests: n,
        prompt_len: LenDist::Uniform(4, 32),
        output_len: LenDist::LongTail { min: 8, mean_extra: 48.0, cap: 256 },
        vocab: 512,
        seed,
    }
}

fn main() {
    let fast = std::env::var("LPU_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests = if fast { 120 } else { 400 };
    let rate_mults: &[f64] = if fast { &[0.5, 8.0] } else { &[0.5, 1.0, 2.0, 8.0] };
    let replicas = 2usize;
    let interactive_fraction = 0.5f64;

    let model = by_name("opt-1.3b").unwrap();
    let device = LpuConfig::asic_3_28tbs();
    let step = StepModel::from_config(&model, &device, 1);
    let mk_pool = || {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = 8;
        vc
    };

    // ---- self-calibration: base TTFT at whisper-light load, and one
    // replica's sustainable request rate from a backlogged run. Both
    // deterministic, so the derived budget and rate grid are too.
    let light = run_virtual(&base_workload(20.0, 40, 0xC11B), &mk_pool()).expect("probe");
    let base_ttft_s = light.ttft.p50;
    let backlog =
        run_virtual(&base_workload(100_000.0, n_requests.min(160), 0xFEED), &mk_pool())
            .expect("backlog probe");
    let total_tokens: usize = backlog.records.iter().map(|r| r.tokens.len()).sum();
    let mean_out = total_tokens as f64 / backlog.records.len().max(1) as f64;
    let sustainable = backlog.tokens_per_s / mean_out.max(1.0);
    let fleet_sustainable = sustainable * replicas as f64;
    let budget_s = base_ttft_s * 8.0;

    // ---- {trace x offered load} attainment sweep ----
    let mut cells: Vec<Json> = Vec::new();
    let mut t = Table::new(
        format!(
            "cluster SLO sweep: opt-1.3b on {}, {replicas} replicas, 50/50 tier mix, \
             TTFT budget {:.2} ms",
            device.name,
            budget_s * 1e3
        ),
        &[
            "trace",
            "x sustain",
            "req/s",
            "int attain %",
            "batch attain %",
            "int shed %",
            "tok/s",
            "wall s",
        ],
    );
    let mut sweep: Vec<(String, f64, ClusterReport)> = Vec::new();
    for &mult in rate_mults {
        let rate = mult * fleet_sustainable;
        let span = n_requests as f64 / rate;
        for trace in [
            ArrivalTrace::Diurnal { period_s: span * 0.5, depth: 0.8 },
            ArrivalTrace::FlashCrowd {
                at_s: span * 0.2,
                dur_s: span * 0.3,
                magnification: 8.0,
            },
        ] {
            let wl = ClusterWorkload {
                base: base_workload(rate, n_requests, 0xA11CE),
                trace,
                interactive_fraction,
                interactive_deadline_s: budget_s,
            };
            let cc = ClusterConfig::new(replicas, mk_pool());
            let r = run_virtual_cluster(&wl, &cc).expect("cluster run");
            let r2 = run_virtual_cluster(&wl, &cc).expect("cluster rerun");
            assert_eq!(r.records, r2.records, "bit-identical rerun ({})", trace.name());
            assert_eq!(r.wall_s, r2.wall_s);
            assert_eq!(r.shed_batch, 0, "the batch tier must never shed");
            assert_eq!(r.end_kv_blocks_in_use, 0, "the fleet leaked KV blocks");
            let ia = r.attainment(SloTier::Interactive);
            let ba = r.attainment(SloTier::Batch);
            let isf = r.shed_fraction(SloTier::Interactive);
            t.row(&[
                trace.name().to_string(),
                format!("{mult:.1}"),
                format!("{rate:.0}"),
                format!("{:.1}", ia * 100.0),
                format!("{:.1}", ba * 100.0),
                format!("{:.1}", isf * 100.0),
                format!("{:.0}", r.tokens_per_s),
                format!("{:.3}", r.wall_s),
            ]);
            cells.push(obj(vec![
                ("trace", trace.name().into()),
                ("rate_multiple", mult.into()),
                ("offered_rate_req_s", rate.into()),
                ("n_requests", n_requests.into()),
                ("replicas", replicas.into()),
                ("interactive_attainment", ia.into()),
                ("batch_attainment", ba.into()),
                ("interactive_shed_fraction", isf.into()),
                ("submitted_interactive", r.submitted_interactive.into()),
                ("submitted_batch", r.submitted_batch.into()),
                ("shed_interactive", r.shed_interactive.into()),
                ("completed_interactive", r.completed_interactive.into()),
                ("completed_batch", r.completed_batch.into()),
                ("peak_replicas", r.peak_replicas.into()),
                ("tok_s", r.tokens_per_s.into()),
                ("wall_s", r.wall_s.into()),
            ]));
            sweep.push((trace.name().to_string(), mult, r));
        }
    }
    t.note("attainment: interactive = TTFT within budget over ALL offered (shed counts against); batch = completed");
    t.note("virtual time; bit-identical across reruns for a fixed seed");
    t.print();
    // The curves must slope the right way: for each trace, interactive
    // attainment at the lightest load is no worse than at 8x overload.
    for trace_name in ["diurnal", "flash_crowd"] {
        let of = |mult: f64| {
            sweep
                .iter()
                .find(|(n, m, _)| n == trace_name && *m == mult)
                .map(|(_, _, r)| r.attainment(SloTier::Interactive))
                .expect("sweep cell")
        };
        let (lo, hi) = (of(rate_mults[0]), of(*rate_mults.last().unwrap()));
        assert!(
            lo >= hi,
            "{trace_name}: attainment {lo:.3} at {}x must be >= {hi:.3} at {}x",
            rate_mults[0],
            rate_mults.last().unwrap()
        );
    }

    // ---- load-shedding ablation at 8x overload ----
    let over_rate = 8.0 * fleet_sustainable;
    let wl_over = ClusterWorkload {
        base: base_workload(over_rate, n_requests, 0xA11CE),
        trace: ArrivalTrace::Uniform,
        interactive_fraction,
        interactive_deadline_s: budget_s,
    };
    let run_over = |shed: bool| -> ClusterReport {
        let mut cc = ClusterConfig::new(replicas, mk_pool());
        cc.shed = shed;
        run_virtual_cluster(&wl_over, &cc).expect("overload run")
    };
    let shed_on = run_over(true);
    let shed_off = run_over(false);
    let a_on = shed_on.attainment(SloTier::Interactive);
    let a_off = shed_off.attainment(SloTier::Interactive);
    let mut at = Table::new(
        format!("shedding ablation: {replicas} replicas at 8x sustainable ({over_rate:.0} req/s)"),
        &["admission", "int attain %", "int shed %", "completed int", "wall s"],
    );
    for (label, r) in [("admit-all", &shed_off), ("deadline-aware", &shed_on)] {
        at.row(&[
            label.to_string(),
            format!("{:.1}", r.attainment(SloTier::Interactive) * 100.0),
            format!("{:.1}", r.shed_fraction(SloTier::Interactive) * 100.0),
            r.completed_interactive.to_string(),
            format!("{:.3}", r.wall_s),
        ]);
    }
    at.note("same plan, same replicas — only the front-end admission rule differs");
    at.print();
    // The tentpole acceptance: shedding strictly beats admit-everything
    // on interactive attainment at overload, even though every shed
    // request counts against it.
    assert!(
        a_on > a_off,
        "shed attainment {a_on:.4} must be strictly above no-shed {a_off:.4} at overload"
    );

    // ---- autoscaling under a flash crowd ----
    let auto_rate = 2.0 * sustainable; // 2x ONE replica's capacity
    let n_auto = n_requests.max(240); // virtual time: cheap even in smoke mode
    let auto_span = n_auto as f64 / auto_rate;
    let flash = ArrivalTrace::FlashCrowd {
        at_s: auto_span * 0.2,
        dur_s: auto_span * 0.3,
        magnification: 8.0,
    };
    // Explicit thresholds so the cell self-scales on any step model:
    // a 2x-overloaded replica accumulates ~t seconds of backlog by
    // virtual time t, crossing `up_backlog_s` within a few intervals.
    let ac = AutoscaleConfig {
        min_replicas: 1,
        max_replicas: 4,
        interval_s: 0.05,
        warmup_s: 0.1,
        up_backlog_s: 0.25,
        down_backlog_s: 0.05,
    };
    let wl_auto = ClusterWorkload {
        base: base_workload(auto_rate, n_auto, 0xA11CE),
        trace: flash,
        interactive_fraction,
        interactive_deadline_s: budget_s,
    };
    let mut cc_auto = ClusterConfig::new(1, mk_pool());
    cc_auto.autoscale = Some(ac);
    let auto_r = run_virtual_cluster(&wl_auto, &cc_auto).expect("autoscale run");
    let auto_r2 = run_virtual_cluster(&wl_auto, &cc_auto).expect("autoscale rerun");
    assert_eq!(auto_r.records, auto_r2.records, "bit-identical rerun (autoscale)");
    assert_eq!(auto_r.replica_timeline, auto_r2.replica_timeline);
    assert!(
        auto_r.peak_replicas >= 2,
        "a 2x-overloaded flash crowd must scale past 1 replica (peak {})",
        auto_r.peak_replicas
    );
    let mut st = Table::new(
        format!(
            "autoscale: flash crowd at {auto_rate:.0} req/s, min {} / max {} replicas, \
             {:.2}s warm-up",
            ac.min_replicas, ac.max_replicas, ac.warmup_s
        ),
        &["t s", "active replicas"],
    );
    for &(at_s, n) in &auto_r.replica_timeline {
        st.row(&[format!("{at_s:.3}"), n.to_string()]);
    }
    st.note(format!(
        "peak {} replicas; scaling is never free — activations land warm-up late",
        auto_r.peak_replicas
    ));
    st.print();

    // ---- chaos: crash + partition mid-flash-crowd ----
    // Shedding off and a generous deadline: chaos must not hide lost
    // requests behind admission control. Replica 2 is never faulted,
    // so the fleet always has a routable survivor.
    let chaos_replicas = 3usize;
    let chaos_rate = sustainable * chaos_replicas as f64;
    let n_chaos = if fast { 80 } else { 200 };
    let chaos_span = n_chaos as f64 / chaos_rate;
    let chaos_flash = ArrivalTrace::FlashCrowd {
        at_s: chaos_span * 0.15,
        dur_s: chaos_span * 0.4,
        magnification: 6.0,
    };
    let chaos_faults = ClusterFaultPlan {
        probe_interval_s: (chaos_span * 0.05).max(1e-3),
        crashes: vec![ReplicaCrashSpec { replica: 0, at_s: chaos_span * 0.25 }],
        partitions: vec![PartitionSpec {
            replica: 1,
            from_s: chaos_span * 0.3,
            until_s: chaos_span * 0.7,
        }],
        ..ClusterFaultPlan::default()
    };
    let wl_chaos = ClusterWorkload {
        base: base_workload(chaos_rate, n_chaos, 0xC4A05),
        trace: chaos_flash,
        interactive_fraction,
        interactive_deadline_s: 1e6,
    };
    let mk_chaos_cc = |faulted: bool| -> ClusterConfig {
        let mut cc = ClusterConfig::new(chaos_replicas, mk_pool());
        cc.shed = false;
        if faulted {
            cc.faults = chaos_faults.clone();
        }
        cc
    };
    let clean_r = run_virtual_cluster(&wl_chaos, &mk_chaos_cc(false)).expect("clean run");
    let chaos_r = run_virtual_cluster(&wl_chaos, &mk_chaos_cc(true)).expect("chaos run");
    let chaos_r2 =
        run_virtual_cluster(&wl_chaos, &mk_chaos_cc(true)).expect("chaos rerun");
    assert_eq!(chaos_r.records, chaos_r2.records, "chaos recovery must rerun bit-identically");
    let chaos_completed = chaos_r.records.iter().filter(|r| r.completed()).count();
    assert_eq!(chaos_completed, n_chaos, "chaos must not lose requests");
    assert_eq!(chaos_r.end_kv_blocks_in_use, 0, "chaos leaked fleet KV blocks");
    for (i, vr) in chaos_r.replicas.iter().enumerate() {
        if let Some(vr) = vr {
            assert_eq!(vr.end_kv_blocks_in_use, 0, "replica {i} leaked KV blocks");
        }
    }
    for (f, c) in chaos_r.records.iter().zip(&clean_r.records) {
        assert_eq!(
            f.tokens, c.tokens,
            "request {} stream changed by the fault plan",
            f.request_id
        );
    }
    assert!(chaos_r.streams_failed_over > 0, "crash mid-crowd must orphan live streams");

    // Small threaded failover run: the dispatch-layer chaos path must
    // also complete everything, value-deterministically across reruns.
    let wl_live = ClusterWorkload {
        base: Workload {
            model: "opt-tiny".into(),
            rate: 800.0,
            n_requests: 24,
            prompt_len: LenDist::Uniform(1, 8),
            output_len: LenDist::Fixed(5),
            vocab: 512,
            seed: 0xC4A05,
        },
        trace: ArrivalTrace::Uniform,
        interactive_fraction: 0.0,
        interactive_deadline_s: 0.0,
    };
    let mut cc_live = ClusterConfig::new(2, mk_pool());
    cc_live.faults = ClusterFaultPlan {
        crashes: vec![ReplicaCrashSpec { replica: 0, at_s: 0.01 }],
        ..ClusterFaultPlan::default()
    };
    let run_live = || {
        let cluster = Cluster::threaded(&cc_live, "opt-tiny", || {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            c
        })
        .expect("threaded cluster");
        let r = run_cluster_open_loop(&cluster, &wl_live).expect("threaded chaos run");
        cluster.shutdown();
        r
    };
    let live = run_live();
    let live2 = run_live();
    assert_eq!(live.failed, 0, "threaded failover must leave no failed streams");
    assert_eq!(live.completed, wl_live.base.n_requests);
    assert_eq!(
        live.token_streams, live2.token_streams,
        "threaded chaos recovery must be value-deterministic"
    );

    // Hedging sub-cell: one 6x-slow replica, interactive tier hedged at
    // a quarter of the TTFT budget. Hedges fire; streams do not change.
    let wl_hedge = ClusterWorkload {
        base: base_workload(2.0 * sustainable * 2.0, if fast { 80 } else { 160 }, 0xC4A05),
        trace: ArrivalTrace::Uniform,
        interactive_fraction: 1.0,
        interactive_deadline_s: budget_s,
    };
    let mk_hedge_cc = |hedge: f64| -> ClusterConfig {
        let mut cc = ClusterConfig::new(2, mk_pool());
        cc.shed = false;
        cc.faults = ClusterFaultPlan {
            slow: vec![ReplicaSlowSpec { replica: 0, factor: 6.0 }],
            ..ClusterFaultPlan::default()
        };
        cc.hedge_fraction = hedge;
        cc
    };
    let unhedged = run_virtual_cluster(&wl_hedge, &mk_hedge_cc(0.0)).expect("unhedged run");
    let hedged = run_virtual_cluster(&wl_hedge, &mk_hedge_cc(0.25)).expect("hedged run");
    assert!(hedged.hedges_issued > 0, "a 6x-slow replica must trigger hedges");
    assert_eq!(hedged.end_kv_blocks_in_use, 0, "hedging leaked KV blocks");
    for (h, u) in hedged.records.iter().zip(&unhedged.records) {
        assert_eq!(
            h.tokens, u.tokens,
            "request {} stream changed by hedging",
            h.request_id
        );
    }

    let mut ct = Table::new(
        format!(
            "chaos: crash + partition mid-flash-crowd, {chaos_replicas} replicas at \
             {chaos_rate:.0} req/s"
        ),
        &["metric", "value"],
    );
    ct.row(&["completion".into(), format!("{chaos_completed}/{n_chaos}")]);
    ct.row(&["replica crashes".into(), chaos_r.replica_crashes.to_string()]);
    ct.row(&["partitions".into(), chaos_r.partitions.to_string()]);
    ct.row(&["streams failed over".into(), chaos_r.streams_failed_over.to_string()]);
    ct.row(&["end KV blocks in use".into(), chaos_r.end_kv_blocks_in_use.to_string()]);
    ct.row(&[
        "hedges won/issued".into(),
        format!("{}/{}", hedged.hedges_won, hedged.hedges_issued),
    ]);
    ct.row(&[
        "threaded failover completed".into(),
        format!("{}/{}", live.completed, wl_live.base.n_requests),
    ]);
    ct.note("every stream bit-identical fault-on vs fault-off; recovery rerun-identical on both paths");
    ct.print();

    // ---- machine-readable results ----
    let out_path = std::env::var("LPU_BENCH_CLUSTER_JSON")
        .unwrap_or_else(|_| "../BENCH_cluster.json".to_string());
    let doc = obj(vec![
        ("bench", "cluster_slo".into()),
        ("fast", fast.into()),
        ("model", "opt-1.3b".into()),
        ("device", device.name.clone().into()),
        ("replicas", replicas.into()),
        ("interactive_fraction", interactive_fraction.into()),
        ("ttft_budget_ms", (budget_s * 1e3).into()),
        (
            "calibration",
            obj(vec![
                ("base_ttft_ms", (base_ttft_s * 1e3).into()),
                ("sustainable_rate_req_s", sustainable.into()),
            ]),
        ),
        (
            "overload_ablation",
            obj(vec![
                ("offered_rate_req_s", over_rate.into()),
                ("noshed_interactive_attainment", a_off.into()),
                ("shed_interactive_attainment", a_on.into()),
                ("attainment_gain", (a_on - a_off).into()),
                (
                    "shed_fraction_interactive",
                    shed_on.shed_fraction(SloTier::Interactive).into(),
                ),
            ]),
        ),
        (
            "autoscale_summary",
            obj(vec![
                ("trace", flash.name().into()),
                ("min_replicas", ac.min_replicas.into()),
                ("max_replicas", ac.max_replicas.into()),
                ("peak_replicas", auto_r.peak_replicas.into()),
                ("scale_events", auto_r.replica_timeline.len().into()),
                ("wall_s", auto_r.wall_s.into()),
            ]),
        ),
        (
            "chaos_summary",
            obj(vec![
                ("trace", chaos_flash.name().into()),
                ("replicas", chaos_replicas.into()),
                ("n_requests", n_chaos.into()),
                ("completion", (chaos_completed as f64 / n_chaos as f64).into()),
                ("end_kv_blocks_in_use", chaos_r.end_kv_blocks_in_use.into()),
                ("streams_identical_fault_on_off", true.into()),
                ("replica_crashes", chaos_r.replica_crashes.into()),
                ("partitions", chaos_r.partitions.into()),
                ("streams_failed_over", chaos_r.streams_failed_over.into()),
                ("hedges_issued", hedged.hedges_issued.into()),
                ("hedges_won", hedged.hedges_won.into()),
                ("threaded_completed", live.completed.into()),
                ("threaded_failed", live.failed.into()),
            ]),
        ),
        ("cells", Json::Arr(cells)),
    ]);
    match std::fs::write(&out_path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }
}
