//! Cluster-scale SLO study over the virtual fleet: {arrival trace ×
//! offered load} sweeps of a 2-replica cluster with a 50/50
//! interactive/batch tier mix, reporting per-tier SLO attainment and
//! shed fraction per cell, plus two ablations:
//!
//! * **load shedding**: at 8x the fleet's sustainable rate, deadline-
//!   aware admission (projected queue delay vs the tier's TTFT budget)
//!   vs admit-everything — interactive attainment with shedding must
//!   land strictly above the no-shedding baseline (asserted; the
//!   no-shed fleet queues every arrival until nearly nothing meets its
//!   budget, while admission keeps the admitted set inside it);
//! * **autoscaling**: a flash-crowd trace over a min=1/max=4 fleet
//!   with a warm-up charge per activation — the controller must ride
//!   the burst up to >= 2 active replicas (asserted) and the full
//!   `(t, active)` timeline is emitted.
//!
//! The TTFT budget and rate grid are **self-calibrated**: a light-load
//! probe measures base TTFT (budget = 8x its p50) and a backlogged
//! probe measures one replica's sustainable request rate, so the sweep
//! lands in the same regimes on any step model. Every number is a pure
//! function of (seed, config); reruns are asserted bit-identical.
//! Results go to `../BENCH_cluster.json` (override with
//! `LPU_BENCH_CLUSTER_JSON=<path>`; schema pinned by
//! `tests/bench_schema.rs` and documented in README).
//!
//! `LPU_BENCH_FAST=1` shrinks the sweep for CI smoke runs.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_virtual, run_virtual_cluster, ArrivalTrace, AutoscaleConfig, ClusterConfig,
    ClusterReport, ClusterWorkload, LenDist, SchedulerPolicy, SloTier, StepModel,
    VirtualConfig, Workload,
};
use lpu::model::by_name;
use lpu::util::json::{obj, Json};
use lpu::util::table::Table;

fn base_workload(rate: f64, n: usize, seed: u64) -> Workload {
    Workload {
        model: "opt-1.3b".into(),
        rate,
        n_requests: n,
        prompt_len: LenDist::Uniform(4, 32),
        output_len: LenDist::LongTail { min: 8, mean_extra: 48.0, cap: 256 },
        vocab: 512,
        seed,
    }
}

fn main() {
    let fast = std::env::var("LPU_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests = if fast { 120 } else { 400 };
    let rate_mults: &[f64] = if fast { &[0.5, 8.0] } else { &[0.5, 1.0, 2.0, 8.0] };
    let replicas = 2usize;
    let interactive_fraction = 0.5f64;

    let model = by_name("opt-1.3b").unwrap();
    let device = LpuConfig::asic_3_28tbs();
    let step = StepModel::from_config(&model, &device, 1);
    let mk_pool = || {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = 8;
        vc
    };

    // ---- self-calibration: base TTFT at whisper-light load, and one
    // replica's sustainable request rate from a backlogged run. Both
    // deterministic, so the derived budget and rate grid are too.
    let light = run_virtual(&base_workload(20.0, 40, 0xC11B), &mk_pool()).expect("probe");
    let base_ttft_s = light.ttft.p50;
    let backlog =
        run_virtual(&base_workload(100_000.0, n_requests.min(160), 0xFEED), &mk_pool())
            .expect("backlog probe");
    let total_tokens: usize = backlog.records.iter().map(|r| r.tokens.len()).sum();
    let mean_out = total_tokens as f64 / backlog.records.len().max(1) as f64;
    let sustainable = backlog.tokens_per_s / mean_out.max(1.0);
    let fleet_sustainable = sustainable * replicas as f64;
    let budget_s = base_ttft_s * 8.0;

    // ---- {trace x offered load} attainment sweep ----
    let mut cells: Vec<Json> = Vec::new();
    let mut t = Table::new(
        format!(
            "cluster SLO sweep: opt-1.3b on {}, {replicas} replicas, 50/50 tier mix, \
             TTFT budget {:.2} ms",
            device.name,
            budget_s * 1e3
        ),
        &[
            "trace",
            "x sustain",
            "req/s",
            "int attain %",
            "batch attain %",
            "int shed %",
            "tok/s",
            "wall s",
        ],
    );
    let mut sweep: Vec<(String, f64, ClusterReport)> = Vec::new();
    for &mult in rate_mults {
        let rate = mult * fleet_sustainable;
        let span = n_requests as f64 / rate;
        for trace in [
            ArrivalTrace::Diurnal { period_s: span * 0.5, depth: 0.8 },
            ArrivalTrace::FlashCrowd {
                at_s: span * 0.2,
                dur_s: span * 0.3,
                magnification: 8.0,
            },
        ] {
            let wl = ClusterWorkload {
                base: base_workload(rate, n_requests, 0xA11CE),
                trace,
                interactive_fraction,
                interactive_deadline_s: budget_s,
            };
            let cc = ClusterConfig::new(replicas, mk_pool());
            let r = run_virtual_cluster(&wl, &cc).expect("cluster run");
            let r2 = run_virtual_cluster(&wl, &cc).expect("cluster rerun");
            assert_eq!(r.records, r2.records, "bit-identical rerun ({})", trace.name());
            assert_eq!(r.wall_s, r2.wall_s);
            assert_eq!(r.shed_batch, 0, "the batch tier must never shed");
            assert_eq!(r.end_kv_blocks_in_use, 0, "the fleet leaked KV blocks");
            let ia = r.attainment(SloTier::Interactive);
            let ba = r.attainment(SloTier::Batch);
            let isf = r.shed_fraction(SloTier::Interactive);
            t.row(&[
                trace.name().to_string(),
                format!("{mult:.1}"),
                format!("{rate:.0}"),
                format!("{:.1}", ia * 100.0),
                format!("{:.1}", ba * 100.0),
                format!("{:.1}", isf * 100.0),
                format!("{:.0}", r.tokens_per_s),
                format!("{:.3}", r.wall_s),
            ]);
            cells.push(obj(vec![
                ("trace", trace.name().into()),
                ("rate_multiple", mult.into()),
                ("offered_rate_req_s", rate.into()),
                ("n_requests", n_requests.into()),
                ("replicas", replicas.into()),
                ("interactive_attainment", ia.into()),
                ("batch_attainment", ba.into()),
                ("interactive_shed_fraction", isf.into()),
                ("submitted_interactive", r.submitted_interactive.into()),
                ("submitted_batch", r.submitted_batch.into()),
                ("shed_interactive", r.shed_interactive.into()),
                ("completed_interactive", r.completed_interactive.into()),
                ("completed_batch", r.completed_batch.into()),
                ("peak_replicas", r.peak_replicas.into()),
                ("tok_s", r.tokens_per_s.into()),
                ("wall_s", r.wall_s.into()),
            ]));
            sweep.push((trace.name().to_string(), mult, r));
        }
    }
    t.note("attainment: interactive = TTFT within budget over ALL offered (shed counts against); batch = completed");
    t.note("virtual time; bit-identical across reruns for a fixed seed");
    t.print();
    // The curves must slope the right way: for each trace, interactive
    // attainment at the lightest load is no worse than at 8x overload.
    for trace_name in ["diurnal", "flash_crowd"] {
        let of = |mult: f64| {
            sweep
                .iter()
                .find(|(n, m, _)| n == trace_name && *m == mult)
                .map(|(_, _, r)| r.attainment(SloTier::Interactive))
                .expect("sweep cell")
        };
        let (lo, hi) = (of(rate_mults[0]), of(*rate_mults.last().unwrap()));
        assert!(
            lo >= hi,
            "{trace_name}: attainment {lo:.3} at {}x must be >= {hi:.3} at {}x",
            rate_mults[0],
            rate_mults.last().unwrap()
        );
    }

    // ---- load-shedding ablation at 8x overload ----
    let over_rate = 8.0 * fleet_sustainable;
    let wl_over = ClusterWorkload {
        base: base_workload(over_rate, n_requests, 0xA11CE),
        trace: ArrivalTrace::Uniform,
        interactive_fraction,
        interactive_deadline_s: budget_s,
    };
    let run_over = |shed: bool| -> ClusterReport {
        let mut cc = ClusterConfig::new(replicas, mk_pool());
        cc.shed = shed;
        run_virtual_cluster(&wl_over, &cc).expect("overload run")
    };
    let shed_on = run_over(true);
    let shed_off = run_over(false);
    let a_on = shed_on.attainment(SloTier::Interactive);
    let a_off = shed_off.attainment(SloTier::Interactive);
    let mut at = Table::new(
        format!("shedding ablation: {replicas} replicas at 8x sustainable ({over_rate:.0} req/s)"),
        &["admission", "int attain %", "int shed %", "completed int", "wall s"],
    );
    for (label, r) in [("admit-all", &shed_off), ("deadline-aware", &shed_on)] {
        at.row(&[
            label.to_string(),
            format!("{:.1}", r.attainment(SloTier::Interactive) * 100.0),
            format!("{:.1}", r.shed_fraction(SloTier::Interactive) * 100.0),
            r.completed_interactive.to_string(),
            format!("{:.3}", r.wall_s),
        ]);
    }
    at.note("same plan, same replicas — only the front-end admission rule differs");
    at.print();
    // The tentpole acceptance: shedding strictly beats admit-everything
    // on interactive attainment at overload, even though every shed
    // request counts against it.
    assert!(
        a_on > a_off,
        "shed attainment {a_on:.4} must be strictly above no-shed {a_off:.4} at overload"
    );

    // ---- autoscaling under a flash crowd ----
    let auto_rate = 2.0 * sustainable; // 2x ONE replica's capacity
    let n_auto = n_requests.max(240); // virtual time: cheap even in smoke mode
    let auto_span = n_auto as f64 / auto_rate;
    let flash = ArrivalTrace::FlashCrowd {
        at_s: auto_span * 0.2,
        dur_s: auto_span * 0.3,
        magnification: 8.0,
    };
    // Explicit thresholds so the cell self-scales on any step model:
    // a 2x-overloaded replica accumulates ~t seconds of backlog by
    // virtual time t, crossing `up_backlog_s` within a few intervals.
    let ac = AutoscaleConfig {
        min_replicas: 1,
        max_replicas: 4,
        interval_s: 0.05,
        warmup_s: 0.1,
        up_backlog_s: 0.25,
        down_backlog_s: 0.05,
    };
    let wl_auto = ClusterWorkload {
        base: base_workload(auto_rate, n_auto, 0xA11CE),
        trace: flash,
        interactive_fraction,
        interactive_deadline_s: budget_s,
    };
    let mut cc_auto = ClusterConfig::new(1, mk_pool());
    cc_auto.autoscale = Some(ac);
    let auto_r = run_virtual_cluster(&wl_auto, &cc_auto).expect("autoscale run");
    let auto_r2 = run_virtual_cluster(&wl_auto, &cc_auto).expect("autoscale rerun");
    assert_eq!(auto_r.records, auto_r2.records, "bit-identical rerun (autoscale)");
    assert_eq!(auto_r.replica_timeline, auto_r2.replica_timeline);
    assert!(
        auto_r.peak_replicas >= 2,
        "a 2x-overloaded flash crowd must scale past 1 replica (peak {})",
        auto_r.peak_replicas
    );
    let mut st = Table::new(
        format!(
            "autoscale: flash crowd at {auto_rate:.0} req/s, min {} / max {} replicas, \
             {:.2}s warm-up",
            ac.min_replicas, ac.max_replicas, ac.warmup_s
        ),
        &["t s", "active replicas"],
    );
    for &(at_s, n) in &auto_r.replica_timeline {
        st.row(&[format!("{at_s:.3}"), n.to_string()]);
    }
    st.note(format!(
        "peak {} replicas; scaling is never free — activations land warm-up late",
        auto_r.peak_replicas
    ));
    st.print();

    // ---- machine-readable results ----
    let out_path = std::env::var("LPU_BENCH_CLUSTER_JSON")
        .unwrap_or_else(|_| "../BENCH_cluster.json".to_string());
    let doc = obj(vec![
        ("bench", "cluster_slo".into()),
        ("fast", fast.into()),
        ("model", "opt-1.3b".into()),
        ("device", device.name.clone().into()),
        ("replicas", replicas.into()),
        ("interactive_fraction", interactive_fraction.into()),
        ("ttft_budget_ms", (budget_s * 1e3).into()),
        (
            "calibration",
            obj(vec![
                ("base_ttft_ms", (base_ttft_s * 1e3).into()),
                ("sustainable_rate_req_s", sustainable.into()),
            ]),
        ),
        (
            "overload_ablation",
            obj(vec![
                ("offered_rate_req_s", over_rate.into()),
                ("noshed_interactive_attainment", a_off.into()),
                ("shed_interactive_attainment", a_on.into()),
                ("attainment_gain", (a_on - a_off).into()),
                (
                    "shed_fraction_interactive",
                    shed_on.shed_fraction(SloTier::Interactive).into(),
                ),
            ]),
        ),
        (
            "autoscale_summary",
            obj(vec![
                ("trace", flash.name().into()),
                ("min_replicas", ac.min_replicas.into()),
                ("max_replicas", ac.max_replicas.into()),
                ("peak_replicas", auto_r.peak_replicas.into()),
                ("scale_events", auto_r.replica_timeline.len().into()),
                ("wall_s", auto_r.wall_s.into()),
            ]),
        ),
        ("cells", Json::Arr(cells)),
    ]);
    match std::fs::write(&out_path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }
}
