//! Table 1 — the LPU instruction set: category table regenerated from
//! the implementation, plus encode/decode/assemble throughput.

use lpu::isa::{asm, Category, Cond, FusedOp, Instr, Program, ScalarOp, VecOp};
use lpu::util::bench::Bencher;
use lpu::util::table::Table;

fn representative_instrs() -> Vec<(&'static str, &'static str, &'static str, Instr)> {
    use Instr::*;
    vec![
        ("MEM", "Read Embedding", "HBM -> LMU", ReadEmbedding { addr: 0x1000, dst: 1, len: 2048 }),
        ("MEM", "Read Key/Value", "HBM -> SMA", ReadKv { addr: 0x2000, len: 65536 }),
        ("MEM", "Read Parameters", "HBM -> SMA", ReadParams { addr: 0x3000, len: 1 << 22 }),
        ("MEM", "Read from Host", "Host -> LMU", ReadHost { addr: 0, dst: 0, len: 1 }),
        ("MEM", "Write Key/Value", "SMA -> HBM", WriteKv { addr: 0x4000, len: 9216 }),
        ("MEM", "Write to Host", "LMU -> Host", WriteHost { src: 2, addr: 0, len: 1 }),
        (
            "COMP",
            "Matrix Computation",
            "LMU/SMA -> LMU/SMA",
            MatMul { src: 1, dst: 2, k: 9216, n: 36864, accum: false, to_net: true, from_lmu: false },
        ),
        (
            "COMP",
            "Vector Computation",
            "LMU -> LMU",
            VecCompute { op: VecOp::Softmax, a: 3, b: 0, dst: 3, len: 2048 },
        ),
        (
            "COMP",
            "Vector Fusion Computation",
            "LMU -> LMU",
            VecFused { op: FusedOp::AddLayerNorm, a: 4, b: 5, dst: 6, len: 9216 },
        ),
        ("COMP", "Sampling with Sort", "LMU -> LMU", Sample { src: 7, dst: 8, len: 50272 }),
        ("NET", "Transmit", "LMU -> P2P", Transmit { src: 9, len: 4608, hops: 1 }),
        ("NET", "Receive", "P2P -> LMU", Receive { dst: 10, len: 4608, hops: 1 }),
        (
            "CTRL",
            "Scalar Computation",
            "ICP/LMU -> ICP/LMU",
            Scalar { op: ScalarOp::Add, dst: 1, a: 2, imm: 64 },
        ),
        ("CTRL", "Branch", "ICP -> ICP", Branch { cond: Cond::Lt, a: 1, b: 2, target: 4 }),
        ("CTRL", "Jump", "ICP -> ICP", Jump { target: 0 }),
    ]
}

fn main() {
    let mut t = Table::new(
        "Table 1 — LPU instruction set architecture",
        &["category", "instruction type", "source -> destination", "encoding (asm)"],
    );
    for (cat, name, route, instr) in representative_instrs() {
        assert_eq!(
            format!("{:?}", instr.category()).to_uppercase().replace("CTRL", "CTRL"),
            match instr.category() {
                Category::Mem => "MEM",
                Category::Comp => "COMP",
                Category::Net => "NET",
                Category::Ctrl => "CTRL",
            }
        );
        t.row(&[cat.to_string(), name.to_string(), route.to_string(), asm::disasm(&instr)]);
    }
    t.print();

    // Throughput micro-benches over the ISA machinery.
    let instrs: Vec<Instr> = representative_instrs().into_iter().map(|(_, _, _, i)| i).collect();
    let words: Vec<u128> = instrs.iter().map(|i| i.encode().unwrap()).collect();
    let prog = Program::new(instrs.clone());
    let text: String = prog
        .instrs
        .iter()
        .map(|i| asm::disasm(i))
        .collect::<Vec<_>>()
        .join("\n");

    let mut b = Bencher::new();
    let n = instrs.len() as f64;
    b.bench_throughput("isa/encode", "instr", n, || {
        instrs.iter().map(|i| i.encode().unwrap()).collect::<Vec<_>>()
    });
    b.bench_throughput("isa/decode", "instr", n, || {
        words.iter().map(|&w| Instr::decode(w).unwrap()).collect::<Vec<_>>()
    });
    b.bench_throughput("isa/assemble", "instr", n, || asm::assemble(&text).unwrap());
    b.bench_throughput("isa/program-serialize", "instr", n, || prog.to_bytes().unwrap());
}
