//! Figure 7(b) — server energy efficiency (tokens/s/kW):
//! Orion-cloud (8 FPGA LPUs) vs 2×H100 on OPT-66B (paper: 1.33×) and
//! Orion-edge (2 FPGA LPUs) vs 2×L4 on OPT-1.3B/6.7B (paper: 1.32×).

use lpu::config::{LpuConfig, ServerConfig};
use lpu::gpu::GpuConfig;
use lpu::model::by_name;
use lpu::power::{orion_power_w, paper, tokens_per_s_per_kw};
use lpu::sim::simulate_generation;
use lpu::util::table::Table;

fn orion_tokens_per_s(server: &ServerConfig, model: &str, out: usize) -> f64 {
    let m = by_name(model).unwrap();
    let r = simulate_generation(&m, &LpuConfig::fpga_u55c(), server.n_devices, 32, out, true)
        .unwrap();
    r.tokens_per_s
}

fn main() {
    let out = 512; // shorter output keeps the FPGA sims quick; per-token
                   // rates are position-averaged like the paper's run

    // ---- cloud: Orion-cloud vs 2xH100, OPT-66B ----
    let cloud = ServerConfig::orion_cloud();
    let h100 = GpuConfig::h100();
    let m66 = by_name("opt-66b").unwrap();

    let orion_tps = orion_tokens_per_s(&cloud, "opt-66b", out);
    let orion_w = orion_power_w(cloud.n_devices, cloud.host_power_w);
    let orion_eff = tokens_per_s_per_kw(orion_tps, orion_w);

    let h100_tps = 1.0 / h100.decode_latency(&m66, 2, 1040);
    let h100_w = h100.decode_power(&m66, 2);
    let h100_eff = tokens_per_s_per_kw(h100_tps, h100_w);

    let mut t = Table::new(
        "Fig 7(b) — cloud server efficiency, OPT-66B",
        &["server", "tokens/s", "power W", "tokens/s/kW", "ratio", "paper"],
    );
    t.row(&[
        "orion-cloud (8x LPU FPGA)".into(),
        format!("{orion_tps:.1}"),
        format!("{orion_w:.0}"),
        format!("{orion_eff:.1}"),
        format!("{:.2}x", orion_eff / h100_eff),
        "1.33x".into(),
    ]);
    t.row(&[
        "2x NVIDIA H100".into(),
        format!("{h100_tps:.1}"),
        format!("{h100_w:.0}"),
        format!("{h100_eff:.1}"),
        "1.00x".into(),
        "-".into(),
    ]);
    t.note(format!(
        "paper wall power: orion-cloud {} W vs H100 server {} W",
        paper::ORION_CLOUD_POWER_W,
        paper::H100_SERVER_POWER_W
    ));
    t.print();

    // ---- edge: Orion-edge vs 2xL4, OPT-1.3B and 6.7B ----
    let edge = ServerConfig::orion_edge();
    let l4 = GpuConfig::l4();
    let mut e = Table::new(
        "Fig 7(b) — edge server efficiency",
        &["model", "orion-edge t/s/kW", "2xL4 t/s/kW", "ratio", "paper"],
    );
    for (model, paper_ratio) in [("opt-1.3b", "-"), ("opt-6.7b", "1.32x")] {
        let m = by_name(model).unwrap();
        let o_tps = orion_tokens_per_s(&edge, model, out);
        let o_eff = tokens_per_s_per_kw(o_tps, orion_power_w(edge.n_devices, edge.host_power_w));
        let l4_tps = 1.0 / l4.decode_latency(&m, 2, 1040);
        // 2xL4 server: two 72 W boards + host chassis.
        let l4_w = l4.decode_power(&m, 2) + 140.0;
        let l4_eff = tokens_per_s_per_kw(l4_tps, l4_w);
        e.row(&[
            model.to_string(),
            format!("{o_eff:.1}"),
            format!("{l4_eff:.1}"),
            format!("{:.2}x", o_eff / l4_eff),
            paper_ratio.to_string(),
        ]);
    }
    e.note("paper: orion-edge 1.32x over 2x L4 on OPT-6.7B");
    e.print();
}
