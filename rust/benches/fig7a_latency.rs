//! Figure 7(a) — Latency per output token and bandwidth utilization,
//! LPU (cycle simulator) vs GPU (calibrated analytical model), with the
//! paper's reported values alongside.
//!
//! Methodology matches the paper: input 32 tokens, output 2016 tokens,
//! 3.28 TB/s LPU vs H100 (3.35 TB/s), equal device counts.

use lpu::config::LpuConfig;
use lpu::gpu::GpuConfig;
use lpu::model::by_name;
use lpu::sim::simulate_generation;
use lpu::util::table::Table;

fn main() {
    let cfg = LpuConfig::asic_3_28tbs();
    let h100 = GpuConfig::h100();
    let (input, output) = (32usize, 2016usize);

    // (model, devices, paper LPU ms/token, paper speedup, paper LPU util %, paper GPU util %)
    let rows: [(&str, usize, Option<f64>, Option<f64>, Option<f64>, Option<f64>); 4] = [
        ("opt-1.3b", 1, Some(1.25), Some(2.09), Some(63.3), Some(28.9)),
        ("opt-6.7b", 1, Some(4.62), None, None, None),
        ("opt-30b", 1, None, None, Some(90.2), Some(70.8)),
        ("opt-66b", 2, Some(22.2), Some(1.37), Some(90.6), Some(64.9)),
    ];

    let mut t = Table::new(
        "Fig 7(a) — ms/token and bandwidth utilization, LPU vs H100",
        &[
            "model", "devs", "LPU ms", "paper", "GPU ms", "speedup", "paper", "LPU util %",
            "paper", "GPU util %", "paper",
        ],
    );

    let avg_pos = input + output / 2;
    for (name, devs, p_ms, p_speed, p_util, p_gutil) in rows {
        let m = by_name(name).unwrap();
        let lpu = simulate_generation(&m, &cfg, devs, input, output, true).unwrap();
        let gpu_ms = h100.decode_latency(&m, devs, avg_pos) * 1e3;
        let shard = m.decode_stream_bytes() / devs as u64;
        let gpu_util = h100.utilization(shard) * 0.92f64.powi((devs as f64).log2() as i32);
        let speedup = gpu_ms / lpu.ms_per_token;
        let fmt_opt = |o: Option<f64>, prec: usize| {
            o.map(|v| format!("{v:.prec$}")).unwrap_or_else(|| "-".into())
        };
        t.row(&[
            name.to_string(),
            devs.to_string(),
            format!("{:.2}", lpu.ms_per_token),
            fmt_opt(p_ms, 2),
            format!("{gpu_ms:.2}"),
            format!("{speedup:.2}x"),
            fmt_opt(p_speed, 2).replace('-', "-"),
            format!("{:.1}", lpu.bandwidth_util * 100.0),
            fmt_opt(p_util, 1),
            format!("{:.1}", gpu_util * 100.0),
            fmt_opt(p_gutil, 1),
        ]);
    }
    t.note("LPU: cycle-accurate simulation; GPU: analytical model calibrated to the paper's measured utilizations");
    t.note("paper headlines: 2.09x @1.3B (1 dev), 1.37x @66B (2 devs)");
    t.print();
}
