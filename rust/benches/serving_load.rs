//! Serving load study over the deterministic virtual-time harness:
//! {scheduler policy × offered rate × device/worker count} sweeps with
//! p50/p95/p99 TTFT and TPOT per cell — the paper's Fig. 7 latency
//! regime, now under open-loop Poisson load with continuous batching.
//!
//! Every number here is a pure function of (seed, config): rerunning the
//! bench on an unchanged tree prints bit-identical tables, so diffs in
//! review are real regressions, not noise.
//!
//! `LPU_BENCH_FAST=1` shrinks the sweep for CI smoke runs.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_virtual, LenDist, SchedulerPolicy, StepModel, VirtualConfig, Workload,
};
use lpu::model::by_name;
use lpu::util::table::Table;

fn main() {
    let fast = std::env::var("LPU_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests = if fast { 60 } else { 400 };
    let rates: &[f64] = if fast { &[200.0, 2000.0] } else { &[100.0, 400.0, 1600.0, 6400.0] };
    let worker_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4, 8] };

    let model = by_name("opt-1.3b").unwrap();
    let device = LpuConfig::asic_3_28tbs();
    // One model replica per worker: each worker is one LPU device
    // running the 1.3B decode stream, KV-bounded by its own HBM.
    let step = StepModel::from_config(&model, &device, 1);
    let kv_budget = device.hbm.capacity().saturating_sub(model.weight_bytes());

    for policy in SchedulerPolicy::all() {
        let mut t = Table::new(
            format!(
                "serving load: opt-1.3b on {} ({} scheduling, max 16 slots, batch cap 8)",
                device.name,
                policy.name()
            ),
            &[
                "workers",
                "req/s",
                "tok/s",
                "peak act",
                "TTFT p50/p95/p99 ms",
                "TPOT p50/p95/p99 ms",
                "lat p99 ms",
            ],
        );
        for &workers in worker_counts {
            for &rate in rates {
                let wl = Workload {
                    model: "opt-1.3b".into(),
                    rate,
                    n_requests,
                    prompt_len: LenDist::Uniform(4, 32),
                    output_len: LenDist::LongTail { min: 8, mean_extra: 48.0, cap: 256 },
                    vocab: 512,
                    seed: 0xA11CE,
                };
                let mut vc = VirtualConfig::new(policy, workers, 16, step);
                vc.max_batch = 8;
                vc.kv_bytes_per_token = model.kv_bytes_per_token();
                vc.kv_budget_bytes = kv_budget;
                let r = run_virtual(&wl, &vc).expect("virtual run");
                assert_eq!(r.records.len(), n_requests, "request conservation");
                t.row(&[
                    workers.to_string(),
                    format!("{rate:.0}"),
                    format!("{:.0}", r.tokens_per_s),
                    r.max_concurrent.to_string(),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        r.ttft.p50 * 1e3,
                        r.ttft.p95 * 1e3,
                        r.ttft.p99 * 1e3
                    ),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        r.tpot.p50 * 1e3,
                        r.tpot.p95 * 1e3,
                        r.tpot.p99 * 1e3
                    ),
                    format!("{:.1}", r.request_latency.p99 * 1e3),
                ]);
            }
        }
        t.note("virtual time; bit-identical across reruns for a fixed seed");
        t.note("peak act = peak simultaneously active requests across workers");
        t.print();
    }

    // Batching ablation: the same backlog at batch caps 1/2/4/8/16 —
    // the continuous-batching throughput lever in one table.
    let mut ab = Table::new(
        "batch-cap ablation: opt-1.3b, 1 worker, backlogged arrivals",
        &["batch cap", "tok/s", "makespan s", "TPOT p95 ms"],
    );
    let wl = Workload {
        model: "opt-1.3b".into(),
        rate: 100_000.0,
        n_requests: if fast { 32 } else { 128 },
        prompt_len: LenDist::Fixed(8),
        output_len: LenDist::Fixed(64),
        vocab: 512,
        seed: 0xBEEF,
    };
    for cap in [1usize, 2, 4, 8, 16] {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = cap;
        let r = run_virtual(&wl, &vc).expect("virtual run");
        ab.row(&[
            cap.to_string(),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.3}", r.wall_s),
            format!("{:.2}", r.tpot.p95 * 1e3),
        ]);
    }
    ab.note("weights stream once per fused step: tok/s grows with cap, TPOT degrades gently");
    ab.print();
}
