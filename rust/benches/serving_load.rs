//! Serving load study over the deterministic virtual-time harness:
//! {scheduler policy × offered rate × device/worker count} sweeps with
//! p50/p95/p99 TTFT and TPOT per cell — the paper's Fig. 7 latency
//! regime, now under open-loop Poisson load with continuous batching —
//! plus two ablations:
//!
//! * **KV policy**: worst-case reservation (`KvPolicy::Reserve`) vs the
//!   paged reserve-as-you-grow allocator (`KvPolicy::Paged`) at the
//!   *same* HBM budget, where paging sustains a materially larger
//!   active batch and higher tok/s;
//! * **chunked prefill**: a long-prompt interference mix where
//!   single-pass prefill (`prefill_chunk = 0`) freezes co-batched
//!   decodes for the whole prompt sweep, while a token-budgeted chunk
//!   (`--prefill-chunk`-style `prefill_chunk = N`) cuts the neighbors'
//!   TPOT p99 at the same KV budget with the long prompt's TTFT staying
//!   within a small factor (both asserted);
//! * **host KV tier**: long-context requests at an oversubscribed HBM
//!   budget, where preempted lanes demote their KV blocks to a bounded
//!   host pool and readmission restores them instead of recomputing —
//!   resume-after-preemption gap and wall time strictly below the
//!   recompute path at the same budget, bit-identical streams asserted
//!   on both the virtual and threaded paths, and the tier self-disables
//!   on a backend without session-restore support;
//! * **fault recovery**: worker 0 killed mid-run under a deterministic
//!   `--fault-plan`-style spec (crash + 1% transient faults): every
//!   request still completes via failover + bounded retry, streams stay
//!   bit-identical to the fault-free run on both paths, the pager ends
//!   the run fully free, and the same seed reproduces the identical
//!   recovery decisions across reruns.
//!
//! * **tracing overhead**: the request-lifecycle span recorder
//!   (`--trace-out`) rerun over a sweep cell with tracing on vs off —
//!   token streams and the virtual clock bit-identical, one timeline
//!   per request captured, and host-side cost gated at 1.05x.
//!
//! Every number here is a pure function of (seed, config): rerunning the
//! bench on an unchanged tree prints bit-identical tables, so diffs in
//! review are real regressions, not noise. (Sole exception: the
//! tracing-overhead host walls are measured times — they are gated by
//! assertion, not compared bit-for-bit.) Results are also written as
//! machine-readable JSON to `../BENCH_serving.json` (override with
//! `LPU_BENCH_JSON=<path>`; schema documented in README's bench
//! section) so the perf trajectory is tracked in-repo.
//!
//! `LPU_BENCH_FAST=1` shrinks the sweep for CI smoke runs.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_virtual, run_virtual_plan, BackendFactory, Coordinator, CoordinatorConfig, FaultPlan,
    HostTierConfig, KvPolicy, LenDist, PrefixCacheConfig, Request, RouterPolicy,
    SchedulerPolicy, StepModel, VirtualConfig, VirtualReport, Workload,
};
use lpu::model::by_name;
use lpu::util::json::{obj, Json};
use lpu::util::stats::Summary;
use lpu::util::table::Table;

fn cell_json(
    section: &str,
    sched: SchedulerPolicy,
    kv: KvPolicy,
    workers: usize,
    rate: f64,
    n_requests: usize,
    r: &VirtualReport,
) -> Json {
    obj(vec![
        ("section", section.into()),
        ("sched_policy", sched.name().into()),
        ("kv_policy", kv.name().into()),
        ("workers", workers.into()),
        ("rate_req_s", rate.into()),
        ("n_requests", n_requests.into()),
        ("tok_s", r.tokens_per_s.into()),
        ("peak_active", r.max_concurrent.into()),
        ("preemptions", r.preemptions.into()),
        ("peak_kv_blocks", r.peak_kv_blocks.into()),
        ("kv_capacity_blocks", r.kv_capacity_blocks.into()),
        ("ttft_p99_ms", (r.ttft.p99 * 1e3).into()),
        ("tpot_p99_ms", (r.tpot.p99 * 1e3).into()),
        ("lat_p99_ms", (r.request_latency.p99 * 1e3).into()),
        ("wall_s", r.wall_s.into()),
    ])
}

fn main() {
    let fast = std::env::var("LPU_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests = if fast { 60 } else { 400 };
    let rates: &[f64] = if fast { &[200.0, 2000.0] } else { &[100.0, 400.0, 1600.0, 6400.0] };
    let worker_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4, 8] };

    let model = by_name("opt-1.3b").unwrap();
    let device = LpuConfig::asic_3_28tbs();
    // One model replica per worker: each worker is one LPU device
    // running the 1.3B decode stream, KV-bounded by its own HBM.
    let step = StepModel::from_config(&model, &device, 1);
    let kv_budget = device.hbm.capacity().saturating_sub(model.weight_bytes());
    let mut cells: Vec<Json> = Vec::new();

    // ---- step-cost calibration: first-order bytes/BW vs the cycle
    // simulator (ROADMAP item: StepModel wired to measured
    // cycles-per-token). The KV ablation below runs on the calibrated
    // costs.
    let cal = StepModel::calibrated(&model, &device, 1).expect("calibration compiles");
    let mut ct = Table::new(
        "step-model calibration: opt-1.3b on ".to_string() + &device.name,
        &["model", "step@pos0 ms", "step@pos1024 ms", "kv ns/pos"],
    );
    for (name, m) in [("first-order bytes/BW", &step), ("CoreSim-calibrated", &cal)] {
        ct.row(&[
            name.to_string(),
            format!("{:.4}", m.single_s(0) * 1e3),
            format!("{:.4}", m.single_s(1024) * 1e3),
            format!("{:.2}", m.kv_read_s_per_pos * 1e9),
        ]);
    }
    ct.note("calibrated = linear fit through compiled-program CoreSim runs at two positions");
    ct.print();

    for policy in SchedulerPolicy::all() {
        let mut t = Table::new(
            format!(
                "serving load: opt-1.3b on {} ({} scheduling, max 16 slots, batch cap 8)",
                device.name,
                policy.name()
            ),
            &[
                "workers",
                "req/s",
                "tok/s",
                "peak act",
                "TTFT p50/p95/p99 ms",
                "TPOT p50/p95/p99 ms",
                "lat p99 ms",
            ],
        );
        for &workers in worker_counts {
            for &rate in rates {
                let wl = Workload {
                    model: "opt-1.3b".into(),
                    rate,
                    n_requests,
                    prompt_len: LenDist::Uniform(4, 32),
                    output_len: LenDist::LongTail { min: 8, mean_extra: 48.0, cap: 256 },
                    vocab: 512,
                    seed: 0xA11CE,
                };
                let mut vc = VirtualConfig::new(policy, workers, 16, step);
                vc.max_batch = 8;
                vc.kv_bytes_per_token = model.kv_bytes_per_token();
                vc.kv_budget_bytes = kv_budget;
                let r = run_virtual(&wl, &vc).expect("virtual run");
                assert_eq!(r.records.len(), n_requests, "request conservation");
                cells.push(cell_json(
                    "sched_sweep",
                    policy,
                    KvPolicy::Reserve,
                    workers,
                    rate,
                    n_requests,
                    &r,
                ));
                t.row(&[
                    workers.to_string(),
                    format!("{rate:.0}"),
                    format!("{:.0}", r.tokens_per_s),
                    r.max_concurrent.to_string(),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        r.ttft.p50 * 1e3,
                        r.ttft.p95 * 1e3,
                        r.ttft.p99 * 1e3
                    ),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        r.tpot.p50 * 1e3,
                        r.tpot.p95 * 1e3,
                        r.tpot.p99 * 1e3
                    ),
                    format!("{:.1}", r.request_latency.p99 * 1e3),
                ]);
            }
        }
        t.note("virtual time; bit-identical across reruns for a fixed seed");
        t.note("peak act = peak simultaneously active requests across workers");
        t.print();
    }

    // Batching ablation: the same backlog at batch caps 1/2/4/8/16 —
    // the continuous-batching throughput lever in one table.
    let mut ab = Table::new(
        "batch-cap ablation: opt-1.3b, 1 worker, backlogged arrivals",
        &["batch cap", "tok/s", "makespan s", "TPOT p95 ms"],
    );
    let wl = Workload {
        model: "opt-1.3b".into(),
        rate: 100_000.0,
        n_requests: if fast { 32 } else { 128 },
        prompt_len: LenDist::Fixed(8),
        output_len: LenDist::Fixed(64),
        vocab: 512,
        seed: 0xBEEF,
    };
    for cap in [1usize, 2, 4, 8, 16] {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = cap;
        let r = run_virtual(&wl, &vc).expect("virtual run");
        ab.row(&[
            cap.to_string(),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.3}", r.wall_s),
            format!("{:.2}", r.tpot.p95 * 1e3),
        ]);
    }
    ab.note("weights stream once per fused step: tok/s grows with cap, TPOT degrades gently");
    ab.print();

    // ---- KV-policy ablation: Reserve vs Paged at the SAME constrained
    // budget. The budget holds 576 context tokens; every request grows
    // to 256 (prompt 8 + output 248), so worst-case reservation admits
    // ⌊576/256⌋ = 2 concurrent requests while the pager (block = 16
    // tokens, 36 blocks) admits by current context + half-growth
    // headroom and sustains twice the active batch, trimming back via
    // preemption only near the end of concurrent growth. Run on
    // opt-6.7b, whose 4-ms weight stream dominates the per-lane terms,
    // so every extra lane the pager admits converts almost fully into
    // throughput (the batch-mode vecmat economics of the paper).
    let model67 = by_name("opt-6.7b").unwrap();
    let cal67 = StepModel::calibrated(&model67, &device, 1).expect("calibration compiles");
    let kv_tokens = 576u64;
    let ablation_budget = kv_tokens * model67.kv_bytes_per_token();
    let mut kt = Table::new(
        "KV-policy ablation: opt-6.7b, 1 worker, 576-token KV budget, calibrated step costs",
        &[
            "kv policy",
            "req/s",
            "tok/s",
            "peak act",
            "preempt",
            "peak blk",
            "TTFT p99 ms",
            "TPOT p99 ms",
        ],
    );
    let kv_rates: &[f64] = &[50.0, 100_000.0];
    let mut high_rate_reports: Vec<(KvPolicy, VirtualReport)> = Vec::new();
    for kv_policy in [KvPolicy::Reserve, KvPolicy::Paged { block_tokens: 16 }] {
        for &rate in kv_rates {
            let wl = Workload {
                model: "opt-6.7b".into(),
                rate,
                n_requests: if fast { 16 } else { 48 },
                prompt_len: LenDist::Fixed(8),
                output_len: LenDist::Fixed(248),
                vocab: 512,
                seed: 0x5EED,
            };
            let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, cal67);
            vc.max_batch = 16;
            vc.kv_bytes_per_token = model67.kv_bytes_per_token();
            vc.kv_budget_bytes = ablation_budget;
            vc.kv_policy = kv_policy;
            let r = run_virtual(&wl, &vc).expect("virtual run");
            let r2 = run_virtual(&wl, &vc).expect("virtual rerun");
            assert_eq!(r.records, r2.records, "bit-identical rerun ({})", kv_policy.name());
            assert_eq!(r.wall_s, r2.wall_s);
            kt.row(&[
                kv_policy.name().to_string(),
                format!("{rate:.0}"),
                format!("{:.0}", r.tokens_per_s),
                r.max_concurrent.to_string(),
                r.preemptions.to_string(),
                r.peak_kv_blocks.to_string(),
                format!("{:.2}", r.ttft.p99 * 1e3),
                format!("{:.2}", r.tpot.p99 * 1e3),
            ]);
            cells.push(cell_json(
                "kv_ablation",
                SchedulerPolicy::RoundRobin,
                kv_policy,
                1,
                rate,
                wl.n_requests,
                &r,
            ));
            if rate > 1000.0 {
                high_rate_reports.push((kv_policy, r));
            }
        }
    }
    let reserve = &high_rate_reports[0].1;
    let paged = &high_rate_reports[1].1;
    let tok_ratio = paged.tokens_per_s / reserve.tokens_per_s;
    let active_ratio = paged.max_concurrent as f64 / reserve.max_concurrent as f64;
    kt.note(format!(
        "high-rate cell: paged/reserve tok/s = {tok_ratio:.2}x, peak active = {active_ratio:.2}x"
    ));
    kt.note("same budget, same workload, same calibrated step model — only admission differs");
    kt.print();
    // The structural win the paged allocator exists for: at the same
    // budget it must hold a materially deeper batch under backlog.
    assert!(
        active_ratio >= 1.5,
        "paged peak active {} vs reserve {} ({active_ratio:.2}x < 1.5x)",
        paged.max_concurrent,
        reserve.max_concurrent
    );
    assert!(
        tok_ratio >= 1.15,
        "paged tok/s {:.1} vs reserve {:.1} ({tok_ratio:.2}x < 1.15x)",
        paged.tokens_per_s,
        reserve.tokens_per_s
    );

    // ---- chunked-prefill interference ablation: a Poisson stream of
    // short-prompt neighbors with long prompts injected every 6th
    // request (deterministic mix via run_virtual_plan). Single-pass
    // prefill sweeps a 1536-token prompt's KV in ONE fused step, so
    // every co-batched decode lane's inter-token gap absorbs the whole
    // sweep; a 64-token chunk budget bounds the per-step addition,
    // cutting neighbor TPOT p99 by an order of magnitude at the same
    // KV budget, while the long prompt's own TTFT stays within a small
    // factor (chunks ride steps that were running anyway).
    let n_mix = if fast { 36 } else { 96 };
    let long_prompt_tokens = 1536usize;
    let chunk_tokens = 64usize;
    let neighbor_wl = Workload {
        model: "opt-1.3b".into(),
        rate: 100.0,
        n_requests: n_mix,
        prompt_len: LenDist::Fixed(8),
        output_len: LenDist::Fixed(64),
        vocab: 512,
        seed: 0xD0C5,
    };
    let mk_mix = || -> (Vec<(f64, Request)>, Vec<usize>) {
        let mut plan: Vec<(f64, Request)> = neighbor_wl
            .generate()
            .into_iter()
            .map(|(at, req)| (at.as_secs_f64(), req))
            .collect();
        let mut long_ids = Vec::new();
        for (i, (_, req)) in plan.iter_mut().enumerate() {
            if i % 6 == 3 {
                req.prompt = vec![(i % 512) as i64; long_prompt_tokens];
                long_ids.push(i);
            }
        }
        (plan, long_ids)
    };
    let run_mix = |prefill_chunk: usize| -> (VirtualReport, Vec<usize>) {
        let (plan, long_ids) = mk_mix();
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = 8;
        vc.kv_bytes_per_token = model.kv_bytes_per_token();
        vc.kv_budget_bytes = kv_budget; // identical budget in every cell
        vc.prefill_chunk = prefill_chunk;
        let r = run_virtual_plan("opt-1.3b", 512, neighbor_wl.rate, plan, &vc)
            .expect("virtual run");
        (r, long_ids)
    };
    // Neighbor (short-prompt) inter-token gaps and long-prompt TTFTs,
    // from the per-record emission timestamps.
    let class_stats = |r: &VirtualReport, long_ids: &[usize]| -> (Summary, f64) {
        let long_ids: std::collections::HashSet<usize> = long_ids.iter().copied().collect();
        let mut gaps = Vec::new();
        let mut long_ttfts = Vec::new();
        for rec in &r.records {
            if long_ids.contains(&rec.request_id) {
                long_ttfts.push(rec.first_token_s - rec.arrival_s);
            } else {
                for w in rec.token_times.windows(2) {
                    gaps.push(w[1] - w[0]);
                }
            }
        }
        let ttft_mean = long_ttfts.iter().sum::<f64>() / long_ttfts.len().max(1) as f64;
        (Summary::of(&gaps), ttft_mean)
    };
    let mut pt = Table::new(
        "chunked-prefill interference: opt-1.3b, 1 worker, long prompts (1536 tok) \
         every 6th request among short neighbors"
            .to_string(),
        &[
            "prefill",
            "tok/s",
            "neighbor TPOT p50/p99 ms",
            "long TTFT mean ms",
            "wall s",
        ],
    );
    let mut interference: Vec<(usize, VirtualReport, Summary, f64)> = Vec::new();
    for prefill_chunk in [0usize, chunk_tokens] {
        let (r, long_ids) = run_mix(prefill_chunk);
        let (r2, _) = run_mix(prefill_chunk);
        assert_eq!(r.records, r2.records, "bit-identical rerun (chunk {prefill_chunk})");
        assert_eq!(r.rejected, 0, "the mix must fit the device budget");
        let (gaps, long_ttft) = class_stats(&r, &long_ids);
        let label = if prefill_chunk == 0 {
            "single-pass".to_string()
        } else {
            format!("chunk {prefill_chunk}")
        };
        pt.row(&[
            label,
            format!("{:.0}", r.tokens_per_s),
            format!("{:.2}/{:.2}", gaps.p50 * 1e3, gaps.p99 * 1e3),
            format!("{:.1}", long_ttft * 1e3),
            format!("{:.3}", r.wall_s),
        ]);
        cells.push(obj(vec![
            ("section", "prefill_interference".into()),
            ("prefill_chunk", prefill_chunk.into()),
            ("long_prompt_tokens", long_prompt_tokens.into()),
            ("n_requests", n_mix.into()),
            ("n_long", long_ids.len().into()),
            ("tok_s", r.tokens_per_s.into()),
            ("neighbor_tpot_p50_ms", (gaps.p50 * 1e3).into()),
            ("neighbor_tpot_p99_ms", (gaps.p99 * 1e3).into()),
            ("long_ttft_mean_ms", (long_ttft * 1e3).into()),
            ("wall_s", r.wall_s.into()),
        ]));
        interference.push((prefill_chunk, r, gaps, long_ttft));
    }
    let (_, single_r, single_gaps, single_ttft) = &interference[0];
    let (_, chunked_r, chunked_gaps, chunked_ttft) = &interference[1];
    // Chunking must not change a single token, only timing.
    for (a, b) in single_r.records.iter().zip(&chunked_r.records) {
        assert_eq!(a.tokens, b.tokens, "prefill chunking changed a stream");
    }
    let tpot_ratio = single_gaps.p99 / chunked_gaps.p99;
    let ttft_ratio = chunked_ttft / single_ttft;
    pt.note(format!(
        "chunking cuts neighbor TPOT p99 {tpot_ratio:.1}x; long-prompt TTFT ratio \
         {ttft_ratio:.2}x (chunked/single-pass)"
    ));
    pt.note("same KV budget and workload in both rows — only prefill_chunk differs");
    pt.print();
    // The tentpole acceptance: chunked prefill strictly cuts neighbor
    // TPOT p99 at equal KV budget, without blowing up the long
    // prompt's TTFT.
    assert!(
        chunked_gaps.p99 < single_gaps.p99,
        "chunked neighbor TPOT p99 {:.3} ms must be strictly below single-pass {:.3} ms",
        chunked_gaps.p99 * 1e3,
        single_gaps.p99 * 1e3
    );
    assert!(
        *chunked_ttft < single_ttft * 3.0,
        "chunked long-prompt TTFT {:.1} ms vs single-pass {:.1} ms exceeds the 3x bound",
        chunked_ttft * 1e3,
        single_ttft * 1e3
    );

    // ---- shared-prefix (prefix cache) cell: one cold 512-token
    // prompt, then 7 requests with the identical prompt arriving after
    // the cold prefill completed and registered its blocks. With
    // `--prefix-cache on` the 7 share ONE physical copy of the prefix
    // (refcounted CoW pages) and skip 511 tokens of prefill each, so
    // physical peak KV blocks collapse and cache-hit TTFT drops to the
    // cost of a 1-token span — at the same budget, with bit-identical
    // streams. This cell runs in smoke mode too (it is cheap and the
    // assertions below are the tentpole acceptance).
    let prefix_tokens = 512usize;
    let n_share = 8usize;
    let share_out = 48usize;
    let shared_prompt: Vec<i64> = (0..prefix_tokens).map(|i| ((i * 13) % 512) as i64).collect();
    let mk_share_plan = || -> Vec<(f64, Request)> {
        let mut plan = vec![(0.0, Request::greedy("opt-1.3b", shared_prompt.clone(), share_out))];
        for _ in 1..n_share {
            plan.push((1.0, Request::greedy("opt-1.3b", shared_prompt.clone(), share_out)));
        }
        plan
    };
    // 300 blocks of 16 tokens: enough that the no-sharing cell holds
    // all 7 simultaneous arrivals without preemption — the comparison
    // is pure block accounting at an EQUAL budget.
    let share_budget_blocks = 300u64;
    let share_budget = share_budget_blocks * 16 * model.kv_bytes_per_token();
    let run_share = |cache: PrefixCacheConfig| -> VirtualReport {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = 8;
        vc.kv_bytes_per_token = model.kv_bytes_per_token();
        vc.kv_budget_bytes = share_budget;
        vc.kv_policy = KvPolicy::Paged { block_tokens: 16 };
        vc.prefix_cache = cache;
        run_virtual_plan("opt-1.3b", 512, 1.0, mk_share_plan(), &vc).expect("virtual run")
    };
    let share_off = run_share(PrefixCacheConfig::off());
    let share_on = run_share(PrefixCacheConfig::on());
    let share_on2 = run_share(PrefixCacheConfig::on());
    assert_eq!(share_on.records, share_on2.records, "bit-identical rerun (prefix cache)");
    assert_eq!(share_off.rejected + share_on.rejected, 0, "the cell must fit the budget");
    // Streams bit-identical with the cache on vs off (virtual path).
    for (a, b) in share_off.records.iter().zip(&share_on.records) {
        assert_eq!(a.tokens, b.tokens, "prefix cache changed stream {}", a.request_id);
    }
    let ttft_of = |r: &VirtualReport, i: usize| -> f64 {
        r.records[i].first_token_s - r.records[i].arrival_s
    };
    let cold_ttft = ttft_of(&share_on, 0);
    let hit_ttft_mean = (1..n_share).map(|i| ttft_of(&share_on, i)).sum::<f64>()
        / (n_share - 1) as f64;
    let mut st = Table::new(
        format!(
            "shared-prefix cache: opt-1.3b, 1 worker, {n_share}-way shared \
             {prefix_tokens}-token prefix, {share_budget_blocks}-block budget"
        ),
        &["prefix cache", "peak blk", "hit tokens", "shared blk", "CoW", "TTFT cold/hit ms"],
    );
    for (label, r) in [("off", &share_off), ("on", &share_on)] {
        let hit_mean = (1..n_share).map(|i| ttft_of(r, i)).sum::<f64>() / (n_share - 1) as f64;
        st.row(&[
            label.to_string(),
            r.peak_kv_blocks.to_string(),
            r.prefix_hit_tokens.to_string(),
            r.shared_blocks.to_string(),
            r.cow_splits.to_string(),
            format!("{:.2}/{:.2}", ttft_of(r, 0) * 1e3, hit_mean * 1e3),
        ]);
        cells.push(obj(vec![
            ("section", "prefix_cache".into()),
            ("prefix_cache", label.into()),
            ("prefix_tokens", prefix_tokens.into()),
            ("n_requests", n_share.into()),
            ("budget_blocks", share_budget_blocks.into()),
            ("peak_kv_blocks", r.peak_kv_blocks.into()),
            ("prefix_hit_tokens", r.prefix_hit_tokens.into()),
            ("shared_blocks", r.shared_blocks.into()),
            ("cow_splits", r.cow_splits.into()),
            ("cold_ttft_ms", (ttft_of(r, 0) * 1e3).into()),
            ("hit_ttft_mean_ms", (hit_mean * 1e3).into()),
            ("tok_s", r.tokens_per_s.into()),
            ("wall_s", r.wall_s.into()),
        ]));
    }
    let block_ratio = share_off.peak_kv_blocks as f64 / share_on.peak_kv_blocks.max(1) as f64;
    let share_ttft_ratio = cold_ttft / hit_ttft_mean.max(1e-12);
    st.note(format!(
        "sharing holds one physical prefix copy: peak blocks {block_ratio:.1}x lower, \
         cache-hit TTFT {share_ttft_ratio:.1}x below cold"
    ));
    st.note("same budget, same arrivals, bit-identical streams — only the prefix cache differs");
    st.print();
    // The tentpole acceptance (ISSUE 4): physical peak strictly below
    // no-sharing at equal budget; cache-hit TTFT strictly below cold.
    assert!(
        share_on.peak_kv_blocks < share_off.peak_kv_blocks,
        "sharing peak {} !< no-sharing peak {}",
        share_on.peak_kv_blocks,
        share_off.peak_kv_blocks
    );
    assert!(
        hit_ttft_mean < cold_ttft,
        "cache-hit TTFT mean {hit_ttft_mean} !< cold TTFT {cold_ttft}"
    );
    assert_eq!(share_off.prefix_hit_tokens, 0);
    assert_eq!(share_on.prefix_hit_tokens, ((n_share - 1) * (prefix_tokens - 1)) as u64);
    assert_eq!(share_on.cow_splits, (n_share - 1) as u64);

    // Threaded half of the stream-identity acceptance: the live
    // coordinator (real threads, sim backend) must also stream
    // bit-identically with the cache on vs off, and actually hit.
    let run_threaded = |cache: PrefixCacheConfig| -> (Vec<Vec<i64>>, u64) {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 16,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            kv_budget_bytes: share_budget,
            kv_policy: KvPolicy::Paged { block_tokens: 16 },
            prefix_cache: cache,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-1.3b", 1, BackendFactory::sim("opt-1.3b", 512));
        let mut streams = vec![c
            .submit(Request::greedy("opt-1.3b", shared_prompt.clone(), share_out))
            .expect("submit")
            .wait()
            .expect("cold request")];
        let handles: Vec<_> = (1..n_share)
            .map(|_| {
                c.submit(Request::greedy("opt-1.3b", shared_prompt.clone(), share_out))
                    .expect("submit")
            })
            .collect();
        streams.extend(handles.into_iter().map(|h| h.wait().expect("hit request")));
        let hits = c.metrics.snapshot().prefix_hit_tokens;
        c.shutdown();
        (streams, hits)
    };
    let (threaded_off, off_hits) = run_threaded(PrefixCacheConfig::off());
    let (threaded_on, on_hits) = run_threaded(PrefixCacheConfig::on());
    assert_eq!(threaded_on, threaded_off, "threaded streams changed by the prefix cache");
    assert_eq!(off_hits, 0);
    assert_eq!(on_hits, ((n_share - 1) * (prefix_tokens - 1)) as u64);
    // And the two paths agree with each other (lane-core invariant).
    for (i, rec) in share_on.records.iter().enumerate() {
        assert_eq!(rec.tokens, threaded_on[i], "virtual/threaded divergence on stream {i}");
    }

    // ---- router cell: affinity-aware routing over a 4-worker pool.
    // 8 clients share a 512-token prefix (distinct one-token tails so
    // streams differ per client): one cold at t=0, seven arriving after
    // its prefill registered. Every cell runs the SAME paged budget and
    // prefix cache — only the routing policy differs. `round-robin`
    // spreads the repeats across workers, so most re-prefill a prefix
    // that is physically resident one worker over; `prefix-affinity`
    // steers all seven to the worker holding the blocks, so they skip
    // 512 tokens of prefill each. Runs in smoke mode too (cheap; the
    // assertions below are the tentpole acceptance).
    let n_route_workers = 4usize;
    let route_out = 32usize;
    let route_prefix: Vec<i64> =
        (0..prefix_tokens).map(|i| ((i * 11 + 5) % 512) as i64).collect();
    let mk_route_plan = || -> Vec<(f64, Request)> {
        (0..n_share)
            .map(|i| {
                let mut prompt = route_prefix.clone();
                prompt.push(i as i64); // distinct tail per client
                let at = if i == 0 { 0.0 } else { 1.0 };
                (at, Request::greedy("opt-1.3b", prompt, route_out))
            })
            .collect()
    };
    let run_route = |router: RouterPolicy| -> VirtualReport {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, n_route_workers, 16, step);
        vc.max_batch = 8;
        vc.kv_bytes_per_token = model.kv_bytes_per_token();
        vc.kv_budget_bytes = share_budget; // equal per-worker budget in every cell
        vc.kv_policy = KvPolicy::Paged { block_tokens: 16 };
        vc.prefix_cache = PrefixCacheConfig::on();
        vc.router = router;
        run_virtual_plan("opt-1.3b", 512, 1.0, mk_route_plan(), &vc).expect("virtual run")
    };
    let mean_ttft_s = |r: &VirtualReport| -> f64 {
        r.records.iter().map(|rec| rec.first_token_s - rec.arrival_s).sum::<f64>()
            / r.records.len().max(1) as f64
    };
    let mut rt = Table::new(
        format!(
            "router: opt-1.3b, {n_route_workers} workers, {n_share} clients sharing a \
             {prefix_tokens}-token prefix, {share_budget_blocks}-block budget each"
        ),
        &["router", "hit tokens", "shared blk", "mean TTFT ms", "peak queue", "peak lanes/worker"],
    );
    let mut route_reports: Vec<(RouterPolicy, VirtualReport)> = Vec::new();
    for router in RouterPolicy::all() {
        let r = run_route(router);
        let r2 = run_route(router);
        assert_eq!(r.records, r2.records, "bit-identical rerun ({})", router.name());
        assert_eq!(r.wall_s, r2.wall_s);
        assert_eq!(r.rejected, 0, "the router cell must fit the budget");
        rt.row(&[
            router.name().to_string(),
            r.prefix_hit_tokens.to_string(),
            r.shared_blocks.to_string(),
            format!("{:.2}", mean_ttft_s(&r) * 1e3),
            r.peak_queue_depth.to_string(),
            format!("{:?}", r.worker_peak_lanes),
        ]);
        cells.push(obj(vec![
            ("section", "router".into()),
            ("router_policy", router.name().into()),
            ("workers", n_route_workers.into()),
            ("n_requests", n_share.into()),
            ("prefix_tokens", prefix_tokens.into()),
            ("budget_blocks", share_budget_blocks.into()),
            ("prefix_hit_tokens", r.prefix_hit_tokens.into()),
            ("shared_blocks", r.shared_blocks.into()),
            ("mean_ttft_ms", (mean_ttft_s(&r) * 1e3).into()),
            ("peak_queue_depth", r.peak_queue_depth.into()),
            (
                "worker_peak_lanes",
                Json::Arr(r.worker_peak_lanes.iter().map(|&l| l.into()).collect()),
            ),
            ("tok_s", r.tokens_per_s.into()),
            ("wall_s", r.wall_s.into()),
        ]));
        route_reports.push((router, r));
    }
    let rr_route = &route_reports[0].1;
    let ll_route = &route_reports[1].1;
    let aff_route = &route_reports[2].1;
    // Routing changes placement and latency only: streams bit-identical
    // across all three policies.
    for (policy, r) in &route_reports[1..] {
        for (a, b) in rr_route.records.iter().zip(&r.records) {
            assert_eq!(
                a.tokens,
                b.tokens,
                "{} changed routed stream {}",
                policy.name(),
                a.request_id
            );
        }
    }
    let route_ttft_ratio = mean_ttft_s(rr_route) / mean_ttft_s(aff_route).max(1e-12);
    rt.note(format!(
        "prefix-affinity steers repeats to the cached worker: {}x the round-robin hit \
         tokens, mean TTFT {route_ttft_ratio:.1}x lower",
        if rr_route.prefix_hit_tokens > 0 {
            (aff_route.prefix_hit_tokens / rr_route.prefix_hit_tokens).to_string()
        } else {
            "inf".to_string()
        }
    ));
    rt.note("same budget, same arrivals, bit-identical streams — only the router differs");
    rt.print();
    // The tentpole acceptance (ISSUE 5): strictly more prefix hits AND
    // strictly lower mean TTFT than round-robin at equal KV budget.
    assert!(
        aff_route.prefix_hit_tokens > rr_route.prefix_hit_tokens,
        "affinity hit tokens {} !> round-robin {}",
        aff_route.prefix_hit_tokens,
        rr_route.prefix_hit_tokens
    );
    assert!(
        mean_ttft_s(aff_route) < mean_ttft_s(rr_route),
        "affinity mean TTFT {} !< round-robin {}",
        mean_ttft_s(aff_route),
        mean_ttft_s(rr_route)
    );
    // Exact hit accounting: all 7 repeats hit the full 512-token prefix
    // under affinity; round-robin's rotation hands exactly one repeat
    // back to the cached worker (the others land on cold siblings).
    assert_eq!(aff_route.prefix_hit_tokens, ((n_share - 1) * prefix_tokens) as u64);
    assert_eq!(rr_route.prefix_hit_tokens, prefix_tokens as u64);

    // Threaded half of the routing acceptance: the live coordinator
    // (real threads, 4 workers) streams bit-identically under every
    // routing policy, and agrees with the virtual path.
    let run_threaded_route = |router: RouterPolicy| -> Vec<Vec<i64>> {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 16,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            kv_budget_bytes: share_budget,
            kv_policy: KvPolicy::Paged { block_tokens: 16 },
            prefix_cache: PrefixCacheConfig::on(),
            router,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-1.3b", n_route_workers, BackendFactory::sim("opt-1.3b", 512));
        let mut reqs = mk_route_plan().into_iter().map(|(_, r)| r);
        let cold = reqs.next().expect("cold request");
        let mut streams =
            vec![c.submit(cold).expect("submit").wait().expect("cold request")];
        let handles: Vec<_> = reqs.map(|r| c.submit(r).expect("submit")).collect();
        streams.extend(handles.into_iter().map(|h| h.wait().expect("routed request")));
        c.shutdown();
        streams
    };
    let threaded_routed: Vec<Vec<Vec<i64>>> =
        RouterPolicy::all().iter().map(|&p| run_threaded_route(p)).collect();
    for (i, s) in threaded_routed.iter().enumerate() {
        assert_eq!(
            s, &threaded_routed[0],
            "threaded streams changed by routing policy {}",
            RouterPolicy::all()[i].name()
        );
    }
    for (i, rec) in aff_route.records.iter().enumerate() {
        assert_eq!(
            rec.tokens, threaded_routed[0][i],
            "virtual/threaded divergence on routed stream {i}"
        );
    }

    // ---- host KV tier (swap) cell: long-context requests at an
    // oversubscribed HBM budget. Two 192-token prompts each decode 320
    // tokens on a 48-block (768-token) pager, so concurrent growth must
    // preempt one lane mid-decode. Without the host tier the victim's
    // readmission recomputes its whole context as a fresh prefill
    // span; with the `--kv-host-mb`-style swap the preemption demotes
    // the lane's blocks to host memory and readmission restores them,
    // refeeding a single token. The restore term is set well below the
    // recompute terms (fast-link regime) so the cost model lands on
    // restore — the cell isolates the swap mechanics, not the link
    // model. Runs in smoke mode too (cheap; the assertions below are
    // the tentpole acceptance).
    let swap_prompt_tokens = 192usize;
    let swap_out = 320usize;
    let swap_budget_blocks = 48u64;
    let swap_budget = swap_budget_blocks * 16 * model.kv_bytes_per_token();
    let mut swap_step = step;
    swap_step.host_restore_s_per_token = 1e-8;
    let swap_tier = HostTierConfig::from_step(&swap_step, 64);
    let mk_swap_plan = || -> Vec<(f64, Request)> {
        (0..2usize)
            .map(|i| {
                let prompt: Vec<i64> = (0..swap_prompt_tokens)
                    .map(|t| ((t * 7 + i * 131) % 512) as i64)
                    .collect();
                (0.0, Request::greedy("opt-1.3b", prompt, swap_out))
            })
            .collect()
    };
    let run_swap = |tier: HostTierConfig| -> VirtualReport {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 2, swap_step);
        vc.max_batch = 8;
        vc.kv_bytes_per_token = model.kv_bytes_per_token();
        vc.kv_budget_bytes = swap_budget;
        vc.kv_policy = KvPolicy::Paged { block_tokens: 16 };
        vc.host_tier = tier;
        run_virtual_plan("opt-1.3b", 512, 1.0, mk_swap_plan(), &vc).expect("virtual run")
    };
    let swap_off = run_swap(HostTierConfig::off());
    let swap_on = run_swap(swap_tier);
    let swap_on2 = run_swap(swap_tier);
    assert_eq!(swap_on.records, swap_on2.records, "bit-identical rerun (host tier)");
    assert_eq!(swap_on.wall_s, swap_on2.wall_s);
    assert_eq!(swap_off.rejected + swap_on.rejected, 0, "the cell must fit the budget");
    assert!(swap_off.preemptions > 0, "the cell must oversubscribe enough to preempt");
    assert!(swap_on.preemptions > 0);
    assert_eq!(swap_off.restored_blocks, 0);
    assert_eq!(swap_off.demoted_blocks, 0);
    assert!(swap_on.demoted_blocks > 0, "preemption must demote to the host pool");
    assert!(swap_on.restored_blocks > 0, "readmission must restore from the host pool");
    // Streams bit-identical with the tier on vs off (virtual path).
    for (a, b) in swap_off.records.iter().zip(&swap_on.records) {
        assert_eq!(a.tokens, b.tokens, "host tier changed stream {}", a.request_id);
    }
    // Resume-after-preemption TTFT: the victim's largest inter-token
    // gap (queue wait + refeed step). The wait is identical on both
    // sides, so the delta is exactly restore-vs-recompute.
    let resume_gap = |r: &VirtualReport| -> f64 {
        r.records
            .iter()
            .flat_map(|rec| rec.token_times.windows(2).map(|w| w[1] - w[0]))
            .fold(0.0_f64, f64::max)
    };
    let gap_off = resume_gap(&swap_off);
    let gap_on = resume_gap(&swap_on);
    let mut ht = Table::new(
        format!(
            "host KV tier: opt-1.3b, 1 worker, 2x {swap_prompt_tokens}-token prompts \
             decoding {swap_out} tokens on a {swap_budget_blocks}-block budget"
        ),
        &["host tier", "preempt", "demoted blk", "restored blk", "resume gap ms", "wall s"],
    );
    for (label, r) in [("off", &swap_off), ("on", &swap_on)] {
        ht.row(&[
            label.to_string(),
            r.preemptions.to_string(),
            r.demoted_blocks.to_string(),
            r.restored_blocks.to_string(),
            format!("{:.3}", resume_gap(r) * 1e3),
            format!("{:.4}", r.wall_s),
        ]);
        cells.push(obj(vec![
            ("section", "kv_tier".into()),
            ("host_tier", label.into()),
            ("prompt_tokens", swap_prompt_tokens.into()),
            ("output_tokens", swap_out.into()),
            ("budget_blocks", swap_budget_blocks.into()),
            ("host_capacity_blocks", r.host_capacity_blocks.into()),
            ("preemptions", r.preemptions.into()),
            ("demoted_blocks", r.demoted_blocks.into()),
            ("restored_blocks", r.restored_blocks.into()),
            ("restored_tokens", r.restored_tokens.into()),
            ("resume_gap_ms", (resume_gap(r) * 1e3).into()),
            ("tok_s", r.tokens_per_s.into()),
            ("wall_s", r.wall_s.into()),
        ]));
    }
    let swap_gap_ratio = gap_off / gap_on.max(1e-12);
    ht.note(format!(
        "restore refeeds one token instead of the whole context: resume gap \
         {swap_gap_ratio:.2}x lower, wall {:.4}s vs {:.4}s",
        swap_on.wall_s, swap_off.wall_s
    ));
    ht.note("same budget, same arrivals, bit-identical streams — only the host tier differs");
    ht.print();
    // The tentpole acceptance (ISSUE 6): resume-after-preemption TTFT
    // with host restore strictly below recompute, at less total wall.
    assert!(
        gap_on < gap_off,
        "restore resume gap {:.4} ms !< recompute resume gap {:.4} ms",
        gap_on * 1e3,
        gap_off * 1e3
    );
    assert!(
        swap_on.wall_s < swap_off.wall_s,
        "host-tier wall {:.4}s !< recompute wall {:.4}s",
        swap_on.wall_s,
        swap_off.wall_s
    );

    // Threaded half of the swap acceptance: the live coordinator (real
    // threads, sim backend) demotes and restores under the same
    // oversubscribed budget and streams bit-identically tier on vs off.
    let run_threaded_swap =
        |tier: HostTierConfig, factory: BackendFactory| -> (Vec<Vec<i64>>, u64, u64, u64) {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 2,
                policy: SchedulerPolicy::RoundRobin,
                kv_bytes_per_token: model.kv_bytes_per_token(),
                kv_budget_bytes: swap_budget,
                kv_policy: KvPolicy::Paged { block_tokens: 16 },
                host_tier: tier,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-1.3b", 1, factory);
            let handles: Vec<_> = mk_swap_plan()
                .into_iter()
                .map(|(_, r)| c.submit(r).expect("submit"))
                .collect();
            let streams: Vec<Vec<i64>> =
                handles.into_iter().map(|h| h.wait().expect("swap request")).collect();
            let s = c.metrics.snapshot();
            c.shutdown();
            (streams, s.preemptions, s.kv_demoted_blocks, s.kv_restored_blocks)
        };
    let (t_off, t_off_preempt, t_off_demoted, _) =
        run_threaded_swap(HostTierConfig::off(), BackendFactory::sim("opt-1.3b", 512));
    let (t_on, t_on_preempt, t_on_demoted, t_on_restored) =
        run_threaded_swap(swap_tier, BackendFactory::sim("opt-1.3b", 512));
    assert_eq!(t_on, t_off, "threaded streams changed by the host tier");
    assert!(t_off_preempt > 0 && t_on_preempt > 0, "threaded swap cell must preempt");
    assert_eq!(t_off_demoted, 0);
    assert!(t_on_demoted > 0 && t_on_restored > 0, "threaded readmission must restore");
    // And the two paths agree with each other (lane-core invariant).
    for (i, rec) in swap_on.records.iter().enumerate() {
        assert_eq!(rec.tokens, t_on[i], "virtual/threaded divergence on swap stream {i}");
    }
    // Self-disable: a backend without session restore serves the same
    // streams with the tier configured on, claiming zero demotions.
    let (t_nores, _, nores_demoted, nores_restored) =
        run_threaded_swap(swap_tier, BackendFactory::sim_no_restore("opt-1.3b", 512));
    assert_eq!(t_nores, t_on, "self-disabled tier changed threaded streams");
    assert_eq!(
        (nores_demoted, nores_restored),
        (0, 0),
        "tier must self-disable without session-restore support"
    );

    // ---- fault-recovery cell: kill worker 0 mid-run under a combined
    // transient + crash plan (`--fault-plan`-style spec) on a paged
    // 2-worker pool. Acceptance: 100% of requests still complete, the
    // end-of-run pager is fully free (no leaked KV blocks), every
    // stream is bit-identical to the fault-free run on BOTH the virtual
    // and threaded paths, and the same seed reproduces the identical
    // recovery decisions (failover targets, restore/recompute split,
    // retry counts) across reruns. Runs in smoke mode too (cheap; the
    // assertions below are the tentpole acceptance).
    let n_fault = if fast { 10 } else { 24 };
    let fault_out = 48usize;
    let fault_budget_blocks = 48u64;
    let fault_budget = fault_budget_blocks * 16 * model.kv_bytes_per_token();
    let fault_spec = "seed=7,transient=0.01,retries=1000000,backoff=0.000001,crash=0@8";
    let mk_fault_plan = || -> Vec<(f64, Request)> {
        (0..n_fault)
            .map(|i| {
                let plen = 8 + (i * 5) % 24;
                let prompt: Vec<i64> =
                    (0..plen).map(|t| ((t * 17 + i * 37) % 512) as i64).collect();
                (0.002 * i as f64, Request::greedy("opt-1.3b", prompt, fault_out))
            })
            .collect()
    };
    let run_fault = |fp: FaultPlan| -> VirtualReport {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 2, 16, step);
        vc.max_batch = 8;
        vc.kv_bytes_per_token = model.kv_bytes_per_token();
        vc.kv_budget_bytes = fault_budget;
        vc.kv_policy = KvPolicy::Paged { block_tokens: 16 };
        vc.faults = fp;
        run_virtual_plan("opt-1.3b", 512, 1.0, mk_fault_plan(), &vc).expect("virtual run")
    };
    let fault_clean = run_fault(FaultPlan::default());
    let fault_on = run_fault(FaultPlan::parse(fault_spec).expect("fault spec"));
    let fault_on2 = run_fault(FaultPlan::parse(fault_spec).expect("fault spec"));
    assert_eq!((fault_clean.worker_crashes, fault_clean.failed), (0, 0));
    assert_eq!(fault_on.worker_crashes, 1, "the crash must fire");
    assert!(fault_on.failovers >= 1, "the crash must salvage at least one lane");
    assert_eq!(
        fault_on.failovers,
        fault_on.lanes_restored_on_failover + fault_on.lanes_recomputed_on_failover,
        "every salvaged lane is either restored or recomputed"
    );
    // 100% completion despite the dead worker: nothing fails, nothing
    // is rejected, and the pager ends the run fully free on both sides.
    assert_eq!((fault_on.failed, fault_on.rejected), (0, 0));
    assert_eq!(fault_clean.end_kv_blocks_in_use, 0);
    assert_eq!(fault_on.end_kv_blocks_in_use, 0, "the crash leaked KV blocks");
    // Faults move *when*, never *which*: streams bit-identical to the
    // fault-free run.
    for (a, b) in fault_clean.records.iter().zip(&fault_on.records) {
        assert_eq!(a.tokens, b.tokens, "faults changed stream {}", a.request_id);
        assert_eq!(a.tokens.len(), fault_out);
    }
    // Same seed → identical recovery decisions across reruns.
    assert_eq!(fault_on.records, fault_on2.records, "bit-identical rerun (faults)");
    assert_eq!(fault_on.wall_s, fault_on2.wall_s);
    assert_eq!(
        (fault_on.failovers, fault_on.lanes_restored_on_failover, fault_on.retries),
        (fault_on2.failovers, fault_on2.lanes_restored_on_failover, fault_on2.retries),
        "recovery decisions not reproducible"
    );
    let mut ft = Table::new(
        format!(
            "fault recovery: opt-1.3b, 2 workers, {n_fault} requests, worker 0 killed at \
             step 8 + 1% transient faults ({fault_budget_blocks}-block budget each)"
        ),
        &["fault plan", "crashes", "failovers", "restored/recomputed", "retries", "wall s"],
    );
    for (label, r) in [("off", &fault_clean), ("on", &fault_on)] {
        ft.row(&[
            label.to_string(),
            r.worker_crashes.to_string(),
            r.failovers.to_string(),
            format!("{}/{}", r.lanes_restored_on_failover, r.lanes_recomputed_on_failover),
            r.retries.to_string(),
            format!("{:.4}", r.wall_s),
        ]);
        cells.push(obj(vec![
            ("section", "fault_recovery".into()),
            ("fault_plan", if label == "on" { fault_spec.into() } else { "off".into() }),
            ("workers", 2.into()),
            ("n_requests", n_fault.into()),
            ("completed", (n_fault - r.failed).into()),
            ("worker_crashes", r.worker_crashes.into()),
            ("failovers", r.failovers.into()),
            ("lanes_restored_on_failover", r.lanes_restored_on_failover.into()),
            ("lanes_recomputed_on_failover", r.lanes_recomputed_on_failover.into()),
            ("faults_injected", r.faults_injected.into()),
            ("retries", r.retries.into()),
            ("end_kv_blocks_in_use", r.end_kv_blocks_in_use.into()),
            ("tok_s", r.tokens_per_s.into()),
            ("wall_s", r.wall_s.into()),
        ]));
    }
    ft.note("all requests complete, streams bit-identical fault-on vs off, pager ends free");
    ft.note("same seed reproduces the identical failover and restore/recompute decisions");
    ft.print();

    // Threaded half of the fault acceptance: the live coordinator under
    // the same plan completes every request with the same streams as
    // its own fault-free run, counts exactly one crash, and leaks
    // nothing (errors stay zero).
    let run_threaded_fault = |fp: FaultPlan| {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 16,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            kv_budget_bytes: fault_budget,
            kv_policy: KvPolicy::Paged { block_tokens: 16 },
            faults: fp,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-1.3b", 2, BackendFactory::sim("opt-1.3b", 512));
        let handles: Vec<_> = mk_fault_plan()
            .into_iter()
            .map(|(_, r)| c.submit(r).expect("submit"))
            .collect();
        let streams: Vec<Vec<i64>> =
            handles.into_iter().map(|h| h.wait().expect("fault request")).collect();
        let s = c.metrics.snapshot();
        c.shutdown();
        (streams, s)
    };
    let (tf_clean, tf_clean_snap) = run_threaded_fault(FaultPlan::default());
    let (tf_on, tf_snap) = run_threaded_fault(FaultPlan::parse(fault_spec).expect("fault spec"));
    assert_eq!(tf_clean_snap.worker_crashes, 0);
    assert_eq!(tf_on, tf_clean, "threaded streams changed by the fault plan");
    assert_eq!(tf_snap.worker_crashes, 1);
    assert_eq!(tf_snap.errors, 0, "no request may fail under failover + retry");
    assert_eq!(tf_snap.completed, n_fault as u64);
    assert!(tf_snap.failovers >= 1);
    assert_eq!(
        tf_snap.failovers,
        tf_snap.lanes_restored_on_failover + tf_snap.lanes_recomputed_on_failover
    );
    // And the two paths agree with each other (lane-core invariant).
    for (i, rec) in fault_on.records.iter().enumerate() {
        assert_eq!(rec.tokens, tf_on[i], "virtual/threaded divergence on fault stream {i}");
    }

    // ---- tracing overhead cell: the lifecycle recorder must be a pure
    // observer, and a cheap one. Same (seed, config) with tracing on vs
    // off: token streams bit-identical, the virtual clock unchanged,
    // every request's timeline captured — and host-side compute within
    // the 1.05x budget (best-of-5 wall measurements; the one
    // intentionally machine-dependent number in this bench, gated
    // rather than tabulated bit-for-bit).
    let trace_wl = Workload {
        model: "opt-1.3b".into(),
        rate: 2000.0,
        n_requests: if fast { 150 } else { 400 },
        prompt_len: LenDist::Uniform(4, 32),
        output_len: LenDist::LongTail { min: 8, mean_extra: 48.0, cap: 128 },
        vocab: 512,
        seed: 0x7ACE5,
    };
    let trace_vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 2, 16, step);
    let mut traced_vc = trace_vc.clone();
    traced_vc.trace = true;
    let time_best = |vc: &VirtualConfig| {
        let mut best = f64::INFINITY;
        let mut out = None;
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            let r = run_virtual(&trace_wl, vc).expect("trace overhead run");
            best = best.min(t0.elapsed().as_secs_f64());
            out = Some(r);
        }
        (out.expect("five timed runs"), best)
    };
    let (trace_off, wall_off) = time_best(&trace_vc);
    let (trace_on, wall_on) = time_best(&traced_vc);
    assert_eq!(trace_off.records.len(), trace_on.records.len());
    for (a, b) in trace_off.records.iter().zip(&trace_on.records) {
        assert_eq!(a.tokens, b.tokens, "tracing changed a token stream");
    }
    assert_eq!(
        trace_off.wall_s.to_bits(),
        trace_on.wall_s.to_bits(),
        "tracing moved the virtual clock"
    );
    assert!(trace_off.timelines.is_empty(), "untraced run must record nothing");
    assert_eq!(trace_on.timelines.len(), trace_on.records.len());
    assert!(trace_on.attribution.is_some(), "traced run must attribute latency");
    let trace_ratio = wall_on / wall_off.max(1e-9);
    // Sub-millisecond walls make the ratio meaningless noise; the
    // absolute guard keeps the gate honest without flaking there.
    assert!(
        trace_ratio <= 1.05 || wall_on - wall_off <= 2e-3,
        "tracing overhead {trace_ratio:.3}x exceeds the 1.05x budget \
         ({wall_on:.4}s on vs {wall_off:.4}s off)"
    );
    let mut tt = Table::new(
        "tracing overhead: 2-worker sweep cell with the span recorder on".to_string(),
        &["variant", "virtual wall s", "timelines", "host wall best-of-5 s"],
    );
    tt.row(&[
        "trace off".to_string(),
        format!("{:.4}", trace_off.wall_s),
        "0".to_string(),
        format!("{wall_off:.4}"),
    ]);
    tt.row(&[
        "trace on".to_string(),
        format!("{:.4}", trace_on.wall_s),
        format!("{}", trace_on.timelines.len()),
        format!("{wall_on:.4}"),
    ]);
    tt.note("streams + virtual clock bit-identical on vs off; host walls measured, gated at 1.05x");
    tt.print();

    // ---- machine-readable results ----
    let out_path = std::env::var("LPU_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_serving.json".to_string());
    let doc = obj(vec![
        ("bench", "serving_load".into()),
        ("fast", fast.into()),
        ("model", "opt-1.3b".into()),
        ("device", device.name.clone().into()),
        ("kv_ablation_budget_tokens", kv_tokens.into()),
        (
            "kv_ablation_summary",
            obj(vec![
                ("reserve_tok_s", reserve.tokens_per_s.into()),
                ("paged_tok_s", paged.tokens_per_s.into()),
                ("tok_s_ratio", tok_ratio.into()),
                ("reserve_peak_active", reserve.max_concurrent.into()),
                ("paged_peak_active", paged.max_concurrent.into()),
                ("peak_active_ratio", active_ratio.into()),
                ("paged_preemptions", paged.preemptions.into()),
            ]),
        ),
        (
            "prefill_interference_summary",
            obj(vec![
                ("long_prompt_tokens", long_prompt_tokens.into()),
                ("chunk_tokens", chunk_tokens.into()),
                ("single_pass_neighbor_tpot_p99_ms", (single_gaps.p99 * 1e3).into()),
                ("chunked_neighbor_tpot_p99_ms", (chunked_gaps.p99 * 1e3).into()),
                ("neighbor_tpot_p99_ratio", tpot_ratio.into()),
                ("single_pass_long_ttft_mean_ms", (single_ttft * 1e3).into()),
                ("chunked_long_ttft_mean_ms", (chunked_ttft * 1e3).into()),
                ("long_ttft_ratio", ttft_ratio.into()),
            ]),
        ),
        (
            "router_summary",
            obj(vec![
                ("workers", n_route_workers.into()),
                ("n_requests", n_share.into()),
                ("prefix_tokens", prefix_tokens.into()),
                ("budget_blocks", share_budget_blocks.into()),
                ("round_robin_prefix_hit_tokens", rr_route.prefix_hit_tokens.into()),
                ("least_loaded_prefix_hit_tokens", ll_route.prefix_hit_tokens.into()),
                ("affinity_prefix_hit_tokens", aff_route.prefix_hit_tokens.into()),
                ("round_robin_mean_ttft_ms", (mean_ttft_s(rr_route) * 1e3).into()),
                ("least_loaded_mean_ttft_ms", (mean_ttft_s(ll_route) * 1e3).into()),
                ("affinity_mean_ttft_ms", (mean_ttft_s(aff_route) * 1e3).into()),
                ("rr_over_affinity_ttft_ratio", route_ttft_ratio.into()),
                ("affinity_peak_queue_depth", aff_route.peak_queue_depth.into()),
            ]),
        ),
        (
            "kv_tier_summary",
            obj(vec![
                ("prompt_tokens", swap_prompt_tokens.into()),
                ("output_tokens", swap_out.into()),
                ("budget_blocks", swap_budget_blocks.into()),
                ("host_capacity_blocks", swap_on.host_capacity_blocks.into()),
                ("preemptions", swap_on.preemptions.into()),
                ("demoted_blocks", swap_on.demoted_blocks.into()),
                ("restored_blocks", swap_on.restored_blocks.into()),
                ("restored_tokens", swap_on.restored_tokens.into()),
                ("recompute_resume_gap_ms", (gap_off * 1e3).into()),
                ("restore_resume_gap_ms", (gap_on * 1e3).into()),
                ("resume_gap_ratio", swap_gap_ratio.into()),
                ("recompute_wall_s", swap_off.wall_s.into()),
                ("restore_wall_s", swap_on.wall_s.into()),
            ]),
        ),
        (
            "fault_recovery_summary",
            obj(vec![
                ("fault_plan", fault_spec.into()),
                ("workers", 2.into()),
                ("n_requests", n_fault.into()),
                ("completed", (n_fault - fault_on.failed).into()),
                ("worker_crashes", fault_on.worker_crashes.into()),
                ("failovers", fault_on.failovers.into()),
                ("lanes_restored_on_failover", fault_on.lanes_restored_on_failover.into()),
                (
                    "lanes_recomputed_on_failover",
                    fault_on.lanes_recomputed_on_failover.into(),
                ),
                ("faults_injected", fault_on.faults_injected.into()),
                ("retries", fault_on.retries.into()),
                ("end_kv_blocks_in_use", fault_on.end_kv_blocks_in_use.into()),
                ("clean_wall_s", fault_clean.wall_s.into()),
                ("faulted_wall_s", fault_on.wall_s.into()),
            ]),
        ),
        (
            "prefix_cache_summary",
            obj(vec![
                ("prefix_tokens", prefix_tokens.into()),
                ("n_requests", n_share.into()),
                ("budget_blocks", share_budget_blocks.into()),
                ("peak_kv_blocks_off", share_off.peak_kv_blocks.into()),
                ("peak_kv_blocks_on", share_on.peak_kv_blocks.into()),
                ("peak_block_ratio", block_ratio.into()),
                ("cold_ttft_ms", (cold_ttft * 1e3).into()),
                ("hit_ttft_mean_ms", (hit_ttft_mean * 1e3).into()),
                ("cold_over_hit_ttft_ratio", share_ttft_ratio.into()),
                ("prefix_hit_tokens", share_on.prefix_hit_tokens.into()),
                ("shared_blocks", share_on.shared_blocks.into()),
                ("cow_splits", share_on.cow_splits.into()),
            ]),
        ),
        (
            "trace_overhead_summary",
            obj(vec![
                ("n_requests", trace_wl.n_requests.into()),
                ("workers", 2.into()),
                ("streams_identical", true.into()),
                ("virtual_wall_s", trace_on.wall_s.into()),
                ("timelines_recorded", trace_on.timelines.len().into()),
                ("wall_off_best_s", wall_off.into()),
                ("wall_on_best_s", wall_on.into()),
                ("overhead_ratio", trace_ratio.into()),
            ]),
        ),
        ("cells", Json::Arr(cells)),
    ]);
    match std::fs::write(&out_path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }
}
