//! Serving load study over the deterministic virtual-time harness:
//! {scheduler policy × offered rate × device/worker count} sweeps with
//! p50/p95/p99 TTFT and TPOT per cell — the paper's Fig. 7 latency
//! regime, now under open-loop Poisson load with continuous batching —
//! plus the **KV-policy ablation**: worst-case reservation
//! (`KvPolicy::Reserve`) vs the paged reserve-as-you-grow allocator
//! (`KvPolicy::Paged`) at the *same* HBM budget, where paging sustains a
//! materially larger active batch and higher tok/s.
//!
//! Every number here is a pure function of (seed, config): rerunning the
//! bench on an unchanged tree prints bit-identical tables, so diffs in
//! review are real regressions, not noise. Results are also written as
//! machine-readable JSON to `../BENCH_serving.json` (override with
//! `LPU_BENCH_JSON=<path>`) so the perf trajectory is tracked in-repo.
//!
//! `LPU_BENCH_FAST=1` shrinks the sweep for CI smoke runs.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_virtual, KvPolicy, LenDist, SchedulerPolicy, StepModel, VirtualConfig, VirtualReport,
    Workload,
};
use lpu::model::by_name;
use lpu::util::json::{obj, Json};
use lpu::util::table::Table;

fn cell_json(
    section: &str,
    sched: SchedulerPolicy,
    kv: KvPolicy,
    workers: usize,
    rate: f64,
    n_requests: usize,
    r: &VirtualReport,
) -> Json {
    obj(vec![
        ("section", section.into()),
        ("sched_policy", sched.name().into()),
        ("kv_policy", kv.name().into()),
        ("workers", workers.into()),
        ("rate_req_s", rate.into()),
        ("n_requests", n_requests.into()),
        ("tok_s", r.tokens_per_s.into()),
        ("peak_active", r.max_concurrent.into()),
        ("preemptions", r.preemptions.into()),
        ("peak_kv_blocks", r.peak_kv_blocks.into()),
        ("kv_capacity_blocks", r.kv_capacity_blocks.into()),
        ("ttft_p99_ms", (r.ttft.p99 * 1e3).into()),
        ("tpot_p99_ms", (r.tpot.p99 * 1e3).into()),
        ("lat_p99_ms", (r.request_latency.p99 * 1e3).into()),
        ("wall_s", r.wall_s.into()),
    ])
}

fn main() {
    let fast = std::env::var("LPU_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests = if fast { 60 } else { 400 };
    let rates: &[f64] = if fast { &[200.0, 2000.0] } else { &[100.0, 400.0, 1600.0, 6400.0] };
    let worker_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4, 8] };

    let model = by_name("opt-1.3b").unwrap();
    let device = LpuConfig::asic_3_28tbs();
    // One model replica per worker: each worker is one LPU device
    // running the 1.3B decode stream, KV-bounded by its own HBM.
    let step = StepModel::from_config(&model, &device, 1);
    let kv_budget = device.hbm.capacity().saturating_sub(model.weight_bytes());
    let mut cells: Vec<Json> = Vec::new();

    // ---- step-cost calibration: first-order bytes/BW vs the cycle
    // simulator (ROADMAP item: StepModel wired to measured
    // cycles-per-token). The KV ablation below runs on the calibrated
    // costs.
    let cal = StepModel::calibrated(&model, &device, 1).expect("calibration compiles");
    let mut ct = Table::new(
        "step-model calibration: opt-1.3b on ".to_string() + &device.name,
        &["model", "step@pos0 ms", "step@pos1024 ms", "kv ns/pos"],
    );
    for (name, m) in [("first-order bytes/BW", &step), ("CoreSim-calibrated", &cal)] {
        ct.row(&[
            name.to_string(),
            format!("{:.4}", m.single_s(0) * 1e3),
            format!("{:.4}", m.single_s(1024) * 1e3),
            format!("{:.2}", m.kv_read_s_per_pos * 1e9),
        ]);
    }
    ct.note("calibrated = linear fit through compiled-program CoreSim runs at two positions");
    ct.print();

    for policy in SchedulerPolicy::all() {
        let mut t = Table::new(
            format!(
                "serving load: opt-1.3b on {} ({} scheduling, max 16 slots, batch cap 8)",
                device.name,
                policy.name()
            ),
            &[
                "workers",
                "req/s",
                "tok/s",
                "peak act",
                "TTFT p50/p95/p99 ms",
                "TPOT p50/p95/p99 ms",
                "lat p99 ms",
            ],
        );
        for &workers in worker_counts {
            for &rate in rates {
                let wl = Workload {
                    model: "opt-1.3b".into(),
                    rate,
                    n_requests,
                    prompt_len: LenDist::Uniform(4, 32),
                    output_len: LenDist::LongTail { min: 8, mean_extra: 48.0, cap: 256 },
                    vocab: 512,
                    seed: 0xA11CE,
                };
                let mut vc = VirtualConfig::new(policy, workers, 16, step);
                vc.max_batch = 8;
                vc.kv_bytes_per_token = model.kv_bytes_per_token();
                vc.kv_budget_bytes = kv_budget;
                let r = run_virtual(&wl, &vc).expect("virtual run");
                assert_eq!(r.records.len(), n_requests, "request conservation");
                cells.push(cell_json(
                    "sched_sweep",
                    policy,
                    KvPolicy::Reserve,
                    workers,
                    rate,
                    n_requests,
                    &r,
                ));
                t.row(&[
                    workers.to_string(),
                    format!("{rate:.0}"),
                    format!("{:.0}", r.tokens_per_s),
                    r.max_concurrent.to_string(),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        r.ttft.p50 * 1e3,
                        r.ttft.p95 * 1e3,
                        r.ttft.p99 * 1e3
                    ),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        r.tpot.p50 * 1e3,
                        r.tpot.p95 * 1e3,
                        r.tpot.p99 * 1e3
                    ),
                    format!("{:.1}", r.request_latency.p99 * 1e3),
                ]);
            }
        }
        t.note("virtual time; bit-identical across reruns for a fixed seed");
        t.note("peak act = peak simultaneously active requests across workers");
        t.print();
    }

    // Batching ablation: the same backlog at batch caps 1/2/4/8/16 —
    // the continuous-batching throughput lever in one table.
    let mut ab = Table::new(
        "batch-cap ablation: opt-1.3b, 1 worker, backlogged arrivals",
        &["batch cap", "tok/s", "makespan s", "TPOT p95 ms"],
    );
    let wl = Workload {
        model: "opt-1.3b".into(),
        rate: 100_000.0,
        n_requests: if fast { 32 } else { 128 },
        prompt_len: LenDist::Fixed(8),
        output_len: LenDist::Fixed(64),
        vocab: 512,
        seed: 0xBEEF,
    };
    for cap in [1usize, 2, 4, 8, 16] {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
        vc.max_batch = cap;
        let r = run_virtual(&wl, &vc).expect("virtual run");
        ab.row(&[
            cap.to_string(),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.3}", r.wall_s),
            format!("{:.2}", r.tpot.p95 * 1e3),
        ]);
    }
    ab.note("weights stream once per fused step: tok/s grows with cap, TPOT degrades gently");
    ab.print();

    // ---- KV-policy ablation: Reserve vs Paged at the SAME constrained
    // budget. The budget holds 576 context tokens; every request grows
    // to 256 (prompt 8 + output 248), so worst-case reservation admits
    // ⌊576/256⌋ = 2 concurrent requests while the pager (block = 16
    // tokens, 36 blocks) admits by current context + half-growth
    // headroom and sustains twice the active batch, trimming back via
    // preemption only near the end of concurrent growth. Run on
    // opt-6.7b, whose 4-ms weight stream dominates the per-lane terms,
    // so every extra lane the pager admits converts almost fully into
    // throughput (the batch-mode vecmat economics of the paper).
    let model67 = by_name("opt-6.7b").unwrap();
    let cal67 = StepModel::calibrated(&model67, &device, 1).expect("calibration compiles");
    let kv_tokens = 576u64;
    let ablation_budget = kv_tokens * model67.kv_bytes_per_token();
    let mut kt = Table::new(
        "KV-policy ablation: opt-6.7b, 1 worker, 576-token KV budget, calibrated step costs",
        &[
            "kv policy",
            "req/s",
            "tok/s",
            "peak act",
            "preempt",
            "peak blk",
            "TTFT p99 ms",
            "TPOT p99 ms",
        ],
    );
    let kv_rates: &[f64] = &[50.0, 100_000.0];
    let mut high_rate_reports: Vec<(KvPolicy, VirtualReport)> = Vec::new();
    for kv_policy in [KvPolicy::Reserve, KvPolicy::Paged { block_tokens: 16 }] {
        for &rate in kv_rates {
            let wl = Workload {
                model: "opt-6.7b".into(),
                rate,
                n_requests: if fast { 16 } else { 48 },
                prompt_len: LenDist::Fixed(8),
                output_len: LenDist::Fixed(248),
                vocab: 512,
                seed: 0x5EED,
            };
            let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, cal67);
            vc.max_batch = 16;
            vc.kv_bytes_per_token = model67.kv_bytes_per_token();
            vc.kv_budget_bytes = ablation_budget;
            vc.kv_policy = kv_policy;
            let r = run_virtual(&wl, &vc).expect("virtual run");
            let r2 = run_virtual(&wl, &vc).expect("virtual rerun");
            assert_eq!(r.records, r2.records, "bit-identical rerun ({})", kv_policy.name());
            assert_eq!(r.wall_s, r2.wall_s);
            kt.row(&[
                kv_policy.name().to_string(),
                format!("{rate:.0}"),
                format!("{:.0}", r.tokens_per_s),
                r.max_concurrent.to_string(),
                r.preemptions.to_string(),
                r.peak_kv_blocks.to_string(),
                format!("{:.2}", r.ttft.p99 * 1e3),
                format!("{:.2}", r.tpot.p99 * 1e3),
            ]);
            cells.push(cell_json(
                "kv_ablation",
                SchedulerPolicy::RoundRobin,
                kv_policy,
                1,
                rate,
                wl.n_requests,
                &r,
            ));
            if rate > 1000.0 {
                high_rate_reports.push((kv_policy, r));
            }
        }
    }
    let reserve = &high_rate_reports[0].1;
    let paged = &high_rate_reports[1].1;
    let tok_ratio = paged.tokens_per_s / reserve.tokens_per_s;
    let active_ratio = paged.max_concurrent as f64 / reserve.max_concurrent as f64;
    kt.note(format!(
        "high-rate cell: paged/reserve tok/s = {tok_ratio:.2}x, peak active = {active_ratio:.2}x"
    ));
    kt.note("same budget, same workload, same calibrated step model — only admission differs");
    kt.print();
    // The structural win the paged allocator exists for: at the same
    // budget it must hold a materially deeper batch under backlog.
    assert!(
        active_ratio >= 1.5,
        "paged peak active {} vs reserve {} ({active_ratio:.2}x < 1.5x)",
        paged.max_concurrent,
        reserve.max_concurrent
    );
    assert!(
        tok_ratio >= 1.15,
        "paged tok/s {:.1} vs reserve {:.1} ({tok_ratio:.2}x < 1.15x)",
        paged.tokens_per_s,
        reserve.tokens_per_s
    );

    // ---- machine-readable results ----
    let out_path = std::env::var("LPU_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_serving.json".to_string());
    let doc = obj(vec![
        ("bench", "serving_load".into()),
        ("fast", fast.into()),
        ("model", "opt-1.3b".into()),
        ("device", device.name.clone().into()),
        ("kv_ablation_budget_tokens", kv_tokens.into()),
        (
            "kv_ablation_summary",
            obj(vec![
                ("reserve_tok_s", reserve.tokens_per_s.into()),
                ("paged_tok_s", paged.tokens_per_s.into()),
                ("tok_s_ratio", tok_ratio.into()),
                ("reserve_peak_active", reserve.max_concurrent.into()),
                ("paged_peak_active", paged.max_concurrent.into()),
                ("peak_active_ratio", active_ratio.into()),
                ("paged_preemptions", paged.preemptions.into()),
            ]),
        ),
        ("cells", Json::Arr(cells)),
    ]);
    match std::fs::write(&out_path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }
}
