//! Figure 7(c) — strong scaling on GPT3-20B, 1–8 devices:
//! LPU + ESL (cycle simulator) vs DGX A100 + FasterTransformer
//! (calibrated analytical model), plus the ESL-overlap ablation.
//!
//! Paper headlines: LPU 5.43× at 8 devices (1.75×/doubling) vs DGX
//! 2.65× (1.38×/doubling).
//!
//! Results are also written as machine-readable JSON to
//! `../BENCH_scaling.json` (override with `LPU_BENCH_SCALING_JSON=
//! <path>`) so the scalability trajectory is tracked in-repo like
//! `BENCH_serving.json`: every number is a pure function of the model/
//! device configs, so a diff in review is a real change. `ci.sh` runs
//! this bench and fails if any `null` survives in the regenerated file.

use lpu::config::LpuConfig;
use lpu::esl::cluster::{scaling_sweep, speedup_per_doubling, ScalingPoint};
use lpu::gpu::{scaling_speedups, GpuConfig};
use lpu::model::by_name;
use lpu::util::json::{obj, Json};
use lpu::util::table::Table;

/// One sweep's rows as JSON cells (devices, ms/token, speedup).
fn points_json(points: &[ScalingPoint], esl_overlap: bool) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                obj(vec![
                    ("devices", p.devices.into()),
                    ("ms_per_token", p.ms_per_token.into()),
                    ("speedup", p.speedup.into()),
                    ("esl_overlap", esl_overlap.into()),
                ])
            })
            .collect(),
    )
}

fn main() {
    let m = by_name("gpt3-20b").unwrap();
    let cfg = LpuConfig::asic_3_28tbs();

    let lpu = scaling_sweep(&m, &cfg, 8, true, 32, 256).unwrap();
    let lpu_blocking = scaling_sweep(&m, &cfg, 8, false, 32, 256).unwrap();
    let dgx = scaling_speedups(&GpuConfig::a100(), &m, 8, 200);
    let paper_lpu = [1.0, 1.75, 3.06, 5.43];
    let paper_dgx = [1.0, 1.45, 1.95, 2.65];

    let mut t = Table::new(
        "Fig 7(c) — strong scaling, GPT3-20B",
        &[
            "devices", "LPU ms/tok", "LPU speedup", "paper", "LPU no-overlap",
            "DGX A100", "paper DGX",
        ],
    );
    for i in 0..lpu.len() {
        t.row(&[
            lpu[i].devices.to_string(),
            format!("{:.2}", lpu[i].ms_per_token),
            format!("{:.2}x", lpu[i].speedup),
            format!("{:.2}x", paper_lpu[i]),
            format!("{:.2}x", lpu_blocking[i].speedup),
            format!("{:.2}x", dgx[i].1),
            format!("{:.2}x", paper_dgx[i]),
        ]);
    }
    t.note(format!(
        "per-doubling: LPU {:.2}x (paper 1.75x), LPU-no-overlap {:.2}x, DGX {:.2}x (paper 1.38x)",
        speedup_per_doubling(&lpu),
        speedup_per_doubling(&lpu_blocking),
        dgx.last().unwrap().1.powf(1.0 / 3.0),
    ));
    t.note("\"LPU achieves 1.75x speedup on average for doubling the number of devices\"");
    t.print();

    // Small-model ring-reconfiguration corollary (Fig 4b motivation).
    let m13 = by_name("opt-1.3b").unwrap();
    let small = scaling_sweep(&m13, &cfg, 8, true, 32, 256).unwrap();
    let mut s = Table::new(
        "Corollary — OPT-1.3B stops scaling (motivates 2/4-rings)",
        &["devices", "ms/token", "speedup"],
    );
    for p in &small {
        s.row(&[p.devices.to_string(), format!("{:.3}", p.ms_per_token), format!("{:.2}x", p.speedup)]);
    }
    s.note("small models saturate on fixed per-token costs; serve them on reconfigured smaller rings instead");
    s.print();

    // ---- machine-readable results (tracked like BENCH_serving.json) ----
    let doc = obj(vec![
        ("bench", "fig7c_scalability".into()),
        ("model", "gpt3-20b".into()),
        ("device", cfg.name.clone().into()),
        (
            "per_doubling",
            obj(vec![
                ("lpu_esl_overlap", speedup_per_doubling(&lpu).into()),
                ("lpu_no_overlap", speedup_per_doubling(&lpu_blocking).into()),
                ("dgx_a100", dgx.last().map(|d| d.1.powf(1.0 / 3.0)).unwrap_or(1.0).into()),
                ("paper_lpu", 1.75.into()),
                ("paper_dgx", 1.38.into()),
            ]),
        ),
        ("lpu_points", points_json(&lpu, true)),
        ("lpu_no_overlap_points", points_json(&lpu_blocking, false)),
        (
            "dgx_points",
            Json::Arr(
                dgx.iter()
                    .map(|&(devices, speedup)| {
                        obj(vec![("devices", devices.into()), ("speedup", speedup.into())])
                    })
                    .collect(),
            ),
        ),
        (
            "small_model_corollary",
            obj(vec![
                ("model", "opt-1.3b".into()),
                ("per_doubling", speedup_per_doubling(&small).into()),
                ("points", points_json(&small, true)),
            ]),
        ),
    ]);
    let out_path = std::env::var("LPU_BENCH_SCALING_JSON")
        .unwrap_or_else(|_| "../BENCH_scaling.json".to_string());
    match std::fs::write(&out_path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nwarning: could not write {out_path}: {e}"),
    }
}
