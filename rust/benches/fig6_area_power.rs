//! Figure 6(a) — LPU ASIC chip layout & specification: per-module
//! area/power breakdown for the three HBM configurations, plus system
//! power, with residuals against the paper's synthesized totals.
//! Includes the vec_dim ablation the paper discusses ("an alternative is
//! to scale down the vector dimension and proportionally scale up the
//! number of MAC trees").

use lpu::config::LpuConfig;
use lpu::power::{chip_estimate, paper, system_power_w};
use lpu::util::table::Table;

fn main() {
    let configs =
        [LpuConfig::asic_819gbs(), LpuConfig::asic_1_64tbs(), LpuConfig::asic_3_28tbs()];

    let mut t = Table::new(
        "Fig 6(a) — chip area/power vs paper synthesis",
        &["config", "MAC trees", "area mm^2", "paper", "Δ%", "power mW", "paper", "Δ%", "system W", "paper"],
    );
    for (cfg, ((trees, p_area, p_power), (stacks, p_sys))) in
        configs.iter().zip(paper::CHIPS.iter().zip(paper::SYSTEMS.iter()))
    {
        assert_eq!(cfg.mac_trees, *trees);
        assert_eq!(cfg.hbm.stacks, *stacks);
        let est = chip_estimate(cfg);
        let area = est.total_area_mm2();
        let power = est.total_power_mw();
        t.row(&[
            cfg.name.clone(),
            trees.to_string(),
            format!("{area:.3}"),
            format!("{p_area:.3}"),
            format!("{:+.1}", (area - p_area) / p_area * 100.0),
            format!("{power:.2}"),
            format!("{p_power:.2}"),
            format!("{:+.1}", (power - p_power) / p_power * 100.0),
            format!("{:.1}", system_power_w(cfg)),
            format!("{p_sys:.0}"),
        ]);
    }
    t.note("model: per-module fixed + per-MAC-tree linear fit (see power/mod.rs)");
    t.print();

    // Per-module breakdown for the flagship config.
    let flagship = LpuConfig::asic_3_28tbs();
    let est = chip_estimate(&flagship);
    let mut b = Table::new(
        "Fig 6(a) — module breakdown (3.28 TB/s, 32 MAC trees)",
        &["module", "area mm^2", "area %", "power mW", "power %"],
    );
    for m in &est.modules {
        b.row(&[
            m.name.to_string(),
            format!("{:.3}", m.area_mm2),
            format!("{:.1}", m.area_mm2 / est.total_area_mm2() * 100.0),
            format!("{:.2}", m.power_mw),
            format!("{:.1}", m.power_mw / est.total_power_mw() * 100.0),
        ]);
    }
    b.note("paper: \"SXE dominates ... followed by SMA and LMU\"");
    b.print();

    // Ablation: vec_dim 32 with doubled MAC trees (paper's alternative).
    let mut alt = flagship.clone();
    alt.name = "lpu-asic-v32-t64 (ablation)".into();
    alt.vec_dim = 32;
    alt.mac_trees = 64;
    let mut ab = Table::new(
        "Ablation — vector dim 64x32 trees vs 32x64 trees",
        &["config", "engine BW TB/s", "est. area mm^2", "VXE latency effect"],
    );
    for (cfg, note) in [(&flagship, "baseline"), (&alt, "halves VXE width, doubles its latency")] {
        ab.row(&[
            cfg.name.clone(),
            format!("{:.2}", cfg.engine_bw() / 1e12),
            format!("{:.3}", chip_estimate(cfg).total_area_mm2()),
            note.to_string(),
        ]);
    }
    ab.note("paper: the v=32 alternative \"would halve the area of VXE at the cost of doubling its latency\"");
    ab.print();
}
