//! Figure 2 — GPU analysis when running LLM inference.
//!
//! (a) H100 bandwidth utilization vs model size (paper: 28.5–28.9% at
//!     OPT-1.3B up to 69.9–70.8% at OPT-30B, 64.9% at 2×66B);
//! (b) H100 power consumption vs model size (paper: 1101 W for 2×66B);
//! (c) DGX A100 strong scaling on GPT3-20B with FasterTransformer
//!     (paper: 1.38× per doubling, 2.65× at 8 GPUs).

use lpu::gpu::{calibration, scaling_speedups, GpuConfig};
use lpu::model::by_name;
use lpu::util::table::Table;

fn main() {
    let h100 = GpuConfig::h100();

    // ---- (a) bandwidth utilization ----
    let mut a = Table::new(
        "Fig 2(a) — H100 bandwidth utilization vs model size",
        &["model", "devices", "modelled util %", "paper util %"],
    );
    let points = [
        ("opt-1.3b", 1usize, Some(28.9)),
        ("opt-2.7b", 1, None),
        ("opt-6.7b", 1, None),
        ("opt-13b", 1, None),
        ("opt-30b", 1, Some(70.8)),
        ("opt-66b", 2, Some(64.9)),
    ];
    for (name, n, paper) in points {
        let m = by_name(name).unwrap();
        let shard = m.decode_stream_bytes() / n as u64;
        let util = h100.utilization(shard) * 0.92f64.powi((n as f64).log2() as i32);
        a.row(&[
            name.to_string(),
            n.to_string(),
            format!("{:.1}", util * 100.0),
            paper.map(|p| format!("{p:.1}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    a.note("paper: \"as low as 28.5% for the smaller OPT 1.3B ... up to 69.9% for OPT 30B\"");
    a.print();

    // ---- (b) power ----
    let mut b = Table::new(
        "Fig 2(b) — GPU power vs model size",
        &["model", "devices", "modelled W", "paper W"],
    );
    for (name, n, paper) in [
        ("opt-1.3b", 1usize, None),
        ("opt-6.7b", 1, None),
        ("opt-30b", 1, None),
        ("opt-66b", 2, Some(calibration::H100_2X_66B_POWER_W)),
    ] {
        let m = by_name(name).unwrap();
        let p = h100.decode_power(&m, n);
        b.row(&[
            name.to_string(),
            n.to_string(),
            format!("{p:.0}"),
            paper.map(|p| format!("{p:.0}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    b.note("paper: \"two NVIDIA H100 GPUs consume an average of 1101 W\" (OPT 66B)");
    b.print();

    // ---- (c) DGX A100 scaling ----
    let a100 = GpuConfig::a100();
    let m = by_name("gpt3-20b").unwrap();
    let mut c = Table::new(
        "Fig 2(c) — DGX A100 strong scaling, GPT3-20B (FT benchmark)",
        &["GPUs", "modelled speedup", "paper speedup"],
    );
    let paper_pts = [1.0, 1.45, 1.95, 2.65];
    for ((n, s), paper) in scaling_speedups(&a100, &m, 8, 200).into_iter().zip(paper_pts) {
        c.row(&[n.to_string(), format!("{s:.2}x"), format!("{paper:.2}x")]);
    }
    c.note(format!(
        "paper per-doubling: {:.2}x; total at 8 GPUs: {:.2}x",
        calibration::DGX_SPEEDUP_PER_DOUBLING,
        calibration::DGX_SPEEDUP_8X
    ));
    c.print();
}
