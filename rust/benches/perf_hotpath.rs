//! Performance benches over the hot paths (EXPERIMENTS.md §Perf), plus
//! design-choice ablations from DESIGN.md:
//!
//! * simulator throughput (simulated cycles/s and instrs/s) — the fig7a
//!   sweeps must run in seconds;
//! * HyperDex compile throughput;
//! * coordinator token path (sim backend) — request-path overhead;
//! * ablations: ESL overlap on/off, batch-mode parameter reuse,
//!   multi-token prefill.

use lpu::compiler::{compile, CompileOpts, ParallelMode};
use lpu::config::LpuConfig;
use lpu::coordinator::{BackendFactory, Coordinator, CoordinatorConfig, Request, SchedulerPolicy};
use lpu::model::by_name;
use lpu::sim::{simulate_prefill, CoreSim};
use lpu::util::bench::Bencher;
use lpu::util::table::Table;

fn main() {
    let mut b = Bencher::new();
    let cfg = LpuConfig::asic_3_28tbs();

    // ---- compiler throughput ----
    let m13 = by_name("opt-1.3b").unwrap();
    let opts = CompileOpts { position: 1000, ..Default::default() };
    let compiled = compile(&m13, &cfg, &opts).unwrap();
    let n_instr = compiled.program.len() as f64;
    b.bench_throughput("compile/opt-1.3b", "instr", n_instr, || {
        compile(&m13, &cfg, &opts).unwrap()
    });

    // ---- simulator throughput ----
    let mut sim = CoreSim::new(&cfg);
    let cycles = sim.run(&compiled.program).unwrap().cycles as f64;
    b.bench_throughput("sim/opt-1.3b-step (sim cycles)", "cycle", cycles, || {
        sim.run(&compiled.program).unwrap()
    });
    b.bench_throughput("sim/opt-1.3b-step (instrs)", "instr", n_instr, || {
        sim.run(&compiled.program).unwrap()
    });

    // 66B x2: the heaviest per-token program.
    let m66 = by_name("opt-66b").unwrap();
    let opts66 = CompileOpts { n_devices: 2, position: 1000, ..Default::default() };
    let c66 = compile(&m66, &cfg, &opts66).unwrap();
    let mut sim66 = CoreSim::new(&cfg);
    b.bench_throughput("sim/opt-66b-x2-step (instrs)", "instr", c66.program.len() as f64, || {
        sim66.run(&c66.program).unwrap()
    });

    // ---- coordinator token path (sim backend) ----
    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
        ..CoordinatorConfig::default()
    });
    coord.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
    b.bench_throughput("coordinator/8-token request (sim backend)", "token", 8.0, || {
        coord
            .submit(Request::greedy("opt-tiny", vec![1, 2, 3], 8))
            .unwrap()
            .wait()
            .unwrap()
    });

    // ---- ablations ----
    let mut t = Table::new("Ablations (DESIGN.md §6)", &["experiment", "value", "comparison"]);

    // ESL overlap vs blocking. At 2 devices even blocking sync hides
    // behind the decoupled SMA weight prefetch (a finding — see
    // EXPERIMENTS.md); the ablation bites at ring size 8.
    for (label, model, ndev) in [("66B x2", &m66, 2usize), ("20B x8", &by_name("gpt3-20b").unwrap(), 8)] {
        let o = CompileOpts { n_devices: ndev, position: 1000, ..Default::default() };
        let cw = compile(model, &cfg, &o).unwrap();
        let cb = compile(model, &cfg, &CompileOpts { esl_overlap: false, ..o }).unwrap();
        let mut s = CoreSim::new(&cfg);
        let with = s.run(&cw.program).unwrap().cycles;
        let without = s.run(&cb.program).unwrap().cycles;
        t.row(&[
            format!("ESL overlap ({label})"),
            format!("{:.3} ms/token", with as f64 / cfg.freq_hz * 1e3),
            format!(
                "blocking: {:.3} ms/token ({:+.1}%)",
                without as f64 / cfg.freq_hz * 1e3,
                (without as f64 / with as f64 - 1.0) * 100.0
            ),
        ]);
    }

    // Batch-mode parameter reuse (paper future work).
    let tiny_cfg = LpuConfig::asic_819gbs();
    let mtiny = by_name("opt-mini").unwrap();
    let single = {
        let c = compile(&mtiny, &tiny_cfg, &CompileOpts { position: 100, ..Default::default() })
            .unwrap();
        CoreSim::new(&tiny_cfg).run(&c.program).unwrap().cycles
    };
    for batch in [2usize, 4, 8] {
        let c = compile(
            &mtiny,
            &tiny_cfg,
            &CompileOpts {
                position: 100,
                mode: ParallelMode::Batch { batch },
                sxe_sets: batch.min(4),
                ..Default::default()
            },
        )
        .unwrap();
        let cycles = CoreSim::new(&tiny_cfg).run(&c.program).unwrap().cycles;
        let per_tok = cycles as f64 / batch as f64;
        t.row(&[
            format!("batch mode x{batch} (opt-mini)"),
            format!("{:.0} cycles/token", per_tok),
            format!("{:.2}x throughput vs single ({single} cycles)", single as f64 / per_tok),
        ]);
    }

    // Multi-token prefill.
    let (mt, _) = simulate_prefill(&m13, &cfg, 1, 32, 4).unwrap();
    let serial = 32.0 * compiled_step_time(&cfg, &compiled);
    t.row(&[
        "multi-token prefill (1.3B, 32 tokens)".into(),
        format!("{:.3} ms total", mt * 1e3),
        format!("serial decode: {:.3} ms ({:.2}x faster)", serial * 1e3, serial / mt),
    ]);

    t.print();

    // ---- serving load study (open-loop Poisson, sim backend) ----
    use lpu::coordinator::{run_open_loop, LenDist, Workload};
    let mut load = Table::new(
        "Serving load study (sim backend, 2 workers, RR token scheduling)",
        &["offered req/s", "tokens/s", "TTFT p50 ms", "TTFT p99 ms", "latency p99 ms"],
    );
    for rate in [50.0f64, 200.0, 1000.0, 4000.0] {
        let wl = Workload {
            model: "opt-tiny".into(),
            rate,
            n_requests: 120,
            prompt_len: LenDist::Uniform(2, 10),
            output_len: LenDist::LongTail { min: 4, mean_extra: 12.0, cap: 64 },
            vocab: 512,
            seed: 7,
        };
        let r = run_open_loop(&coord, &wl).unwrap();
        load.row(&[
            format!("{rate:.0}"),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.2}", r.ttft.p50 * 1e3),
            format!("{:.2}", r.ttft.p99 * 1e3),
            format!("{:.2}", r.request_latency.p99 * 1e3),
        ]);
    }
    load.note("open-loop arrivals; TTFT rises once offered load exceeds worker token throughput");
    load.print();

    drop(b);
    coord.shutdown();
}

fn compiled_step_time(cfg: &LpuConfig, c: &lpu::compiler::Compiled) -> f64 {
    let mut sim = CoreSim::new(cfg);
    sim.run(&c.program).unwrap().time_s()
}
