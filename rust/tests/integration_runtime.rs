//! Integration: the PJRT bridge — AOT'd JAX/Pallas decoder executed from
//! rust, validated against the python-side golden vector.
//!
//! Requires `make artifacts`; tests self-skip (with a loud message) if
//! the artifacts are absent so `cargo test` works standalone.

use std::path::PathBuf;

use lpu::numerics::sampler::argmax;
use lpu::runtime::Engine;

fn artifacts() -> Option<PathBuf> {
    for dir in ["artifacts", "../artifacts"] {
        let d = PathBuf::from(dir);
        if Engine::artifacts_present(&d, "opt-tiny") {
            return Some(d);
        }
    }
    eprintln!("SKIP: artifacts missing; run `make artifacts` for full runtime coverage");
    None
}

#[test]
fn bridge_matches_python_golden_vector() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    engine.validate().unwrap();
}

#[test]
fn decode_is_deterministic_across_engine_instances() {
    let Some(dir) = artifacts() else { return };
    let a = Engine::load(&dir, "opt-tiny").unwrap();
    let b = Engine::load(&dir, "opt-tiny").unwrap();
    let ta = a.generate_greedy(&[1, 2, 3], 5).unwrap();
    let tb = b.generate_greedy(&[1, 2, 3], 5).unwrap();
    assert_eq!(ta, tb);
}

#[test]
fn sessions_are_isolated() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    let mut s1 = engine.new_session().unwrap();
    let mut s2 = engine.new_session().unwrap();
    // Different histories -> different logits at the same position.
    engine.decode_step(&mut s1, 1).unwrap();
    engine.decode_step(&mut s2, 2).unwrap();
    let l1 = engine.decode_step(&mut s1, 9).unwrap();
    let l2 = engine.decode_step(&mut s2, 9).unwrap();
    assert_ne!(argmax(&l1), usize::MAX); // touch
    let diff = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(diff > 1e-4, "sessions leaked state (max diff {diff})");
}

#[test]
fn context_affects_prediction() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    // Same final token, different prefix: logits must differ (the KV
    // cache round-trips through PJRT buffers correctly).
    let mut s1 = engine.new_session().unwrap();
    let mut s2 = engine.new_session().unwrap();
    for t in [1, 2, 3] {
        engine.decode_step(&mut s1, t).unwrap();
    }
    for t in [4, 5, 3] {
        engine.decode_step(&mut s2, t).unwrap();
    }
    let l1 = engine.decode_step(&mut s1, 7).unwrap();
    let l2 = engine.decode_step(&mut s2, 7).unwrap();
    let diff = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(diff > 1e-4, "attention ignored context (max diff {diff})");
}

#[test]
fn max_seq_enforced() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    let max = engine.manifest.max_seq;
    let mut s = engine.new_session().unwrap();
    s.pos = max; // simulate exhaustion
    assert!(engine.decode_step(&mut s, 1).is_err());
}

#[test]
fn logits_are_finite_and_vocab_sized() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    let mut s = engine.new_session().unwrap();
    let logits = engine.decode_step(&mut s, 0).unwrap();
    assert_eq!(logits.len(), engine.manifest.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn missing_model_fails_cleanly() {
    let Some(dir) = artifacts() else { return };
    let err = match Engine::load(&dir, "opt-nonexistent") {
        Err(e) => e,
        Ok(_) => panic!("expected load failure"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest") || msg.contains("reading"), "{msg}");
}
