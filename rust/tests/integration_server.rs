//! Integration: TCP server + client over the coordinator — with the sim
//! backend always, and over the real PJRT artifacts when present (the
//! full request path of the paper's Orion server).

use std::sync::Arc;

use lpu::coordinator::{BackendFactory, Coordinator, CoordinatorConfig, SchedulerPolicy};
use lpu::runtime::Engine;
use lpu::server::{serve, Client};

fn start(factory: BackendFactory, model: &str) -> (lpu::server::ServerHandle, std::net::SocketAddr) {
    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
        ..CoordinatorConfig::default()
    });
    coord.add_pool(model, 2, factory);
    let h = serve(Arc::new(coord), "127.0.0.1:0").unwrap();
    let addr = h.addr;
    (h, addr)
}

#[test]
fn sim_backend_full_protocol() {
    let (h, addr) = start(BackendFactory::sim("opt-tiny", 512), "opt-tiny");
    let mut c = Client::connect(&addr).unwrap();
    c.ping().unwrap();
    assert_eq!(c.models().unwrap(), vec!["opt-tiny".to_string()]);
    let r = c.generate("opt-tiny", &[1, 2, 3], 10, true).unwrap();
    assert_eq!(r.tokens.len(), 10);
    assert_eq!(r.streamed, r.tokens);
    assert_eq!(r.reason, "length");
    let m = c.metrics().unwrap();
    assert_eq!(m.get("completed").as_u64(), Some(1));
    h.stop();
}

#[test]
fn sim_backend_parallel_clients_and_throughput_counter() {
    let (h, addr) = start(BackendFactory::sim("opt-tiny", 512), "opt-tiny");
    let threads: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.generate("opt-tiny", &[i as i64 + 1], 12, false).unwrap().tokens
            })
        })
        .collect();
    let mut all = Vec::new();
    for t in threads {
        all.push(t.join().unwrap());
    }
    assert!(all.iter().all(|t| t.len() == 12));
    let mut c = Client::connect(&addr).unwrap();
    let m = c.metrics().unwrap();
    assert_eq!(m.get("tokens_out").as_u64(), Some(8 * 12));
    h.stop();
}

/// The real thing: serve the AOT-compiled opt-tiny over PJRT and check
/// the served tokens equal the python golden continuation.
#[test]
fn pjrt_backend_serves_golden_tokens() {
    let dir = std::path::PathBuf::from("artifacts");
    if !Engine::artifacts_present(&dir, "opt-tiny") {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return;
    }
    // Read the golden vector straight from the manifest.
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    let test = engine.manifest.test.clone().expect("manifest test vector");
    drop(engine);

    let (h, addr) = start(BackendFactory::pjrt(dir, "opt-tiny"), "opt-tiny");
    let mut c = Client::connect(&addr).unwrap();
    let r = c
        .generate("opt-tiny", &test.prompt, test.expected_tokens.len(), true)
        .unwrap();
    assert_eq!(
        r.tokens, test.expected_tokens,
        "served tokens diverge from python reference"
    );
    h.stop();
}
