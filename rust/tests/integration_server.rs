//! Integration: TCP server + client over the coordinator — with the sim
//! backend always, and over the real PJRT artifacts when present (the
//! full request path of the paper's Orion server).

use std::sync::Arc;

use lpu::coordinator::{BackendFactory, Coordinator, CoordinatorConfig, SchedulerPolicy};
use lpu::runtime::Engine;
use lpu::server::{serve, Client};

fn start(factory: BackendFactory, model: &str) -> (lpu::server::ServerHandle, std::net::SocketAddr) {
    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
        ..CoordinatorConfig::default()
    });
    coord.add_pool(model, 2, factory);
    let h = serve(Arc::new(coord), "127.0.0.1:0").unwrap();
    let addr = h.addr;
    (h, addr)
}

#[test]
fn sim_backend_full_protocol() {
    let (h, addr) = start(BackendFactory::sim("opt-tiny", 512), "opt-tiny");
    let mut c = Client::connect(&addr).unwrap();
    c.ping().unwrap();
    assert_eq!(c.models().unwrap(), vec!["opt-tiny".to_string()]);
    let r = c.generate("opt-tiny", &[1, 2, 3], 10, true).unwrap();
    assert_eq!(r.tokens.len(), 10);
    assert_eq!(r.streamed, r.tokens);
    assert_eq!(r.reason, "length");
    let m = c.metrics().unwrap();
    assert_eq!(m.get("completed").as_u64(), Some(1));
    h.stop();
}

#[test]
fn sim_backend_parallel_clients_and_throughput_counter() {
    let (h, addr) = start(BackendFactory::sim("opt-tiny", 512), "opt-tiny");
    let threads: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.generate("opt-tiny", &[i as i64 + 1], 12, false).unwrap().tokens
            })
        })
        .collect();
    let mut all = Vec::new();
    for t in threads {
        all.push(t.join().unwrap());
    }
    assert!(all.iter().all(|t| t.len() == 12));
    let mut c = Client::connect(&addr).unwrap();
    let m = c.metrics().unwrap();
    assert_eq!(m.get("tokens_out").as_u64(), Some(8 * 12));
    h.stop();
}

/// Schema pin for the `metrics` op (the README documents this table):
/// run load through TWO pools and assert every documented gauge —
/// aggregate and per-pool, including the per-worker routing-balance
/// gauges — is present and numeric, so the documented schema cannot
/// rot silently. Keys that are nullable by contract (pager capacity
/// and utilization under the unbounded reserve policy) are pinned to
/// export JSON null rather than a sentinel value.
#[test]
fn metrics_op_schema_is_complete_across_pools() {
    use lpu::util::json::Json;

    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
        ..CoordinatorConfig::default()
    });
    coord.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
    coord.add_pool("opt-mini", 3, BackendFactory::sim("opt-mini", 256));
    let h = serve(Arc::new(coord), "127.0.0.1:0").unwrap();
    let addr = h.addr;

    let mut c = Client::connect(&addr).unwrap();
    for model in ["opt-tiny", "opt-mini"] {
        for p in 0..3i64 {
            let r = c.generate(model, &[p + 1, p + 2], 5, false).unwrap();
            assert_eq!(r.tokens.len(), 5, "{model}");
        }
    }

    let m = c.metrics().unwrap();
    // Aggregate snapshot fields (every key Snapshot::to_json emits) plus
    // the server-side tags.
    let aggregate = [
        "submitted",
        "started",
        "completed",
        "errors",
        "cancelled",
        "rejected",
        "preemptions",
        "peak_kv_blocks",
        "tokens_out",
        "batch_steps",
        "mean_batch_size",
        "prefill_spans",
        "prefill_tokens",
        "prefix_hit_tokens",
        "shared_blocks",
        "cow_splits",
        "kv_demoted_blocks",
        "kv_restored_blocks",
        "kv_restored_tokens",
        "kv_host_capacity_blocks",
        "mean_queue_delay_s",
        "mean_ttft_s",
        "ttft_p50_s",
        "ttft_p95_s",
        "ttft_p99_s",
        "mean_token_latency_s",
        "tpot_p50_s",
        "tpot_p95_s",
        "tpot_p99_s",
        "max_token_latency_s",
        "mean_request_latency_s",
        "faults_injected",
        "retries",
        "failovers",
        "lanes_restored_on_failover",
        "lanes_recomputed_on_failover",
        "worker_crashes",
        "shed_expired",
        "shed_livelock",
        "tier_interactive_submitted",
        "tier_interactive_shed",
        "tier_interactive_done",
        "tier_interactive_attained",
        "tier_batch_submitted",
        "tier_batch_shed",
        "tier_batch_done",
        "replica_crashes",
        "partitions",
        "streams_failed_over",
        "hedges_issued",
        "hedges_won",
    ];
    for field in aggregate {
        assert!(
            m.get(field).as_f64().is_some(),
            "aggregate metrics field '{field}' missing or non-numeric"
        );
    }
    // Full latency distributions, not just percentiles: both histograms
    // carry the pinned log-spaced grid (37 bounds, 38 counts — the last
    // is the overflow bucket) and every completion is accounted for.
    for (field, expect_total) in [("ttft_hist", Some(6u64)), ("tpot_hist", None)] {
        let hist = m.get(field);
        let bounds = hist.get("bounds_s").as_arr().unwrap_or_else(|| {
            panic!("{field}.bounds_s missing from the metrics frame")
        });
        let counts = hist
            .get("counts")
            .as_arr()
            .unwrap_or_else(|| panic!("{field}.counts missing from the metrics frame"));
        assert_eq!(bounds.len(), 37, "{field}.bounds_s log-spaced grid changed");
        assert_eq!(counts.len(), 38, "{field}.counts must be bounds + overflow");
        let total: u64 =
            counts.iter().map(|c| c.as_u64().expect("integer bucket count")).sum();
        match expect_total {
            // One first token per completed request.
            Some(n) => assert_eq!(total, n, "{field} lost samples"),
            // One step latency per emitted token: 5 × 6 requests.
            None => assert_eq!(total, 6 * 5, "{field} lost samples"),
        }
    }
    // Nullable-by-contract: this coordinator runs the unbounded reserve
    // policy, so pager capacity and utilization export JSON null — not
    // the usize::MAX sentinel a scraper would graph as a real value.
    for field in ["kv_capacity_blocks", "kv_block_utilization"] {
        assert!(
            matches!(*m.get(field), Json::Null),
            "'{field}' must export null (not a sentinel) when the pager is unbounded"
        );
    }
    assert_eq!(m.get("type").as_str(), Some("metrics"));
    assert!(m.get("policy").as_str().is_some());
    assert_eq!(m.get("completed").as_u64(), Some(6));

    // Per-pool frames: both pools present with every documented gauge
    // non-null, and one worker frame per configured worker.
    let pool_fields = [
        "prefill_spans",
        "prefill_tokens",
        "prefix_hit_tokens",
        "shared_blocks",
        "cow_splits",
        "demoted_blocks",
        "restored_blocks",
        "queue_depth",
    ];
    for (model, n_workers) in [("opt-tiny", 2usize), ("opt-mini", 3)] {
        let pool = m.get("pools").get(model);
        assert!(
            !matches!(*pool, Json::Null),
            "pools.{model} missing from the metrics frame"
        );
        for field in pool_fields {
            assert!(
                pool.get(field).as_u64().is_some(),
                "pools.{model}.{field} missing or non-numeric"
            );
        }
        // Three single-pass prompts ran in each pool.
        assert_eq!(pool.get("prefill_spans").as_u64(), Some(3), "{model}");
        let workers = pool.get("workers").as_arr().expect("workers array");
        assert_eq!(workers.len(), n_workers, "pools.{model}.workers length");
        for (i, w) in workers.iter().enumerate() {
            for field in ["queue_depth", "peak_queue_depth", "active_lanes"] {
                assert!(
                    w.get(field).as_u64().is_some(),
                    "pools.{model}.workers[{i}].{field} missing or non-numeric"
                );
            }
            // The health gauge is boolean by contract (a scraper alerts
            // on false), and no fault plan ran here.
            assert_eq!(
                w.get("healthy").as_bool(),
                Some(true),
                "pools.{model}.workers[{i}].healthy missing or not a bool"
            );
        }
    }
    h.stop();
}

/// The `trace` op drains the flight recorder: every completed request's
/// lifecycle timeline (opening with `submitted`, closing with a
/// terminal event), per-request attribution that sums bitwise to
/// TTFT + decode time, and the shed/deadline "why" digest — and a
/// second drain proves the ring actually empties.
#[test]
fn trace_op_drains_flight_recorder() {
    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
        trace: true,
        ..CoordinatorConfig::default()
    });
    coord.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
    let h = serve(Arc::new(coord), "127.0.0.1:0").unwrap();
    let mut c = Client::connect(&h.addr).unwrap();
    for p in 0..4i64 {
        c.generate("opt-tiny", &[p + 1, p + 2], 5, false).unwrap();
    }

    let t = c.trace().unwrap();
    assert_eq!(t.get("type").as_str(), Some("trace"));
    assert_eq!(t.get("enabled").as_bool(), Some(true));
    let tls = t.get("timelines").as_arr().expect("timelines array");
    assert_eq!(tls.len(), 4, "one sealed timeline per completed request");
    for tl in tls {
        let events = tl.get("events").as_arr().expect("events array");
        assert_eq!(events.first().unwrap().get("ev").as_str(), Some("submitted"));
        assert_eq!(events.last().unwrap().get("ev").as_str(), Some("finished"));
        assert_eq!(
            events.iter().filter(|e| e.get("ev").as_str() == Some("decode_step")).count(),
            5,
            "one decode_step per generated token"
        );
        // Attribution identity: the exported components sum to the
        // exported endpoints (same f64s on both sides of the wire).
        let a = tl.get("attribution");
        let total = a.get("ttft_s").as_f64().unwrap() + a.get("decode_s").as_f64().unwrap();
        let sum: f64 = [
            "queue_wait_s",
            "admission_delay_s",
            "prefill_s",
            "preempt_stall_s",
            "restore_s",
            "failover_s",
            "decode_gap_s",
        ]
        .iter()
        .map(|k| a.get(k).as_f64().unwrap())
        .sum();
        assert!(
            (sum - total).abs() < 1e-12,
            "attribution components ({sum}) do not sum to TTFT + decode ({total})"
        );
    }
    assert_eq!(t.get("digest").get("completed").as_u64(), Some(4));

    // The op is a drain, not a peek: the ring is now empty.
    let again = c.trace().unwrap();
    assert_eq!(
        again.get("timelines").as_arr().map(|a| a.len()),
        Some(0),
        "second drain must see an empty flight recorder"
    );

    // With tracing live, the metrics frame carries the attribution
    // component summary alongside the endpoint histograms.
    let m = c.metrics().unwrap();
    let att = m.get("attribution");
    assert_eq!(att.get("count").as_u64(), Some(4));
    assert!(att.get("prefill_s").get("mean_s").as_f64().is_some());
    h.stop();
}

/// The real thing: serve the AOT-compiled opt-tiny over PJRT and check
/// the served tokens equal the python golden continuation.
#[test]
fn pjrt_backend_serves_golden_tokens() {
    let dir = std::path::PathBuf::from("artifacts");
    if !Engine::artifacts_present(&dir, "opt-tiny") {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return;
    }
    // Read the golden vector straight from the manifest.
    let engine = Engine::load(&dir, "opt-tiny").unwrap();
    let test = engine.manifest.test.clone().expect("manifest test vector");
    drop(engine);

    let (h, addr) = start(BackendFactory::pjrt(dir, "opt-tiny"), "opt-tiny");
    let mut c = Client::connect(&addr).unwrap();
    let r = c
        .generate("opt-tiny", &test.prompt, test.expected_tokens.len(), true)
        .unwrap();
    assert_eq!(
        r.tokens, test.expected_tokens,
        "served tokens diverge from python reference"
    );
    h.stop();
}
