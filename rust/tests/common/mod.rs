//! Shared helpers for the integration-test crates. Each test file pulls
//! this in with `mod common;`, so not every helper is referenced from
//! every crate.
#![allow(dead_code)]

pub mod invariants;
