//! The reusable serving-invariant harness: ONE place that states the
//! standing contract every serving PR re-asserts — instead of each test
//! hand-rolling its own copy.
//!
//! The contract, for any virtual/threaded run pair:
//!
//! 1. **Rerun determinism** — the virtual harness is a pure function of
//!    (workload seed, config): rerunning yields bit-identical records
//!    AND bit-identical latency percentiles.
//! 2. **Stream identity across paths** — the live threaded coordinator
//!    produces the same greedy token streams as the virtual harness,
//!    request for request.
//! 3. **No duplicate / diverging tokens** — records are plan-indexed
//!    with no duplicates, `token_times` matches `tokens` one-to-one,
//!    and timelines are ordered (`arrival <= first_token <= done <=
//!    wall`).
//! 4. **Zero end-of-run KV blocks in use** — every pager block is
//!    returned once the run drains; a leak means a lifetime bug.
//!
//! Checks come in two flavors: `Result<(), String>`-returning functions
//! for property-test closures (compose with `?`), and the panicking
//! [`assert_standing_contract`] entry point for `#[test]` bodies.

use lpu::coordinator::trace::COMPONENTS;
use lpu::coordinator::{
    Attribution, ClusterReport, RequestTimeline, SloTier, SpanEvent, VirtualReport,
};

/// Per-record well-formedness + the KV-leak gate on one virtual run
/// (contract points 3 and 4).
pub fn well_formed(r: &VirtualReport) -> Result<(), String> {
    if r.end_kv_blocks_in_use != 0 {
        return Err(format!(
            "KV leak: {} blocks still in use after the run drained",
            r.end_kv_blocks_in_use
        ));
    }
    let served = r.records.iter().filter(|rec| !rec.tokens.is_empty()).count();
    if served + r.rejected + r.shed_expired + r.shed_livelock + r.failed + r.orphaned
        < r.records.len()
    {
        return Err(format!(
            "lost requests: served {served} + rejected {} + shed {}+{} + failed {} \
             + orphaned {} < {}",
            r.rejected,
            r.shed_expired,
            r.shed_livelock,
            r.failed,
            r.orphaned,
            r.records.len()
        ));
    }
    for (i, rec) in r.records.iter().enumerate() {
        if rec.request_id != i {
            return Err(format!(
                "duplicate or misordered record: id {} at index {i}",
                rec.request_id
            ));
        }
        if rec.token_times.len() != rec.tokens.len() {
            return Err(format!(
                "request {i}: {} token times for {} tokens",
                rec.token_times.len(),
                rec.tokens.len()
            ));
        }
        if rec.token_times.windows(2).any(|w| w[0] > w[1]) {
            return Err(format!("request {i}: token times go backwards"));
        }
        if !rec.tokens.is_empty() {
            if rec.first_token_s < rec.arrival_s
                || rec.done_s < rec.first_token_s
                || rec.done_s > r.wall_s
            {
                return Err(format!(
                    "request {i}: inconsistent timeline {} .. {} .. {} vs wall {}",
                    rec.arrival_s, rec.first_token_s, rec.done_s, r.wall_s
                ));
            }
        }
    }
    Ok(())
}

/// Contract point 1: two runs of the same (seed, config) are
/// bit-identical — records, percentiles, and makespan (f64 equality,
/// not approximate).
pub fn rerun_deterministic(a: &VirtualReport, b: &VirtualReport) -> Result<(), String> {
    if a.records.len() != b.records.len() {
        return Err(format!(
            "rerun changed record count: {} vs {}",
            a.records.len(),
            b.records.len()
        ));
    }
    for (ra, rb) in a.records.iter().zip(&b.records) {
        if ra != rb {
            return Err(format!("rerun diverged at request {}", ra.request_id));
        }
    }
    for (name, x, y) in [
        ("ttft.p50", a.ttft.p50, b.ttft.p50),
        ("ttft.p95", a.ttft.p95, b.ttft.p95),
        ("ttft.p99", a.ttft.p99, b.ttft.p99),
        ("tpot.p50", a.tpot.p50, b.tpot.p50),
        ("tpot.p95", a.tpot.p95, b.tpot.p95),
        ("tpot.p99", a.tpot.p99, b.tpot.p99),
        ("latency.p99", a.request_latency.p99, b.request_latency.p99),
        ("wall_s", a.wall_s, b.wall_s),
    ] {
        if x != y {
            return Err(format!("rerun changed {name}: {x} vs {y}"));
        }
    }
    Ok(())
}

/// Stream identity between two virtual runs that may differ in timing
/// or placement but must not differ in tokens (routing, chunking,
/// caching, tiering, host-KV are all placement/timing features).
/// Rejection decisions must agree too — a config knob that silently
/// changes admission is a bug the old ad-hoc tests each re-checked.
pub fn streams_identical(
    a: &VirtualReport,
    b: &VirtualReport,
    what: &str,
) -> Result<(), String> {
    if a.rejected != b.rejected {
        return Err(format!(
            "rejection count changed by {what}: {} vs {}",
            a.rejected, b.rejected
        ));
    }
    if a.records.len() != b.records.len() {
        return Err(format!(
            "record count changed by {what}: {} vs {}",
            a.records.len(),
            b.records.len()
        ));
    }
    for (ra, rb) in a.records.iter().zip(&b.records) {
        if ra.tokens != rb.tokens {
            return Err(format!(
                "request {} stream changed by {what}",
                ra.request_id
            ));
        }
    }
    Ok(())
}

/// Contract point 2: the threaded path's token streams (plan-ordered,
/// as [`run_open_loop`](lpu::coordinator::run_open_loop) and
/// [`run_cluster_open_loop`](lpu::coordinator::run_cluster_open_loop)
/// report them) match the virtual run request-for-request.
pub fn threaded_matches_virtual(
    virt: &VirtualReport,
    threaded_streams: &[Vec<i64>],
) -> Result<(), String> {
    if virt.records.len() != threaded_streams.len() {
        return Err(format!(
            "path record counts differ: virtual {} vs threaded {}",
            virt.records.len(),
            threaded_streams.len()
        ));
    }
    for (v, l) in virt.records.iter().zip(threaded_streams) {
        if &v.tokens != l {
            return Err(format!(
                "request {} diverges between virtual and threaded paths",
                v.request_id
            ));
        }
    }
    Ok(())
}

/// The single panicking entry point for `#[test]` bodies: given a
/// virtual run, its rerun, and (optionally) the threaded path's streams
/// for the same plan, assert the full standing contract.
pub fn assert_standing_contract(
    virt: &VirtualReport,
    rerun: &VirtualReport,
    threaded_streams: Option<&[Vec<i64>]>,
) {
    require(well_formed(virt));
    require(well_formed(rerun));
    require(rerun_deterministic(virt, rerun));
    if let Some(streams) = threaded_streams {
        require(threaded_matches_virtual(virt, streams));
    }
}

/// Unwrap a harness check inside a `#[test]` with its message intact.
pub fn require(res: Result<(), String>) {
    if let Err(e) = res {
        panic!("serving invariant violated: {e}");
    }
}

// ---- cluster-tier extensions of the same contract ----

/// Cluster-run well-formedness: the pool contract on every replica,
/// plus the fleet rules — shed strictly before the first token (never
/// mid-stream), batch never shed, tier counters consistent with the
/// records, zero KV blocks leaked across the whole fleet.
pub fn cluster_well_formed(r: &ClusterReport) -> Result<(), String> {
    for vr in r.replicas.iter().flatten() {
        well_formed(vr)?;
    }
    if r.end_kv_blocks_in_use != 0 {
        return Err(format!(
            "fleet KV leak: {} blocks in use after drain",
            r.end_kv_blocks_in_use
        ));
    }
    if r.shed_batch != 0 {
        return Err(format!("batch tier shed {} requests", r.shed_batch));
    }
    let mut shed_interactive = 0;
    for (i, rec) in r.records.iter().enumerate() {
        if rec.request_id != i {
            return Err(format!(
                "duplicate or misordered cluster record: id {} at index {i}",
                rec.request_id
            ));
        }
        if rec.shed {
            // Shed happens at admission or never: no tokens, no
            // replica, first-token time pinned to arrival.
            if !rec.tokens.is_empty()
                || !rec.token_times.is_empty()
                || rec.replica.is_some()
                || rec.first_token_s != rec.arrival_s
            {
                return Err(format!("request {i} shed after streaming began"));
            }
            if rec.tier == SloTier::Interactive {
                shed_interactive += 1;
            }
        } else if rec.replica.is_none() && !rec.tokens.is_empty() {
            return Err(format!("request {i} has tokens but no replica"));
        }
        if rec.token_times.len() != rec.tokens.len() {
            return Err(format!(
                "request {i}: {} token times for {} tokens",
                rec.token_times.len(),
                rec.tokens.len()
            ));
        }
    }
    if shed_interactive != r.shed_interactive {
        return Err(format!(
            "shed counter disagrees with records: {} vs {}",
            r.shed_interactive, shed_interactive
        ));
    }
    let submitted = r.submitted_interactive + r.submitted_batch;
    if submitted != r.records.len() {
        return Err(format!(
            "tier submitted counters {} != {} records",
            submitted,
            r.records.len()
        ));
    }
    if r.attained_interactive > r.completed_interactive {
        return Err(format!(
            "attained {} > completed {}",
            r.attained_interactive, r.completed_interactive
        ));
    }
    Ok(())
}

/// Exactly-once delivery under failover (contract point 3 at the fleet
/// tier): every record's delivery times are monotonic (a reordered pump
/// would interleave the old and new lanes), and every completed stream
/// EQUALS its rid-matched baseline record — a resumption that restarts
/// one token early re-delivers the boundary token, which shows up here
/// as a replayed prefix and is named as a duplicate rather than folded
/// into a generic stream mismatch.
pub fn no_duplicate_or_reordered_tokens(
    fleet: &ClusterReport,
    baseline: &VirtualReport,
) -> Result<(), String> {
    if fleet.records.len() != baseline.records.len() {
        return Err(format!(
            "record counts differ: fleet {} vs baseline {}",
            fleet.records.len(),
            baseline.records.len()
        ));
    }
    for (f, b) in fleet.records.iter().zip(&baseline.records) {
        if f.token_times.windows(2).any(|w| w[0] > w[1]) {
            return Err(format!(
                "request {}: token delivery times go backwards (reordered streams)",
                f.request_id
            ));
        }
        if !f.completed() || b.tokens.is_empty() {
            continue;
        }
        if f.tokens.len() > b.tokens.len() && f.tokens[..b.tokens.len()] == b.tokens[..] {
            return Err(format!(
                "request {}: {} duplicate token(s) delivered past the {}-token stream",
                f.request_id,
                f.tokens.len() - b.tokens.len(),
                b.tokens.len()
            ));
        }
        if f.tokens != b.tokens {
            return Err(format!(
                "request {}: stream diverges from the fault-free baseline",
                f.request_id
            ));
        }
    }
    Ok(())
}

/// Named fleet KV-leak gate for chaos tests: zero blocks in use on the
/// fleet aggregate AND on every replica individually after drain — a
/// crashed replica's pager must be released by the halt teardown, a
/// partitioned one by the post-thaw drain. Naming the leaking replica
/// turns "some block leaked somewhere" into a one-line diagnosis.
pub fn fleet_kv_clean(r: &ClusterReport) -> Result<(), String> {
    if r.end_kv_blocks_in_use != 0 {
        return Err(format!(
            "fleet KV leak: {} blocks in use after drain",
            r.end_kv_blocks_in_use
        ));
    }
    for (i, vr) in r.replicas.iter().enumerate() {
        if let Some(vr) = vr {
            if vr.end_kv_blocks_in_use != 0 {
                return Err(format!(
                    "replica {i} leaked {} KV blocks after drain",
                    vr.end_kv_blocks_in_use
                ));
            }
        }
    }
    Ok(())
}

// ---- request-lifecycle trace extensions of the same contract ----

/// Structural well-formedness of one recorded timeline: opens with
/// `Submitted`, timestamps never go backwards, exactly one terminal
/// event and it comes last, and — when the timeline is sealed — the
/// attribution both recomputes to itself and satisfies the identity
/// `Σ components == ttft + decode` bitwise with no meaningfully
/// negative component.
pub fn timeline_well_formed(tl: &RequestTimeline) -> Result<(), String> {
    let rid = tl.request_id;
    if tl.events.is_empty() {
        return Err(format!("request {rid}: empty timeline"));
    }
    if !matches!(tl.events[0].ev, SpanEvent::Submitted { .. }) {
        return Err(format!(
            "request {rid}: timeline opens with {} instead of Submitted",
            tl.events[0].ev.kind()
        ));
    }
    if tl.events.windows(2).any(|w| w[0].t_s > w[1].t_s) {
        return Err(format!("request {rid}: timeline timestamps go backwards"));
    }
    for (i, e) in tl.events.iter().enumerate() {
        let last = i + 1 == tl.events.len();
        if e.ev.is_terminal() != last {
            return Err(format!(
                "request {rid}: {} event {} of {} (terminal events must come last, \
                 exactly once)",
                e.ev.kind(),
                i + 1,
                tl.events.len()
            ));
        }
    }
    if tl.events[1..].iter().any(|e| matches!(e.ev, SpanEvent::Submitted { .. })) {
        return Err(format!("request {rid}: Submitted recorded twice"));
    }
    if let Some(a) = &tl.attribution {
        if Attribution::from_timeline(tl) != Some(*a) {
            return Err(format!(
                "request {rid}: sealed attribution does not recompute from the events \
                 (corrupted timeline or stale seal)"
            ));
        }
        if a.component_sum().to_bits() != a.total_s().to_bits() {
            return Err(format!(
                "request {rid}: attribution identity broken: components sum to {} but \
                 ttft+decode is {}",
                a.component_sum(),
                a.total_s()
            ));
        }
        for (name, v) in COMPONENTS.iter().zip(a.components()) {
            if v < -1e-9 {
                return Err(format!("request {rid}: negative {name} component {v}"));
            }
        }
    }
    Ok(())
}

/// Pool-level trace/record agreement on a traced virtual run: one
/// timeline per record, each well-formed, with the decode walk exactly
/// matching the record — one `DecodeStep` per token, first step at
/// `first_token_s`, last at `done_s` (bitwise; both drivers stamp the
/// same virtual clock).
pub fn timelines_match_records(r: &VirtualReport) -> Result<(), String> {
    if r.timelines.len() != r.records.len() {
        return Err(format!(
            "{} timelines for {} records",
            r.timelines.len(),
            r.records.len()
        ));
    }
    for (tl, rec) in r.timelines.iter().zip(&r.records) {
        timeline_well_formed(tl)?;
        if tl.request_id != rec.request_id as u64 {
            return Err(format!(
                "timeline {} paired with record {}",
                tl.request_id, rec.request_id
            ));
        }
        // The exact decode-walk contract holds for streams that ran to
        // completion; failed/shed streams legitimately stop partway.
        if !matches!(tl.events.last().map(|e| &e.ev), Some(SpanEvent::Finished)) {
            continue;
        }
        let steps: Vec<f64> = tl
            .events
            .iter()
            .filter(|e| matches!(e.ev, SpanEvent::DecodeStep))
            .map(|e| e.t_s)
            .collect();
        if steps.len() != rec.tokens.len() {
            return Err(format!(
                "request {}: {} DecodeStep events for {} tokens",
                rec.request_id,
                steps.len(),
                rec.tokens.len()
            ));
        }
        if let (Some(&first), Some(&last)) = (steps.first(), steps.last()) {
            if first != rec.first_token_s || last != rec.done_s {
                return Err(format!(
                    "request {}: decode walk [{first}, {last}] disagrees with record \
                     [{}, {}]",
                    rec.request_id, rec.first_token_s, rec.done_s
                ));
            }
        }
    }
    Ok(())
}

/// Fleet-level trace/record agreement on a traced cluster run: one
/// stitched timeline per arrival, each well-formed, terminal agreeing
/// with the record outcome. Decode counts are NOT matched here — a
/// failover-resumed stream's winner hop replays fewer steps than the
/// client saw tokens, by design.
pub fn cluster_timelines_match_records(r: &ClusterReport) -> Result<(), String> {
    if r.timelines.len() != r.records.len() {
        return Err(format!(
            "{} timelines for {} cluster records",
            r.timelines.len(),
            r.records.len()
        ));
    }
    for (tl, rec) in r.timelines.iter().zip(&r.records) {
        timeline_well_formed(tl)?;
        if tl.request_id != rec.request_id as u64 {
            return Err(format!(
                "timeline {} paired with cluster record {}",
                tl.request_id, rec.request_id
            ));
        }
        let terminal = tl.events.last().map(|e| e.ev.kind()).unwrap_or("none");
        if rec.shed && terminal != "shed" {
            return Err(format!(
                "request {}: shed at admission but timeline ends with {terminal}",
                rec.request_id
            ));
        }
        if rec.completed() && terminal != "finished" {
            return Err(format!(
                "request {}: completed but timeline ends with {terminal}",
                rec.request_id
            ));
        }
    }
    Ok(())
}

/// Cluster stream identity: every request the fleet completed carries
/// tokens bit-identical to the rid-matched record of a baseline run
/// (e.g. single-replica, no-shed, no-autoscale over the same plan) —
/// replica count, tier mix, shedding, and autoscaling are
/// placement/admission features, never token features.
pub fn cluster_streams_match_baseline(
    fleet: &ClusterReport,
    baseline: &VirtualReport,
) -> Result<(), String> {
    if fleet.records.len() != baseline.records.len() {
        return Err(format!(
            "record counts differ: fleet {} vs baseline {}",
            fleet.records.len(),
            baseline.records.len()
        ));
    }
    for (f, b) in fleet.records.iter().zip(&baseline.records) {
        if f.completed() && !b.tokens.is_empty() && f.tokens != b.tokens {
            return Err(format!(
                "request {} stream changed by cluster placement (tier {:?}, replica {:?})",
                f.request_id, f.tier, f.replica
            ));
        }
    }
    Ok(())
}
