//! Schema regression over the committed `BENCH_*.json` baselines. Each
//! file starts life as a hand-written null placeholder that the bench
//! emitters overwrite with measured values; ci.sh's null gate catches a
//! value the emitter forgot, but nothing caught the *keys* drifting —
//! a renamed summary field would silently orphan the README table and
//! any downstream consumer. This test pins every key path (recursing
//! through objects; array elements are cell-shaped and deliberately
//! unpinned) for the placeholder AND the regenerated file alike:
//! `note` is the one placeholder-only key (the emitters drop it), so it
//! is allowed-optional rather than required.

use lpu::util::json::Json;

/// Collect every object key path in `json` (dot-joined; arrays are not
/// descended into).
fn key_paths(json: &Json, prefix: &str, out: &mut Vec<String>) {
    if let Some(o) = json.as_obj() {
        for (k, v) in o.iter() {
            let path = if prefix.is_empty() {
                k.to_string()
            } else {
                format!("{prefix}.{k}")
            };
            key_paths(v, &path, out);
            out.push(path);
        }
    }
}

fn check_schema(file: &str, required: &[&str], optional: &[&str]) {
    let path = format!("{}/../{file}", env!("CARGO_MANIFEST_DIR"));
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {file}: {e}"));
    let doc = Json::parse(&src).unwrap_or_else(|e| panic!("parse {file}: {e}"));
    let mut present = Vec::new();
    key_paths(&doc, "", &mut present);
    for req in required {
        assert!(
            present.iter().any(|p| p == req),
            "{file}: required key `{req}` is missing — emitter and placeholder must \
             carry the same schema"
        );
    }
    for p in &present {
        assert!(
            required.contains(&p.as_str()) || optional.contains(&p.as_str()),
            "{file}: unexpected key `{p}` — update this pinned schema AND the README \
             bench-schema table in the same change"
        );
    }
}

#[test]
fn bench_serving_schema_is_pinned() {
    check_schema(
        "BENCH_serving.json",
        &[
            "bench",
            "fast",
            "model",
            "device",
            "kv_ablation_budget_tokens",
            "kv_ablation_summary",
            "kv_ablation_summary.reserve_tok_s",
            "kv_ablation_summary.paged_tok_s",
            "kv_ablation_summary.tok_s_ratio",
            "kv_ablation_summary.reserve_peak_active",
            "kv_ablation_summary.paged_peak_active",
            "kv_ablation_summary.peak_active_ratio",
            "kv_ablation_summary.paged_preemptions",
            "prefill_interference_summary",
            "prefill_interference_summary.long_prompt_tokens",
            "prefill_interference_summary.chunk_tokens",
            "prefill_interference_summary.single_pass_neighbor_tpot_p99_ms",
            "prefill_interference_summary.chunked_neighbor_tpot_p99_ms",
            "prefill_interference_summary.neighbor_tpot_p99_ratio",
            "prefill_interference_summary.single_pass_long_ttft_mean_ms",
            "prefill_interference_summary.chunked_long_ttft_mean_ms",
            "prefill_interference_summary.long_ttft_ratio",
            "router_summary",
            "router_summary.workers",
            "router_summary.n_requests",
            "router_summary.prefix_tokens",
            "router_summary.budget_blocks",
            "router_summary.round_robin_prefix_hit_tokens",
            "router_summary.least_loaded_prefix_hit_tokens",
            "router_summary.affinity_prefix_hit_tokens",
            "router_summary.round_robin_mean_ttft_ms",
            "router_summary.least_loaded_mean_ttft_ms",
            "router_summary.affinity_mean_ttft_ms",
            "router_summary.rr_over_affinity_ttft_ratio",
            "router_summary.affinity_peak_queue_depth",
            "kv_tier_summary",
            "kv_tier_summary.prompt_tokens",
            "kv_tier_summary.output_tokens",
            "kv_tier_summary.budget_blocks",
            "kv_tier_summary.host_capacity_blocks",
            "kv_tier_summary.preemptions",
            "kv_tier_summary.demoted_blocks",
            "kv_tier_summary.restored_blocks",
            "kv_tier_summary.restored_tokens",
            "kv_tier_summary.recompute_resume_gap_ms",
            "kv_tier_summary.restore_resume_gap_ms",
            "kv_tier_summary.resume_gap_ratio",
            "kv_tier_summary.recompute_wall_s",
            "kv_tier_summary.restore_wall_s",
            "fault_recovery_summary",
            "fault_recovery_summary.fault_plan",
            "fault_recovery_summary.workers",
            "fault_recovery_summary.n_requests",
            "fault_recovery_summary.completed",
            "fault_recovery_summary.worker_crashes",
            "fault_recovery_summary.failovers",
            "fault_recovery_summary.lanes_restored_on_failover",
            "fault_recovery_summary.lanes_recomputed_on_failover",
            "fault_recovery_summary.faults_injected",
            "fault_recovery_summary.retries",
            "fault_recovery_summary.end_kv_blocks_in_use",
            "fault_recovery_summary.clean_wall_s",
            "fault_recovery_summary.faulted_wall_s",
            "prefix_cache_summary",
            "prefix_cache_summary.prefix_tokens",
            "prefix_cache_summary.n_requests",
            "prefix_cache_summary.budget_blocks",
            "prefix_cache_summary.peak_kv_blocks_off",
            "prefix_cache_summary.peak_kv_blocks_on",
            "prefix_cache_summary.peak_block_ratio",
            "prefix_cache_summary.cold_ttft_ms",
            "prefix_cache_summary.hit_ttft_mean_ms",
            "prefix_cache_summary.cold_over_hit_ttft_ratio",
            "prefix_cache_summary.prefix_hit_tokens",
            "prefix_cache_summary.shared_blocks",
            "prefix_cache_summary.cow_splits",
            "trace_overhead_summary",
            "trace_overhead_summary.n_requests",
            "trace_overhead_summary.workers",
            "trace_overhead_summary.streams_identical",
            "trace_overhead_summary.virtual_wall_s",
            "trace_overhead_summary.timelines_recorded",
            "trace_overhead_summary.wall_off_best_s",
            "trace_overhead_summary.wall_on_best_s",
            "trace_overhead_summary.overhead_ratio",
            "cells",
        ],
        &["note"],
    );
}

#[test]
fn bench_scaling_schema_is_pinned() {
    check_schema(
        "BENCH_scaling.json",
        &[
            "bench",
            "model",
            "device",
            "per_doubling",
            "per_doubling.lpu_esl_overlap",
            "per_doubling.lpu_no_overlap",
            "per_doubling.dgx_a100",
            "per_doubling.paper_lpu",
            "per_doubling.paper_dgx",
            "lpu_points",
            "lpu_no_overlap_points",
            "dgx_points",
            "small_model_corollary",
            "small_model_corollary.model",
            "small_model_corollary.per_doubling",
            "small_model_corollary.points",
        ],
        &["note"],
    );
}

#[test]
fn bench_cluster_schema_is_pinned() {
    check_schema(
        "BENCH_cluster.json",
        &[
            "bench",
            "fast",
            "model",
            "device",
            "replicas",
            "interactive_fraction",
            "ttft_budget_ms",
            "calibration",
            "calibration.base_ttft_ms",
            "calibration.sustainable_rate_req_s",
            "overload_ablation",
            "overload_ablation.offered_rate_req_s",
            "overload_ablation.noshed_interactive_attainment",
            "overload_ablation.shed_interactive_attainment",
            "overload_ablation.attainment_gain",
            "overload_ablation.shed_fraction_interactive",
            "autoscale_summary",
            "autoscale_summary.trace",
            "autoscale_summary.min_replicas",
            "autoscale_summary.max_replicas",
            "autoscale_summary.peak_replicas",
            "autoscale_summary.scale_events",
            "autoscale_summary.wall_s",
            "chaos_summary",
            "chaos_summary.trace",
            "chaos_summary.replicas",
            "chaos_summary.n_requests",
            "chaos_summary.completion",
            "chaos_summary.end_kv_blocks_in_use",
            "chaos_summary.streams_identical_fault_on_off",
            "chaos_summary.replica_crashes",
            "chaos_summary.partitions",
            "chaos_summary.streams_failed_over",
            "chaos_summary.hedges_issued",
            "chaos_summary.hedges_won",
            "chaos_summary.threaded_completed",
            "chaos_summary.threaded_failed",
            "cells",
        ],
        &["note"],
    );
}
