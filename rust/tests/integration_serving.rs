//! Integration: the continuous-batching serving pipeline end to end —
//! seeded load generator → batched coordinator → latency percentiles —
//! plus property tests (in-tree harness) for the admission/scheduling
//! invariants:
//!
//! * the same seed yields bit-identical token streams AND bit-identical
//!   latency percentiles across runs (virtual-time harness);
//! * the live threaded coordinator produces the same greedy streams as
//!   the virtual harness;
//! * admission never exceeds the KV budget (random configs/workloads);
//! * no admitted request starves under RoundRobin;
//! * chunked prefill changes step timing only — streams stay
//!   bit-identical to single-pass runs per seed, and a long prompt's
//!   interference on co-resident decode lanes shrinks.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_open_loop, run_virtual, run_virtual_plan, BackendFactory, Coordinator,
    CoordinatorConfig, HostTierConfig, KvPolicy, LenDist, PrefixCacheConfig, Request,
    RouterPolicy, SchedulerPolicy, StepModel, VirtualConfig, Workload,
};
use lpu::model::by_name;
use lpu::util::proptest::quick;

mod common;
use common::invariants;

fn step_model() -> StepModel {
    StepModel::from_config(&by_name("opt-1.3b").unwrap(), &LpuConfig::asic_3_28tbs(), 1)
}

fn workload(rate: f64, n: usize, seed: u64) -> Workload {
    Workload {
        model: "opt-tiny".into(),
        rate,
        n_requests: n,
        prompt_len: LenDist::Uniform(1, 12),
        output_len: LenDist::LongTail { min: 2, mean_extra: 10.0, cap: 48 },
        vocab: 512,
        seed,
    }
}

/// The tentpole acceptance test: run the seeded load generator through
/// the batched serving model twice; token streams and latency
/// percentiles must be bit-identical, and the worker must sustain >= 8
/// concurrent requests.
#[test]
fn serving_pipeline_is_deterministic_and_batches_deep() {
    let wl = workload(4000.0, 64, 0xD15EA5E);
    let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step_model());
    vc.kv_bytes_per_token = 64;
    vc.kv_budget_bytes = u64::MAX;

    let a = run_virtual(&wl, &vc).unwrap();
    let b = run_virtual(&wl, &vc).unwrap();

    // Bit-identical records AND latency percentiles, via the shared
    // invariant harness (f64 equality, not approximate: the harness is
    // a pure function of the seed).
    assert_eq!(a.records.len(), 64);
    invariants::assert_standing_contract(&a, &b, None);

    // The 1.3B step model is slow relative to a 4000 req/s offered
    // rate: the slot table must fill well past 8 concurrent requests.
    assert!(a.max_concurrent >= 8, "sustained concurrency {}", a.max_concurrent);
    // Percentile ordering is sane.
    assert!(a.ttft.p50 <= a.ttft.p95 && a.ttft.p95 <= a.ttft.p99);
    assert!(a.tpot.p50 <= a.tpot.p95 && a.tpot.p95 <= a.tpot.p99);
}

/// The live threaded coordinator (real threads, real channels) produces
/// identical greedy token streams across two runs of the same seeded
/// workload, and agrees with the virtual harness stream-for-stream.
#[test]
fn threaded_and_virtual_streams_agree() {
    let wl = workload(2000.0, 24, 77);

    let run_live = || {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 8,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
        let r = run_open_loop(&c, &wl).unwrap();
        c.shutdown();
        r
    };
    let live1 = run_live();
    let live2 = run_live();
    assert_eq!(live1.token_streams, live2.token_streams);
    assert_eq!(live1.completed, 24);

    let vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 8, step_model());
    let virt = run_virtual(&wl, &vc).unwrap();
    let rerun = run_virtual(&wl, &vc).unwrap();
    // Full standing contract: virtual rerun determinism + the threaded
    // path's streams matching the virtual run request-for-request.
    invariants::assert_standing_contract(&virt, &rerun, Some(&live1.token_streams));
}

/// Live batched coordinator under the seeded generator: every policy
/// serves the whole workload with percentile metrics populated.
#[test]
fn live_load_reports_percentiles_per_policy() {
    for policy in SchedulerPolicy::all() {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 8,
            policy,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        let r = run_open_loop(&c, &workload(3000.0, 30, 5)).unwrap();
        assert_eq!(r.completed, 30, "{policy:?}");
        assert!(r.ttft.p99 >= r.ttft.p50, "{policy:?}");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.completed, 30);
        assert!(snap.ttft.p99 >= snap.ttft.p50, "{policy:?}");
        assert!(snap.tpot.p99 > 0.0, "{policy:?}");
        assert!(snap.batch_steps > 0);
        c.shutdown();
    }
}

/// Property: admission never exceeds the KV budget, for random budgets,
/// request shapes, rates, and policies.
#[test]
fn prop_admission_never_exceeds_kv_budget() {
    quick("kv-admission-bounded", |rng| {
        let policy = *rng.choose(&SchedulerPolicy::all());
        let workers = rng.range(1, 4);
        let max_active = rng.range(1, 12);
        let mut vc = VirtualConfig::new(policy, workers, max_active, step_model());
        vc.kv_bytes_per_token = rng.range_u64(1, 2000);
        vc.kv_budget_bytes = rng.range_u64(1_000, 200_000);
        vc.max_batch = rng.range(0, max_active + 1);
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(100.0, 20_000.0),
            n_requests: rng.range(1, 24),
            prompt_len: LenDist::Uniform(1, rng.range(2, 20)),
            output_len: LenDist::Uniform(1, rng.range(2, 30)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let r = run_virtual(&wl, &vc)?;
        if r.peak_kv_reserved > vc.kv_budget_bytes {
            return Err(format!(
                "peak KV {} exceeded budget {}",
                r.peak_kv_reserved, vc.kv_budget_bytes
            ));
        }
        // Conservation: every request is either served or rejected.
        let served = r.records.iter().filter(|rec| !rec.tokens.is_empty()).count();
        if served + r.rejected != wl.n_requests {
            return Err(format!(
                "lost requests: served {served} + rejected {} != {}",
                r.rejected, wl.n_requests
            ));
        }
        Ok(())
    });
}

/// Property: under RoundRobin no admitted request starves — every
/// non-rejected request completes with exactly the tokens it asked for,
/// and its first token arrives within the run's makespan.
#[test]
fn prop_no_starvation_under_round_robin() {
    quick("rr-no-starvation", |rng| {
        let workers = rng.range(1, 3);
        let max_active = rng.range(2, 16);
        let mut vc =
            VirtualConfig::new(SchedulerPolicy::RoundRobin, workers, max_active, step_model());
        // A tight batch cap is the starvation-prone regime.
        vc.max_batch = rng.range(1, max_active.min(4));
        let n = rng.range(4, 32);
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(500.0, 50_000.0),
            n_requests: n,
            prompt_len: LenDist::Uniform(1, 8),
            output_len: LenDist::LongTail { min: 1, mean_extra: 15.0, cap: 64 },
            vocab: 128,
            seed: rng.next_u64(),
        };
        let r = run_virtual(&wl, &vc)?;
        if r.rejected != 0 {
            return Err(format!("unlimited budget rejected {} requests", r.rejected));
        }
        for rec in &r.records {
            if rec.tokens.is_empty() {
                return Err(format!("request {} starved (no tokens)", rec.request_id));
            }
            if rec.first_token_s < rec.arrival_s || rec.done_s > r.wall_s {
                return Err(format!(
                    "request {} has inconsistent timeline ({} .. {} vs wall {})",
                    rec.request_id, rec.first_token_s, rec.done_s, r.wall_s
                ));
            }
        }
        Ok(())
    });
}

// ---- paged KV (reserve-as-you-grow + preemption) ----

/// The engineered preemption cell: an 18-block pager (16-token blocks,
/// 288 tokens of KV) serving requests that each grow to 128 tokens
/// (8 blocks). Expected-footprint admission holds 3 concurrently
/// (3 × 5 expected blocks ≤ 18 < 4 × 5), but their concurrent growth
/// (3 × 8 = 24 blocks) must overshoot capacity, forcing the preemption
/// path. Worst-case reservation at the same budget holds only
/// ⌊288/128⌋ = 2.
fn preemption_cell(
    n_requests: usize,
    step: StepModel,
    kv_policy: KvPolicy,
) -> (Workload, VirtualConfig) {
    let wl = Workload {
        model: "opt-tiny".into(),
        rate: 100_000.0,
        n_requests,
        prompt_len: LenDist::Fixed(8),
        output_len: LenDist::Fixed(120),
        vocab: 512,
        seed: 0xFACE,
    };
    let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 16, step);
    vc.max_batch = 16;
    vc.kv_bytes_per_token = 100;
    vc.kv_budget_bytes = 288 * 100;
    vc.kv_policy = kv_policy;
    (wl, vc)
}

/// Paged runs are bit-identical per seed even when the preemption path
/// fires, and every preempted request still completes in full.
#[test]
fn paged_virtual_deterministic_across_preemption() {
    let (wl, vc) =
        preemption_cell(6, step_model(), KvPolicy::Paged { block_tokens: 16 });
    let a = run_virtual(&wl, &vc).unwrap();
    let b = run_virtual(&wl, &vc).unwrap();
    invariants::assert_standing_contract(&a, &b, None);
    assert_eq!(a.preemptions, b.preemptions);
    // The cell is engineered to overshoot the pager: growth must have
    // preempted at least once, and nobody may starve because of it.
    assert!(a.preemptions >= 1, "expected the preemption path to fire");
    assert_eq!(a.rejected, 0);
    assert!(a.records.iter().all(|rec| rec.tokens.len() == 120));
    assert_eq!(a.kv_capacity_blocks, 18);
    assert!(a.peak_kv_blocks <= a.kv_capacity_blocks);
}

/// The tentpole payoff: at the same KV budget, paged admission sustains
/// a materially deeper active batch than worst-case reservation, and
/// (with a weight-stream-dominated step) finishes the backlog faster.
#[test]
fn paged_outperforms_reserve_at_same_budget() {
    // opt-6.7b step costs: the 4-ms weight stream dominates per-lane
    // terms, so extra lanes convert almost fully into throughput.
    let step =
        StepModel::from_config(&by_name("opt-6.7b").unwrap(), &LpuConfig::asic_3_28tbs(), 1);
    let (wl, reserve_vc) = preemption_cell(9, step, KvPolicy::Reserve);
    let (_, paged_vc) = preemption_cell(9, step, KvPolicy::Paged { block_tokens: 16 });
    let res = run_virtual(&wl, &reserve_vc).unwrap();
    let pag = run_virtual(&wl, &paged_vc).unwrap();
    for r in [&res, &pag] {
        assert_eq!(r.rejected, 0);
        assert!(r.records.iter().all(|rec| rec.tokens.len() == 120));
    }
    assert_eq!(res.max_concurrent, 2, "worst-case reservation admits ⌊288/128⌋");
    assert!(
        pag.max_concurrent as f64 >= res.max_concurrent as f64 * 1.5,
        "paged peak active {} vs reserve {}",
        pag.max_concurrent,
        res.max_concurrent
    );
    assert!(
        pag.tokens_per_s >= res.tokens_per_s * 1.1,
        "paged tok/s {:.1} vs reserve {:.1}",
        pag.tokens_per_s,
        res.tokens_per_s
    );
    assert!(pag.wall_s < res.wall_s);
    assert_eq!(res.preemptions, 0, "reserve never preempts");
}

/// Property: the pager never exceeds its block capacity (nor the byte
/// budget), for random block sizes, budgets, shapes, and policies — and
/// no request is ever lost.
#[test]
fn prop_paged_blocks_never_exceed_budget() {
    quick("paged-kv-bounded", |rng| {
        let policy = *rng.choose(&SchedulerPolicy::all());
        let workers = rng.range(1, 3);
        let max_active = rng.range(1, 10);
        let block_tokens = rng.range(1, 24);
        let mut vc = VirtualConfig::new(policy, workers, max_active, step_model());
        vc.kv_bytes_per_token = rng.range_u64(1, 1500);
        vc.kv_budget_bytes = rng.range_u64(2_000, 150_000);
        vc.kv_policy = KvPolicy::Paged { block_tokens };
        vc.max_batch = rng.range(0, max_active + 1);
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(100.0, 20_000.0),
            n_requests: rng.range(1, 20),
            prompt_len: LenDist::Uniform(1, rng.range(2, 16)),
            output_len: LenDist::Uniform(1, rng.range(2, 24)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let r = run_virtual(&wl, &vc)?;
        if r.kv_capacity_blocks > 0 && r.peak_kv_blocks > r.kv_capacity_blocks {
            return Err(format!(
                "peak blocks {} > capacity {}",
                r.peak_kv_blocks, r.kv_capacity_blocks
            ));
        }
        if r.peak_kv_reserved > vc.kv_budget_bytes {
            return Err(format!(
                "peak KV bytes {} > budget {}",
                r.peak_kv_reserved, vc.kv_budget_bytes
            ));
        }
        let served = r.records.iter().filter(|rec| !rec.tokens.is_empty()).count();
        if served + r.rejected != wl.n_requests {
            return Err(format!(
                "lost requests: served {served} + rejected {} != {}",
                r.rejected, wl.n_requests
            ));
        }
        Ok(())
    });
}

/// Property: under tight paged budgets (preemption-prone regime), every
/// admitted request completes in full and its token stream is identical
/// to an unbounded run's — recompute-on-readmit never corrupts or
/// starves a stream.
#[test]
fn prop_paged_preemption_preserves_streams_and_completes() {
    quick("paged-preemption-completes", |rng| {
        let max_active = rng.range(3, 10);
        let block_tokens = rng.range(2, 10);
        let out = rng.range(16, 48);
        let mut vc =
            VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, max_active, step_model());
        vc.kv_bytes_per_token = 10;
        // Room for roughly 1.5–3 worst-case requests: tight enough to
        // preempt, loose enough that every request can complete alone.
        let budget_tokens = (out + 4) * rng.range(3, 6) / 2;
        vc.kv_budget_bytes = budget_tokens as u64 * 10;
        vc.kv_policy = KvPolicy::Paged { block_tokens };
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: 50_000.0,
            n_requests: rng.range(4, 12),
            prompt_len: LenDist::Uniform(1, 4),
            output_len: LenDist::Fixed(out),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let r = run_virtual(&wl, &vc)?;
        let mut unbounded_vc = vc.clone();
        unbounded_vc.kv_budget_bytes = u64::MAX;
        let free = run_virtual(&wl, &unbounded_vc)?;
        for (a, b) in r.records.iter().zip(&free.records) {
            if a.tokens.is_empty() {
                continue; // rejected-as-impossible under the tight budget
            }
            if a.tokens.len() != out {
                return Err(format!(
                    "request {} incomplete: {} of {out} tokens",
                    a.request_id,
                    a.tokens.len()
                ));
            }
            if a.tokens != b.tokens {
                return Err(format!(
                    "request {} stream corrupted by preemption",
                    a.request_id
                ));
            }
        }
        Ok(())
    });
}

// ---- prefix cache (shared blocks + prefill skip) ----

/// Property: per-seed token streams are bit-identical with the prefix
/// cache on vs off — including under paged preemption (tight budgets)
/// and chunked prefill — and rejection decisions do not change. The
/// workloads share prefixes by construction (a common prefix grafted
/// onto every prompt) so the cache path actually fires.
#[test]
fn prop_prefix_cache_streams_bit_identical() {
    quick("prefix-cache-streams", |rng| {
        let policy = *rng.choose(&SchedulerPolicy::all());
        let workers = rng.range(1, 3);
        let max_active = rng.range(2, 10);
        let block_tokens = rng.range(2, 17);
        let mut base = VirtualConfig::new(policy, workers, max_active, step_model());
        base.max_batch = rng.range(0, max_active + 1);
        base.kv_bytes_per_token = 100;
        base.kv_policy = KvPolicy::Paged { block_tokens };
        // Tight-but-feasible budget: every request (prompt <= 48 + out
        // <= 24 = 72 tokens max) can still complete alone; tight cells
        // exercise preemption with shared blocks in play.
        base.kv_budget_bytes = rng.range_u64(10_000, 60_000);
        if rng.bool(0.3) {
            base.prefill_chunk = rng.range(1, 33);
        }
        let shared_prefix_len = rng.range(1, 33);
        let shared_prefix: Vec<i64> =
            (0..shared_prefix_len).map(|_| rng.range(0, 128) as i64).collect();
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(200.0, 20_000.0),
            n_requests: rng.range(2, 14),
            prompt_len: LenDist::Uniform(1, rng.range(2, 16)),
            output_len: LenDist::Uniform(1, rng.range(2, 24)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let plan: Vec<(f64, Request)> = wl
            .generate()
            .into_iter()
            .map(|(at, mut req)| {
                // Graft the shared prefix onto every prompt so block
                // sharing genuinely occurs.
                let mut prompt = shared_prefix.clone();
                prompt.extend_from_slice(&req.prompt);
                req.prompt = prompt;
                (at.as_secs_f64(), req)
            })
            .collect();
        let off = run_virtual_plan(&wl.model, wl.vocab, wl.rate, plan.clone(), &base)?;
        let mut on_vc = base.clone();
        on_vc.prefix_cache = PrefixCacheConfig::on();
        let on = run_virtual_plan(&wl.model, wl.vocab, wl.rate, plan, &on_vc)?;
        invariants::well_formed(&on)?;
        invariants::streams_identical(
            &off,
            &on,
            &format!("the prefix cache (block {block_tokens})"),
        )
    });
}

/// Property (host KV tier): token streams are bit-identical with the
/// host tier on vs off — across random paged configs with tight
/// budgets (so preemption genuinely demotes blocks), random host pool
/// capacities, and optionally chunked prefill or the prefix cache in
/// play — and rejection decisions do not change. Restore replays the
/// exact positions recompute would refeed, so greedy streams cannot
/// diverge no matter which side of the restore-vs-recompute decision
/// each readmission lands on.
#[test]
fn prop_kv_tier_streams_bit_identical() {
    quick("kv-tier-streams", |rng| {
        let policy = *rng.choose(&SchedulerPolicy::all());
        let workers = rng.range(1, 3);
        let max_active = rng.range(2, 10);
        let block_tokens = rng.range(2, 17);
        let mut base = VirtualConfig::new(policy, workers, max_active, step_model());
        base.max_batch = rng.range(0, max_active + 1);
        base.kv_bytes_per_token = 100;
        base.kv_policy = KvPolicy::Paged { block_tokens };
        // Tight-but-feasible budget (every request fits alone; see
        // prop_prefix_cache_streams_bit_identical) so preemption fires
        // and readmissions actually consult the host tier.
        base.kv_budget_bytes = rng.range_u64(10_000, 60_000);
        if rng.bool(0.3) {
            base.prefill_chunk = rng.range(1, 33);
        }
        if rng.bool(0.3) {
            base.prefix_cache = PrefixCacheConfig::on();
        }
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(200.0, 20_000.0),
            n_requests: rng.range(2, 14),
            prompt_len: LenDist::Uniform(1, rng.range(2, 16)),
            output_len: LenDist::Uniform(1, rng.range(2, 24)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let plan: Vec<(f64, Request)> = wl
            .generate()
            .into_iter()
            .map(|(at, req)| (at.as_secs_f64(), req))
            .collect();
        let off = run_virtual_plan(&wl.model, wl.vocab, wl.rate, plan.clone(), &base)?;
        let mut on_vc = base.clone();
        // Cheap restore term so the cost model prefers restore when a
        // demoted lane comes back; streams must not care either way.
        let mut sm = step_model();
        sm.host_restore_s_per_token = 1e-8;
        on_vc.host_tier = HostTierConfig::from_step(&sm, rng.range(1, 48));
        let on = run_virtual_plan(&wl.model, wl.vocab, wl.rate, plan, &on_vc)?;
        invariants::well_formed(&on)?;
        invariants::streams_identical(
            &off,
            &on,
            &format!(
                "the host tier (block {block_tokens}, cap {})",
                on_vc.host_tier.capacity_blocks
            ),
        )
    });
}

/// Property: with sharing enabled, physical `blocks_in_use` never
/// exceeds `capacity_blocks` (nor the byte budget), and no request is
/// lost — for random budgets, block sizes, cache capacities, and
/// shared-prefix workloads.
#[test]
fn prop_prefix_sharing_blocks_never_exceed_capacity() {
    quick("prefix-sharing-bounded", |rng| {
        let policy = *rng.choose(&SchedulerPolicy::all());
        let workers = rng.range(1, 3);
        let max_active = rng.range(1, 10);
        let block_tokens = rng.range(1, 24);
        let mut vc = VirtualConfig::new(policy, workers, max_active, step_model());
        vc.kv_bytes_per_token = rng.range_u64(1, 1500);
        vc.kv_budget_bytes = rng.range_u64(2_000, 150_000);
        vc.kv_policy = KvPolicy::Paged { block_tokens };
        vc.prefix_cache = if rng.bool(0.5) {
            PrefixCacheConfig::on()
        } else {
            PrefixCacheConfig { enabled: true, capacity_blocks: rng.range(1, 32) }
        };
        vc.max_batch = rng.range(0, max_active + 1);
        let shared_prefix: Vec<i64> =
            (0..rng.range(1, 24)).map(|_| rng.range(0, 128) as i64).collect();
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(100.0, 20_000.0),
            n_requests: rng.range(1, 16),
            prompt_len: LenDist::Uniform(1, rng.range(2, 12)),
            output_len: LenDist::Uniform(1, rng.range(2, 24)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let plan: Vec<(f64, Request)> = wl
            .generate()
            .into_iter()
            .map(|(at, mut req)| {
                let mut prompt = shared_prefix.clone();
                prompt.extend_from_slice(&req.prompt);
                req.prompt = prompt;
                (at.as_secs_f64(), req)
            })
            .collect();
        let r = run_virtual_plan(&wl.model, wl.vocab, wl.rate, plan, &vc)?;
        if r.kv_capacity_blocks > 0 && r.peak_kv_blocks > r.kv_capacity_blocks {
            return Err(format!(
                "peak blocks {} > capacity {} with sharing enabled",
                r.peak_kv_blocks, r.kv_capacity_blocks
            ));
        }
        if r.peak_kv_reserved > vc.kv_budget_bytes {
            return Err(format!(
                "peak KV bytes {} > budget {}",
                r.peak_kv_reserved, vc.kv_budget_bytes
            ));
        }
        let served = r.records.iter().filter(|rec| !rec.tokens.is_empty()).count();
        if served + r.rejected != wl.n_requests {
            return Err(format!(
                "lost requests: served {served} + rejected {} != {}",
                r.rejected, wl.n_requests
            ));
        }
        Ok(())
    });
}

// ---- affinity routing ----

/// Property (ISSUE 5 acceptance): under `prefix-affinity` routing,
/// every request completes even when ALL prefixes map to one worker —
/// the adversarial case where affinity steers the whole workload at a
/// single queue. The imbalance bound at routing plus idle siblings
/// stealing past the spill bound must keep the pool work-conserving;
/// random worker counts, slot limits, budgets, and arrival rates probe
/// for a schedule where a steered request starves.
#[test]
fn prop_affinity_routing_never_starves_hot_prefix_workloads() {
    quick("router-affinity-no-starvation", |rng| {
        let workers = rng.range(2, 5);
        let max_active = rng.range(1, 4); // tight slots: the hot worker saturates
        let block_tokens = rng.range(2, 17);
        let mut vc =
            VirtualConfig::new(SchedulerPolicy::RoundRobin, workers, max_active, step_model());
        vc.max_batch = rng.range(0, max_active + 1);
        vc.kv_bytes_per_token = 100;
        // Generous budget: nothing is rejected, so every request must
        // actually be served somewhere.
        vc.kv_budget_bytes = 4096 * 100;
        vc.kv_policy = KvPolicy::Paged { block_tokens };
        vc.prefix_cache = PrefixCacheConfig::on();
        vc.router = RouterPolicy::PrefixAffinity;
        // Every prompt is the SAME shared prefix plus a short tail, so
        // once the first request registers, every later one steers to
        // that worker.
        let shared_prefix: Vec<i64> =
            (0..rng.range(8, 49)).map(|_| rng.range(0, 128) as i64).collect();
        let out = rng.range(2, 16);
        let n = rng.range(4, 20);
        let mut plan =
            vec![(0.0, Request::greedy("opt-tiny", shared_prefix.clone(), out))];
        // The cold request registers during the warmup gap; the flood
        // then arrives in a burst (non-decreasing arrival times).
        let mut at = 0.5;
        for _ in 1..n {
            at += rng.range_f64(0.0, 0.002);
            let mut prompt = shared_prefix.clone();
            prompt.push(rng.range(0, 128) as i64);
            plan.push((at, Request::greedy("opt-tiny", prompt, out)));
        }
        let r = run_virtual_plan("opt-tiny", 128, 1.0, plan, &vc)?;
        if r.rejected != 0 {
            return Err(format!("generous budget rejected {} requests", r.rejected));
        }
        for rec in &r.records {
            if rec.tokens.len() != out {
                return Err(format!(
                    "request {} starved under prefix-affinity: {} of {out} tokens \
                     (workers {workers}, max_active {max_active})",
                    rec.request_id,
                    rec.tokens.len()
                ));
            }
        }
        Ok(())
    });
}

/// Routing is placement-only: for any policy and workload, per-seed
/// token streams match the round-robin run's exactly (virtual path; the
/// bench asserts the same on the threaded path).
#[test]
fn prop_router_policies_stream_identical() {
    quick("router-streams-identical", |rng| {
        let workers = rng.range(1, 4);
        let max_active = rng.range(2, 8);
        let mut base = VirtualConfig::new(
            *rng.choose(&SchedulerPolicy::all()),
            workers,
            max_active,
            step_model(),
        );
        base.max_batch = rng.range(0, max_active + 1);
        if rng.bool(0.5) {
            base.kv_bytes_per_token = 100;
            base.kv_budget_bytes = rng.range_u64(10_000, 80_000);
            base.kv_policy = KvPolicy::Paged { block_tokens: rng.range(2, 17) };
            if rng.bool(0.5) {
                base.prefix_cache = PrefixCacheConfig::on();
            }
        }
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(200.0, 20_000.0),
            n_requests: rng.range(2, 16),
            prompt_len: LenDist::Uniform(1, rng.range(2, 24)),
            output_len: LenDist::Uniform(1, rng.range(2, 20)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let policies = RouterPolicy::all();
        let mut runs = policies.iter().map(|&router| {
            let mut vc = base.clone();
            vc.router = router;
            run_virtual(&wl, &vc)
        });
        let baseline = runs.next().expect("round-robin run")?;
        for run in runs {
            let r = run?;
            invariants::streams_identical(
                &baseline,
                &r,
                &format!("{:?} routing", r.router_policy),
            )?;
        }
        Ok(())
    });
}

// ---- chunked prefill ----

/// Property: chunked-prefill streams are bit-identical to unchunked
/// (single-pass) streams per seed, for random policies, budgets, and
/// chunk sizes — including under paged preemption. Chunking changes
/// step composition and timing only.
#[test]
fn prop_chunked_prefill_streams_bit_identical() {
    quick("chunked-prefill-streams", |rng| {
        let policy = *rng.choose(&SchedulerPolicy::all());
        let workers = rng.range(1, 3);
        let max_active = rng.range(2, 10);
        let mut base = VirtualConfig::new(policy, workers, max_active, step_model());
        base.max_batch = rng.range(0, max_active + 1);
        if rng.bool(0.5) {
            // Tight-but-feasible budget: every request (prompt <= 40 +
            // out <= 24 = 64 tokens max) can still complete alone.
            base.kv_bytes_per_token = 100;
            base.kv_budget_bytes = rng.range_u64(8_000, 60_000);
            if rng.bool(0.5) {
                base.kv_policy = KvPolicy::Paged { block_tokens: rng.range(2, 17) };
            }
        }
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(200.0, 20_000.0),
            n_requests: rng.range(2, 16),
            prompt_len: LenDist::Uniform(1, rng.range(2, 40)),
            output_len: LenDist::Uniform(1, rng.range(2, 24)),
            vocab: 128,
            seed: rng.next_u64(),
        };
        let single = run_virtual(&wl, &base)?;
        let mut chunked_vc = base.clone();
        chunked_vc.prefill_chunk = rng.range(1, 33);
        let chunked = run_virtual(&wl, &chunked_vc)?;
        invariants::well_formed(&chunked)?;
        invariants::streams_identical(
            &single,
            &chunked,
            &format!("chunking (chunk {})", chunked_vc.prefill_chunk),
        )
    });
}

/// Integration mirror of the bench's interference cell: a long prompt
/// landing among active decode lanes. Single-pass prefill sweeps the
/// whole prompt in one fused step, so every neighbor absorbs the sweep
/// in one inter-token gap; a 32-token chunk budget must strictly shrink
/// the neighbors' worst gap while streams stay identical and the long
/// prompt's TTFT stays within a small factor.
#[test]
fn chunked_prefill_cuts_neighbor_interference() {
    let mk_plan = || {
        let mut plan: Vec<(f64, Request)> = (0..4)
            .map(|i| (0.0, Request::greedy("opt-tiny", vec![i as i64 + 1], 48)))
            .collect();
        // Lands mid-run, while all four neighbors are decoding.
        plan.push((0.05, Request::greedy("opt-tiny", vec![9; 768], 4)));
        plan
    };
    let run = |chunk: usize| {
        let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 8, step_model());
        vc.prefill_chunk = chunk;
        run_virtual_plan("opt-tiny", 512, 1.0, mk_plan(), &vc).unwrap()
    };
    let single = run(0);
    let chunked = run(32);
    for (a, b) in single.records.iter().zip(&chunked.records) {
        assert_eq!(a.tokens, b.tokens, "chunking changed request {}", a.request_id);
    }
    let neighbor_worst_gap = |r: &lpu::coordinator::VirtualReport| -> f64 {
        (0..4)
            .flat_map(|i| {
                r.records[i].token_times.windows(2).map(|w| w[1] - w[0]).collect::<Vec<_>>()
            })
            .fold(0.0, f64::max)
    };
    let single_gap = neighbor_worst_gap(&single);
    let chunked_gap = neighbor_worst_gap(&chunked);
    assert!(
        chunked_gap < single_gap,
        "chunked neighbor worst gap {chunked_gap} !< single-pass {single_gap}"
    );
    let ttft = |r: &lpu::coordinator::VirtualReport| {
        r.records[4].first_token_s - r.records[4].arrival_s
    };
    assert!(
        ttft(&chunked) < ttft(&single) * 5.0,
        "chunked long-prompt TTFT {} vs single-pass {} exceeds the 5x bound",
        ttft(&chunked),
        ttft(&single)
    );
}

/// KV-bounded live serving: a coordinator sized from a real device
/// config (LpuConfig + ModelConfig) admits, throttles, and completes a
/// burst without losing requests.
#[test]
fn device_derived_admission_serves_burst() {
    let device = LpuConfig::fpga_u55c();
    let model = by_name("opt-tiny").unwrap();
    let mut cfg = CoordinatorConfig::for_device(&device, &model, SchedulerPolicy::RoundRobin);
    // Shrink the budget so admission control actually bites: room for
    // ~3 worst-case requests of 24 tokens each.
    cfg.kv_budget_bytes = 3 * 24 * cfg.kv_bytes_per_token;
    let mut c = Coordinator::new(cfg);
    c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
    let handles: Vec<_> = (0..12)
        .map(|i| {
            c.submit(lpu::coordinator::Request::greedy("opt-tiny", vec![i as i64 + 1], 16))
                .unwrap()
        })
        .collect();
    for h in handles {
        assert_eq!(h.wait().unwrap().len(), 16);
    }
    let snap = c.metrics.snapshot();
    assert_eq!(snap.completed, 12);
    assert_eq!(snap.rejected, 0);
    c.shutdown();
}
