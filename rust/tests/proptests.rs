//! Cross-module property tests (in-tree harness: `lpu::util::proptest`).
//!
//! Invariants:
//! * random well-formed MEM/COMP straight-line programs always simulate
//!   to completion, and timing is monotone under instruction insertion;
//! * the HyperDex pipeline (map → instgen → regalloc → chain-verify)
//!   holds its invariants for random (model, devices, position, mode);
//! * JSON round-trips arbitrary generated documents;
//! * the sampler's support respects top-k under random logits;
//! * mapper regions stay disjoint (delegated check, random configs).

use lpu::compiler::{compile, CompileError, CompileOpts, ParallelMode};
use lpu::config::LpuConfig;
use lpu::isa::asm::assemble;
use lpu::model::by_name;
use lpu::numerics::{SampleParams, Sampler};
use lpu::sim::CoreSim;
use lpu::util::json::{Json, JsonObj};
use lpu::util::proptest::{quick, Config};
use lpu::util::rng::Rng;

/// Generate a random well-formed straight-line program (stream
/// discipline respected) as asm text; return (text, instr count).
fn random_program(rng: &mut Rng) -> String {
    let mut src = String::new();
    let n_ops = rng.range(1, 30);
    for _ in 0..n_ops {
        match rng.range(0, 5) {
            0 => {
                let len = rng.range(64, 100_000);
                let k = 64 * rng.range(1, 16);
                let n = rng.range(1, 256);
                src.push_str(&format!("read.params 0x0, len={len}\n"));
                src.push_str(&format!("matmul v1 -> v2, k={k}, n={n}\n"));
            }
            1 => {
                let len = rng.range(1, 8192);
                src.push_str(&format!("vec.add v1, v2 -> v3, len={len}\n"));
            }
            2 => {
                let len = rng.range(1, 4096);
                src.push_str(&format!("fused.scale_softmax v2, v2 -> v4, len={len}\n"));
            }
            3 => {
                let len = rng.range(1, 65536);
                src.push_str(&format!("write.kv 0x100, len={len}\n"));
            }
            _ => {
                let len = rng.range(64, 8192);
                src.push_str(&format!(
                    "matmul v1 -> v5, k=64, n={}, lmu\nsample v5 -> v6, len={len}\n",
                    rng.range(1, 128)
                ));
            }
        }
    }
    src.push_str("halt\n");
    src
}

#[test]
fn prop_random_programs_simulate_to_completion() {
    quick("random-programs-halt", |rng| {
        let src = random_program(rng);
        let prog = assemble(&src).map_err(|e| format!("asm: {e}\n{src}"))?;
        let mut sim = CoreSim::new(&LpuConfig::asic_3_28tbs());
        let stats = sim.run(&prog).map_err(|e| format!("sim: {e}"))?;
        if stats.cycles == 0 && prog.len() > 1 {
            return Err("zero cycles for nonempty program".into());
        }
        if stats.bandwidth_util() > 1.0 {
            return Err(format!("utilization {} > 1", stats.bandwidth_util()));
        }
        Ok(())
    });
}

#[test]
fn prop_adding_work_never_reduces_cycles() {
    quick("sim-monotone", |rng| {
        let base_src = random_program(rng);
        let extra = "read.params 0x0, len=100000\nmatmul v1 -> v2, k=64, n=64\nhalt\n";
        let extended = format!("{}{}", base_src.trim_end_matches("halt\n"), extra);
        let mut sim = CoreSim::new(&LpuConfig::asic_3_28tbs());
        let a = sim.run(&assemble(&base_src).unwrap()).map_err(|e| e.to_string())?;
        let b = sim.run(&assemble(&extended).unwrap()).map_err(|e| e.to_string())?;
        if b.cycles >= a.cycles {
            Ok(())
        } else {
            Err(format!("extended program faster: {} < {}", b.cycles, a.cycles))
        }
    });
}

#[test]
fn prop_compiler_pipeline_invariants() {
    let models = ["opt-tiny", "opt-mini", "opt-125m", "opt-350m"];
    quick("compiler-pipeline", |rng| {
        let model = by_name(models[rng.range(0, models.len())]).unwrap();
        let cfg = if rng.bool(0.5) { LpuConfig::asic_819gbs() } else { LpuConfig::fpga_u55c() };
        let mode = match rng.range(0, 3) {
            0 => ParallelMode::Single,
            1 => ParallelMode::Batch { batch: rng.range(2, 5) },
            _ => ParallelMode::MultiToken { tokens: rng.range(2, 9) },
        };
        let opts = CompileOpts {
            n_devices: 1 << rng.range(0, 3),
            position: rng.range(0, model.max_seq / 2),
            esl_overlap: rng.bool(0.5),
            mode,
            sxe_sets: rng.range(1, 4),
        };
        match compile(&model, &cfg, &opts) {
            Ok(c) => {
                // chain-verified inside compile(); additionally:
                if c.stats.peak_live_regs > 64 {
                    return Err(format!("{}: peak regs {}", model.name, c.stats.peak_live_regs));
                }
                if !matches!(c.program.instrs.last(), Some(lpu::isa::Instr::Halt)) {
                    return Err("missing halt".into());
                }
                // Simulate it, too: compiled programs must always run.
                let mut sim = CoreSim::new(&cfg);
                sim.run(&c.program).map_err(|e| format!("{}: {e}", model.name))?;
                Ok(())
            }
            Err(CompileError::BadPartition { .. }) | Err(CompileError::OutOfMemory { .. }) => Ok(()),
            Err(e) => Err(format!("{}: {e}", model.name)),
        }
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bool(0.5)),
        2 => Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
        3 => {
            let n = rng.range(0, 12);
            Json::Str((0..n).map(|_| *rng.choose(&['a', 'é', '"', '\\', '\n', '7', '中'])).collect())
        }
        4 => Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut o = JsonObj::new();
            for i in 0..rng.range(0, 5) {
                o.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(o)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    lpu::util::proptest::check("json-roundtrip", Config { cases: 512, ..Default::default() }, |rng| {
        let v = random_json(rng, 4);
        for text in [v.to_string(), v.to_string_pretty()] {
            let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
            if back != v {
                return Err(format!("roundtrip mismatch: {v} -> {text} -> {back}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sampler_respects_topk_support() {
    quick("sampler-topk", |rng| {
        let vocab = rng.range(4, 200);
        let logits: Vec<f32> = (0..vocab).map(|_| rng.f32() * 10.0 - 5.0).collect();
        let k = rng.range(1, vocab);
        let p = SampleParams::sampled(rng.range_f64(0.2, 3.0) as f32, k, 1.0);
        let mut sampler = Sampler::new(rng.next_u64());
        // The sampled token must be among the k largest logits.
        let mut ranked: Vec<usize> = (0..vocab).collect();
        ranked.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let allowed = &ranked[..k];
        for _ in 0..16 {
            let t = sampler.sample(&logits, &p);
            if !allowed.contains(&t) {
                return Err(format!("token {t} outside top-{k}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fp16_roundtrip_via_f32_stable() {
    quick("fp16-double-roundtrip", |rng| {
        // f32 -> f16 -> f32 -> f16 must be a fixed point after one hop.
        let x = (rng.f32() - 0.5) * 1e5;
        let h1 = lpu::numerics::F16::from_f32(x);
        let h2 = lpu::numerics::F16::from_f32(h1.to_f32());
        if h1 == h2 { Ok(()) } else { Err(format!("{x}: {:04x} != {:04x}", h1.0, h2.0)) }
    });
}
