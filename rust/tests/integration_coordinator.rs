//! Integration: the serving coordinator under realistic load — Poisson
//! arrivals, mixed lengths, multiple pools — with conservation checks.

use lpu::coordinator::{
    BackendFactory, Coordinator, CoordinatorConfig, Request, SchedulerPolicy,
};
use lpu::numerics::SampleParams;
use lpu::util::rng::Rng;

fn coord(policy: SchedulerPolicy, workers: usize, max_active: usize) -> Coordinator {
    let mut c = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: max_active,
        policy,
        ..CoordinatorConfig::default()
    });
    c.add_pool("opt-tiny", workers, BackendFactory::sim("opt-tiny", 512));
    c
}

/// Every submitted request completes with exactly the tokens it asked
/// for (conservation under concurrency).
#[test]
fn poisson_load_conserves_requests() {
    let c = coord(SchedulerPolicy::RoundRobin, 3, 4);
    let mut rng = Rng::new(42);
    let mut handles = Vec::new();
    let mut expected_tokens = 0usize;
    for i in 0..40 {
        let len = rng.range(1, 12);
        let n = rng.range(1, 10);
        expected_tokens += n;
        let prompt: Vec<i64> = (0..len).map(|j| (i * 31 + j) as i64 % 512).collect();
        handles.push((n, c.submit(Request::greedy("opt-tiny", prompt, n)).unwrap()));
        // Poisson-ish arrival jitter.
        if rng.bool(0.3) {
            std::thread::sleep(std::time::Duration::from_micros(rng.range_u64(10, 500)));
        }
    }
    for (n, h) in handles {
        let toks = h.wait().unwrap();
        assert_eq!(toks.len(), n);
    }
    let snap = c.metrics.snapshot();
    assert_eq!(snap.submitted, 40);
    assert_eq!(snap.completed, 40);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.tokens_out as usize, expected_tokens);
    c.shutdown();
}

/// Sampled generation is reproducible for a fixed seed and differs
/// across seeds.
#[test]
fn sampled_generation_seeded() {
    let c = coord(SchedulerPolicy::Fcfs, 1, 1);
    let mk = |seed| Request {
        model: "opt-tiny".into(),
        prompt: vec![1, 2, 3],
        max_new_tokens: 12,
        params: SampleParams::sampled(1.0, 50, 0.95),
        eos_token: None,
        seed,
    };
    // NOTE: request_id is XORed into the sampler seed, so identical
    // seeds give identical streams only via explicit seed choice that
    // compensates — here we assert the weaker, still-useful property:
    // different seeds explore different continuations.
    let a = c.submit(mk(7)).unwrap().wait().unwrap();
    let b = c.submit(mk(999)).unwrap().wait().unwrap();
    assert_eq!(a.len(), 12);
    assert_eq!(b.len(), 12);
    assert_ne!(a, b, "different seeds should diverge");
    c.shutdown();
}

/// Two pools route independently; cross-model traffic never mixes.
#[test]
fn multi_model_routing() {
    let mut c = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 2,
        policy: SchedulerPolicy::RoundRobin,
        ..CoordinatorConfig::default()
    });
    c.add_pool("model-a", 1, BackendFactory::sim("model-a", 64));
    c.add_pool("model-b", 1, BackendFactory::sim("model-b", 64));
    let a = c.submit(Request::greedy("model-a", vec![5], 8)).unwrap().wait().unwrap();
    let b = c.submit(Request::greedy("model-b", vec![5], 8)).unwrap().wait().unwrap();
    // Same prompt, different models -> different deterministic streams.
    assert_ne!(a, b);
    assert_eq!(c.models(), vec!["model-a".to_string(), "model-b".to_string()]);
    c.shutdown();
}

/// FCFS vs round-robin: under concurrent load with the hardware batch
/// capped below the slot count (so policy decides which lane advances),
/// round-robin must give the later request a *much* earlier completion.
#[test]
fn round_robin_improves_ttft_fairness() {
    let ttft_rank = |policy| {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 2,
            policy,
            max_batch: 1,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
        // Long request first, short request right after. Long enough
        // that FCFS (batch cap 1) holds the short request back for a
        // clearly measurable stretch.
        let long = c.submit(Request::greedy("opt-tiny", vec![1], 20_000)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let short = c.submit(Request::greedy("opt-tiny", vec![2], 3)).unwrap();
        let t0 = std::time::Instant::now();
        let _ = short.wait().unwrap();
        let short_done = t0.elapsed();
        let _ = long.wait().unwrap();
        c.shutdown();
        short_done
    };
    let fcfs = ttft_rank(SchedulerPolicy::Fcfs);
    let rr = ttft_rank(SchedulerPolicy::RoundRobin);
    assert!(
        rr < fcfs,
        "round-robin short-request completion {rr:?} should beat FCFS {fcfs:?}"
    );
}

/// Metrics latency fields are populated and ordered sensibly.
#[test]
fn metrics_fields_sane() {
    let c = coord(SchedulerPolicy::RoundRobin, 2, 2);
    for _ in 0..6 {
        c.submit(Request::greedy("opt-tiny", vec![1, 2, 3, 4], 10)).unwrap().wait().unwrap();
    }
    let s = c.metrics.snapshot();
    assert!(s.mean_token_latency_s > 0.0);
    assert!(s.mean_ttft_s >= s.mean_queue_delay_s);
    assert!(s.mean_request_latency_s >= s.mean_ttft_s);
    c.shutdown();
}
