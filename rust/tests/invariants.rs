//! The invariant harness, exercised directly: first against reports we
//! deliberately corrupt (the harness must actually catch contract
//! violations, not just bless clean runs), then as the acceptance
//! surface for the cluster tier — virtual fleet runs, threaded fleet
//! runs, and the `cluster-slo-streams` property:
//!
//! * every stream a fleet completes is bit-identical to a
//!   single-replica, no-shed, no-autoscale run of the same seed —
//!   replica count, tier mix, shedding, and autoscaling are
//!   placement/admission features, never token features;
//! * shed happens at admission or never: a shed request has zero
//!   tokens (no mid-stream drops);
//! * the threaded dispatcher's streams match the virtual fleet's,
//!   request for request, because both share one front-end core;
//! * chaos: replica crashes, partitions, hedged duplicates, and the
//!   pool-level fault plan applied per replica leave every completed
//!   stream bit-identical to the fault-free run, deliver each token
//!   exactly once, leak zero KV blocks fleet-wide, and recover
//!   rerun-identically — on the virtual AND threaded paths.

use lpu::config::LpuConfig;
use lpu::coordinator::{
    run_cluster_open_loop, run_open_loop, run_virtual, run_virtual_cluster,
    run_virtual_cluster_plan, run_virtual_plan, ArrivalTrace, AutoscaleConfig,
    BackendFactory, Cluster, ClusterConfig, ClusterFaultPlan, ClusterWorkload,
    Coordinator, CoordinatorConfig, FaultPlan, LenDist, PartitionSpec, ReplicaCrashSpec,
    ReplicaSlowSpec, Request, SchedulerPolicy, SpanEvent, StepModel, TraceEvent,
    VirtualConfig, Workload,
};
use lpu::model::by_name;
use lpu::util::proptest::{check, quick, Config};

mod common;
use common::invariants;

fn step_model() -> StepModel {
    StepModel::from_config(&by_name("opt-1.3b").unwrap(), &LpuConfig::asic_819gbs(), 1)
}

fn cwl(
    rate: f64,
    n: usize,
    frac: f64,
    deadline: f64,
    trace: ArrivalTrace,
    seed: u64,
) -> ClusterWorkload {
    ClusterWorkload {
        base: Workload {
            model: "opt-tiny".into(),
            rate,
            n_requests: n,
            prompt_len: LenDist::Uniform(1, 8),
            output_len: LenDist::Fixed(5),
            vocab: 512,
            seed,
        },
        trace,
        interactive_fraction: frac,
        interactive_deadline_s: deadline,
    }
}

/// Strip deadlines from a plan so the baseline pool neither sheds nor
/// expires anything — pure token-stream ground truth.
fn strip_deadlines(plan: &[(f64, Request)]) -> Vec<(f64, Request)> {
    plan.iter()
        .map(|(t, r)| (*t, Request { deadline_s: None, ..r.clone() }))
        .collect()
}

/// The harness must flag corrupted reports, not just pass clean ones:
/// KV leaks, lost requests, backwards token times, and broken
/// timelines all produce errors.
#[test]
fn harness_rejects_corrupted_pool_reports() {
    let wl = Workload {
        model: "opt-tiny".into(),
        rate: 500.0,
        n_requests: 16,
        prompt_len: LenDist::Uniform(1, 8),
        output_len: LenDist::Fixed(4),
        vocab: 512,
        seed: 9,
    };
    let vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model());
    let clean = run_virtual(&wl, &vc).unwrap();
    invariants::require(invariants::well_formed(&clean));

    let mut leak = clean.clone();
    leak.end_kv_blocks_in_use = 3;
    assert!(invariants::well_formed(&leak).unwrap_err().contains("KV leak"));

    let mut dup = clean.clone();
    dup.records[1].request_id = 0;
    assert!(invariants::well_formed(&dup).unwrap_err().contains("duplicate"));

    let mut backwards = clean.clone();
    let last = *backwards.records[0].token_times.last().unwrap();
    backwards.records[0].token_times[0] = last + 1.0;
    assert!(invariants::well_formed(&backwards).is_err());

    let mut late = clean.clone();
    late.records[0].done_s = clean.wall_s + 1.0;
    assert!(invariants::well_formed(&late).unwrap_err().contains("timeline"));
}

/// Rerun- and cross-path checks must flag a single diverging token or
/// percentile, and shifted stream assignments between paths.
#[test]
fn harness_rejects_diverging_streams() {
    let wl = Workload {
        model: "opt-tiny".into(),
        rate: 500.0,
        n_requests: 12,
        prompt_len: LenDist::Uniform(1, 8),
        output_len: LenDist::Fixed(4),
        vocab: 512,
        seed: 10,
    };
    let vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model());
    let a = run_virtual(&wl, &vc).unwrap();

    let mut flipped = a.clone();
    flipped.records[2].tokens[0] ^= 1;
    assert!(invariants::rerun_deterministic(&a, &flipped)
        .unwrap_err()
        .contains("request 2"));
    assert!(invariants::streams_identical(&a, &flipped, "the bit flip")
        .unwrap_err()
        .contains("the bit flip"));

    let mut skewed = a.clone();
    skewed.ttft.p99 += 1e-9;
    assert!(invariants::rerun_deterministic(&a, &skewed)
        .unwrap_err()
        .contains("ttft.p99"));

    let mut streams: Vec<Vec<i64>> =
        a.records.iter().map(|r| r.tokens.clone()).collect();
    invariants::require(invariants::threaded_matches_virtual(&a, &streams));
    streams[3].push(0);
    assert!(invariants::threaded_matches_virtual(&a, &streams)
        .unwrap_err()
        .contains("request 3"));
}

/// The cluster checks must flag fleet-rule violations the pool checks
/// can't see: mid-stream sheds, batch sheds, lying tier counters.
#[test]
fn harness_rejects_mid_stream_sheds_and_counter_drift() {
    let wl = cwl(2000.0, 60, 0.5, 0.05, ArrivalTrace::Uniform, 21);
    let cc = ClusterConfig::new(2, VirtualConfig::new(
        SchedulerPolicy::RoundRobin,
        1,
        4,
        step_model(),
    ));
    let clean = run_virtual_cluster(&wl, &cc).unwrap();
    invariants::require(invariants::cluster_well_formed(&clean));

    // Corrupt a completed record into a "shed after streaming" state.
    let mut mid = clean.clone();
    let victim = mid.records.iter().position(|r| r.completed()).unwrap();
    mid.records[victim].shed = true;
    assert!(invariants::cluster_well_formed(&mid)
        .unwrap_err()
        .contains("shed after streaming"));

    let mut batch_shed = clean.clone();
    batch_shed.shed_batch = 1;
    assert!(invariants::cluster_well_formed(&batch_shed)
        .unwrap_err()
        .contains("batch"));

    let mut drift = clean.clone();
    drift.shed_interactive += 1;
    assert!(invariants::cluster_well_formed(&drift)
        .unwrap_err()
        .contains("disagrees"));
}

/// Virtual fleet acceptance: a 2-replica autoscaling cluster under a
/// diurnal trace passes the full fleet contract, reruns bit-identically
/// (records AND autoscale timeline), and every completed stream matches
/// the single-replica no-shed baseline of the same seed.
#[test]
fn cluster_fleet_reruns_bit_identical_and_matches_baseline() {
    let wl = cwl(
        3000.0,
        80,
        0.5,
        0.05,
        ArrivalTrace::Diurnal { period_s: 2.0, depth: 0.9 },
        11,
    );
    let mut cc = ClusterConfig::new(
        2,
        VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model()),
    );
    cc.autoscale = Some(AutoscaleConfig::default());

    let a = run_virtual_cluster(&wl, &cc).unwrap();
    let b = run_virtual_cluster(&wl, &cc).unwrap();
    invariants::require(invariants::cluster_well_formed(&a));
    invariants::require(invariants::cluster_well_formed(&b));
    assert_eq!(a.records, b.records);
    assert_eq!(a.replica_timeline, b.replica_timeline);
    assert_eq!(a.peak_replicas, b.peak_replicas);

    let baseline = run_virtual_plan(
        &wl.base.model,
        wl.base.vocab,
        wl.base.rate,
        strip_deadlines(&wl.generate()),
        &cc.pool,
    )
    .unwrap();
    invariants::require(invariants::cluster_streams_match_baseline(&a, &baseline));
}

/// Cross-path acceptance: the threaded dispatcher (live coordinators,
/// real threads) and the virtual fleet share one front-end, so with the
/// same planned timestamps their admission decisions AND token streams
/// agree request for request — and the threaded run is itself
/// deterministic across reruns.
#[test]
fn threaded_cluster_streams_match_virtual_fleet() {
    // Generous TTFT budget: admission never sheds, so every request
    // streams on both paths.
    let wl = cwl(2000.0, 24, 0.5, 1000.0, ArrivalTrace::Uniform, 42);
    let cc = ClusterConfig::new(
        2,
        VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model()),
    );

    let virt = run_virtual_cluster(&wl, &cc).unwrap();
    invariants::require(invariants::cluster_well_formed(&virt));
    assert_eq!(virt.shed_interactive, 0);

    let run_live = || {
        let cluster = Cluster::threaded(&cc, "opt-tiny", || {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            c
        })
        .unwrap();
        let r = run_cluster_open_loop(&cluster, &wl).unwrap();
        cluster.shutdown();
        r
    };
    let live1 = run_live();
    let live2 = run_live();
    assert_eq!(live1.token_streams, live2.token_streams);
    assert_eq!(live1.shed, 0);
    assert_eq!(live1.failed, 0);
    assert_eq!(live1.completed, 24);

    assert_eq!(virt.records.len(), live1.token_streams.len());
    for (rec, stream) in virt.records.iter().zip(&live1.token_streams) {
        assert_eq!(
            &rec.tokens, stream,
            "request {} diverges between virtual and threaded fleets",
            rec.request_id
        );
    }
}

/// Property `cluster-slo-streams`: over random replica counts, tier
/// mixes, arrival traces, and autoscale settings, every completed
/// stream is bit-identical to a single-replica no-shed run of the same
/// seed, and shed requests only ever shed before their first token.
#[test]
fn prop_cluster_slo_streams() {
    quick("cluster-slo-streams", |rng| {
        let seed = rng.next_u64();
        let n = rng.range(20, 61);
        let rate = rng.range_f64(200.0, 5000.0);
        let frac = rng.range_f64(0.0, 1.0);
        let deadline = rng.range_f64(0.005, 0.5);
        let trace = *rng.choose(&[
            ArrivalTrace::Uniform,
            ArrivalTrace::Diurnal { period_s: 3.0, depth: 0.8 },
            ArrivalTrace::FlashCrowd { at_s: 0.05, dur_s: 0.4, magnification: 25.0 },
        ]);
        let wl = cwl(rate, n, frac, deadline, trace, seed);

        let replicas = rng.range(1, 5);
        let pool = VirtualConfig::new(
            SchedulerPolicy::RoundRobin,
            rng.range(1, 3),
            rng.range(2, 9),
            step_model(),
        );
        let mut cc = ClusterConfig::new(replicas, pool);
        cc.shed = rng.bool(0.8);
        if rng.bool(0.5) {
            cc.autoscale = Some(AutoscaleConfig {
                max_replicas: rng.range(replicas, replicas + 3),
                ..AutoscaleConfig::default()
            });
        }

        let plan = wl.generate();
        let fleet = run_virtual_cluster_plan(
            &wl.base.model,
            wl.base.vocab,
            rate,
            plan.clone(),
            &cc,
        )?;
        // Fleet contract: per-replica pool invariants, shed strictly
        // before the first token, batch never shed, counters honest.
        invariants::cluster_well_formed(&fleet)?;

        // Ground truth: one replica, no shedding, no autoscale, no
        // deadlines — the same plan must yield the same tokens for
        // every request the fleet completed.
        let baseline = run_virtual_plan(
            &wl.base.model,
            wl.base.vocab,
            rate,
            strip_deadlines(&plan),
            &cc.pool,
        )?;
        invariants::well_formed(&baseline)?;
        invariants::cluster_streams_match_baseline(&fleet, &baseline)
    });
}

/// Property `trace-noninterference`: the lifecycle tracer is a pure
/// observer. Per seed, tracing on vs. off leaves records, counters,
/// percentiles, and token streams bit-identical (virtual always,
/// threaded sampled); a traced run reruns with bit-identical event
/// timelines; traced timelines agree with the records they narrate.
#[test]
fn prop_trace_noninterference() {
    quick("trace-noninterference", |rng| {
        let seed = rng.next_u64();
        let wl = Workload {
            model: "opt-tiny".into(),
            rate: rng.range_f64(200.0, 3000.0),
            n_requests: rng.range(10, 31),
            prompt_len: LenDist::Uniform(1, 8),
            output_len: LenDist::Fixed(rng.range(3, 7)),
            vocab: 512,
            seed,
        };
        let workers = rng.range(1, 3);
        let max_active = rng.range(2, 7);
        let vc =
            VirtualConfig::new(SchedulerPolicy::RoundRobin, workers, max_active, step_model());
        let mut traced = vc.clone();
        traced.trace = true;

        let off = run_virtual(&wl, &vc)?;
        let on = run_virtual(&wl, &traced)?;
        let on2 = run_virtual(&wl, &traced)?;

        // Tracing must not move a single bit of the run itself.
        invariants::rerun_deterministic(&off, &on)?;
        invariants::streams_identical(&off, &on, "tracing")?;
        if !off.timelines.is_empty() || off.attribution.is_some() {
            return Err("tracing off must record nothing".into());
        }
        if on.attribution.is_none() {
            return Err("traced run lost its attribution summary".into());
        }
        invariants::timelines_match_records(&on)?;

        // Event sequences (and virtual timestamps) replay bit-identically.
        if on.timelines.len() != on2.timelines.len() {
            return Err("rerun changed timeline count".into());
        }
        for (x, y) in on.timelines.iter().zip(&on2.timelines) {
            if x != y {
                return Err(format!("request {}: timeline diverged on rerun", x.request_id));
            }
        }

        // Sampled threaded leg: same noninterference on the live pool.
        if rng.bool(0.15) {
            let run_live = |trace: bool| -> Result<(Vec<Vec<i64>>, usize), String> {
                let mut c = Coordinator::new(CoordinatorConfig {
                    max_active_per_worker: max_active,
                    policy: SchedulerPolicy::RoundRobin,
                    trace,
                    ..CoordinatorConfig::default()
                });
                c.add_pool("opt-tiny", workers, BackendFactory::sim("opt-tiny", 512));
                let r = run_open_loop(&c, &wl)?;
                let timelines = c.tracer.drain().0;
                for tl in &timelines {
                    invariants::timeline_well_formed(tl)?;
                }
                let n_timelines = timelines.len();
                c.shutdown();
                Ok((r.token_streams, n_timelines))
            };
            let (streams_off, n_off) = run_live(false)?;
            let (streams_on, n_on) = run_live(true)?;
            if streams_off != streams_on {
                return Err("threaded streams changed by tracing".into());
            }
            if n_off != 0 {
                return Err("threaded tracer recorded while off".into());
            }
            if n_on != wl.n_requests {
                return Err(format!(
                    "threaded tracer kept {n_on} of {} timelines",
                    wl.n_requests
                ));
            }
        }
        Ok(())
    });
}

/// Cross-path acceptance for the tracer: per seed, the threaded pool
/// and the virtual harness record the SAME per-request event sequence
/// (payloads included — span lengths, cache skips, workers), because
/// both drivers feed the one shared lane core. Only timestamps differ
/// (wall offsets vs. the virtual clock).
#[test]
fn trace_event_sequences_match_across_paths() {
    let wl = Workload {
        model: "opt-tiny".into(),
        rate: 600.0,
        n_requests: 18,
        prompt_len: LenDist::Uniform(1, 8),
        output_len: LenDist::Fixed(5),
        vocab: 512,
        seed: 77,
    };
    let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 2, 4, step_model());
    vc.trace = true;
    let virt = run_virtual(&wl, &vc).unwrap();
    invariants::require(invariants::timelines_match_records(&virt));
    assert_eq!(virt.timelines.len(), wl.n_requests);

    let mut coord = Coordinator::new(CoordinatorConfig {
        max_active_per_worker: 4,
        policy: SchedulerPolicy::RoundRobin,
        trace: true,
        ..CoordinatorConfig::default()
    });
    coord.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
    let live = run_open_loop(&coord, &wl).unwrap();
    let (mut live_tls, _) = coord.tracer.drain();
    coord.shutdown();
    invariants::require(invariants::threaded_matches_virtual(&virt, &live.token_streams));

    live_tls.sort_by_key(|t| t.request_id);
    assert_eq!(live_tls.len(), virt.timelines.len());
    for (t, v) in live_tls.iter().zip(&virt.timelines) {
        // Threaded pool ids are 1-based; virtual rids are plan indices.
        assert_eq!(t.request_id, v.request_id + 1);
        invariants::require(invariants::timeline_well_formed(t));
        assert_eq!(
            t.sequence(),
            v.sequence(),
            "request {}: event sequences diverge between drivers",
            v.request_id
        );
    }
}

/// The trace checkers must catch corrupted timelines, not just bless
/// clean ones: backwards timestamps, misplaced terminals, and a sealed
/// attribution that no longer recomputes from the events.
#[test]
fn harness_rejects_corrupted_timelines() {
    let wl = Workload {
        model: "opt-tiny".into(),
        rate: 800.0,
        n_requests: 12,
        prompt_len: LenDist::Uniform(2, 8),
        output_len: LenDist::Fixed(5),
        vocab: 512,
        seed: 13,
    };
    let mut vc = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model());
    vc.trace = true;
    let r = run_virtual(&wl, &vc).unwrap();
    invariants::require(invariants::timelines_match_records(&r));
    let tl = r
        .timelines
        .iter()
        .find(|t| t.events.len() >= 4 && t.attribution.is_some())
        .expect("a completed traced request");

    // Timestamp gap/overlap: an event stamped after its successor.
    let mut backwards = tl.clone();
    backwards.events[1].t_s = backwards.events.last().unwrap().t_s + 1.0;
    assert!(invariants::timeline_well_formed(&backwards)
        .unwrap_err()
        .contains("backwards"));

    // A terminal event anywhere but last is a torn lifecycle.
    let mut torn = tl.clone();
    let t0 = torn.events[0].t_s;
    torn.events.insert(1, TraceEvent { t_s: t0, ev: SpanEvent::Finished });
    assert!(invariants::timeline_well_formed(&torn)
        .unwrap_err()
        .contains("terminal"));

    // An attribution that stops summing to TTFT + decode is caught.
    let mut skewed = tl.clone();
    if let Some(a) = &mut skewed.attribution {
        a.queue_wait_s += 0.25;
    }
    assert!(invariants::timeline_well_formed(&skewed)
        .unwrap_err()
        .contains("attribution"));

    // Dropping a DecodeStep breaks the trace/record walk agreement.
    let mut dropped = r.clone();
    let victim = r
        .timelines
        .iter()
        .position(|t| t.events.iter().any(|e| matches!(e.ev, SpanEvent::DecodeStep)))
        .unwrap();
    let step = dropped.timelines[victim]
        .events
        .iter()
        .position(|e| matches!(e.ev, SpanEvent::DecodeStep))
        .unwrap();
    dropped.timelines[victim].events.remove(step);
    assert!(invariants::timelines_match_records(&dropped).is_err());
}

/// Chaos acceptance, virtual path: a replica crash plus a partition in
/// the middle of a flash crowd. Every request still completes, every
/// completed stream is bit-identical to the fault-free single-replica
/// baseline (exactly-once across the failover boundary), zero KV
/// blocks leak on any replica, and the recovery replays bit-identically
/// on a rerun.
#[test]
fn virtual_chaos_crash_and_partition_preserve_streams() {
    let wl = cwl(
        3000.0,
        60,
        0.5,
        1000.0, // generous: chaos must not hide behind shedding
        ArrivalTrace::FlashCrowd { at_s: 0.01, dur_s: 0.1, magnification: 10.0 },
        33,
    );
    let mut cc = ClusterConfig::new(
        3,
        VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model()),
    );
    cc.faults =
        ClusterFaultPlan::parse("probe=0.05,crash=0@0.02,partition=1@0.05..0.4").unwrap();

    let a = run_virtual_cluster(&wl, &cc).unwrap();
    let b = run_virtual_cluster(&wl, &cc).unwrap();
    invariants::require(invariants::cluster_well_formed(&a));
    invariants::require(invariants::fleet_kv_clean(&a));
    invariants::require(invariants::rerun_deterministic(
        a.replicas[2].as_ref().unwrap(),
        b.replicas[2].as_ref().unwrap(),
    ));
    assert_eq!(a.records, b.records, "chaos recovery must replay bit-identically");

    assert_eq!(a.replica_crashes, 1);
    assert_eq!(a.partitions, 1);
    assert!(a.streams_failed_over > 0, "crash mid-crowd must orphan live streams");
    assert_eq!(
        a.records.iter().filter(|r| r.failed_over).count(),
        a.streams_failed_over,
        "failover counter must agree with the per-record flags"
    );
    assert!(a.records.iter().all(|r| r.completed()), "chaos must not lose requests");

    let baseline = run_virtual_plan(
        &wl.base.model,
        wl.base.vocab,
        wl.base.rate,
        strip_deadlines(&wl.generate()),
        &cc.pool,
    )
    .unwrap();
    invariants::require(invariants::no_duplicate_or_reordered_tokens(&a, &baseline));
    invariants::require(invariants::cluster_streams_match_baseline(&a, &baseline));
}

/// Chaos acceptance, threaded path: a replica crash while live streams
/// are in flight. The dispatcher re-homes the orphans with exactly-once
/// token delivery — streams match the fault-free VIRTUAL baseline value
/// for value — nothing fails, and reruns agree stream for stream
/// (threaded timing counters are wall-clock-dependent; token values are
/// not).
#[test]
fn threaded_chaos_failover_matches_fault_free_virtual() {
    let wl = cwl(800.0, 24, 0.0, 0.0, ArrivalTrace::Uniform, 52);
    let clean = ClusterConfig::new(
        2,
        VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model()),
    );
    let virt = run_virtual_cluster(&wl, &clean).unwrap();
    invariants::require(invariants::cluster_well_formed(&virt));

    let mut cc = clean;
    cc.faults = ClusterFaultPlan::parse("crash=0@0.01").unwrap();
    let run_live = || {
        let cluster = Cluster::threaded(&cc, "opt-tiny", || {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            c
        })
        .unwrap();
        let r = run_cluster_open_loop(&cluster, &wl).unwrap();
        cluster.shutdown();
        r
    };
    let live1 = run_live();
    let live2 = run_live();
    assert_eq!(live1.failed, 0, "failover must leave no failed streams");
    assert_eq!(live1.completed, 24);
    assert_eq!(
        live1.token_streams, live2.token_streams,
        "threaded chaos recovery must be value-deterministic"
    );
    assert_eq!(virt.records.len(), live1.token_streams.len());
    for (rec, stream) in virt.records.iter().zip(&live1.token_streams) {
        assert_eq!(
            &rec.tokens, stream,
            "request {} diverges from the fault-free virtual run",
            rec.request_id
        );
    }
}

/// Hedged interactive requests: a replica slowdown pushes interactive
/// admissions past the hedge threshold, duplicates are issued — and
/// change nothing about the token streams, KV accounting, or rerun
/// determinism. Hedging is a latency feature, never a token feature.
#[test]
fn hedged_interactive_requests_leave_streams_identical() {
    let wl = cwl(5000.0, 40, 1.0, 5.0, ArrivalTrace::Uniform, 61);
    let mut base = ClusterConfig::new(
        2,
        VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model()),
    );
    base.faults = ClusterFaultPlan::parse("slow=0x8").unwrap();
    let unhedged = run_virtual_cluster(&wl, &base).unwrap();

    let mut cc = base;
    cc.hedge_fraction = 0.01;
    let a = run_virtual_cluster(&wl, &cc).unwrap();
    let b = run_virtual_cluster(&wl, &cc).unwrap();
    invariants::require(invariants::cluster_well_formed(&a));
    invariants::require(invariants::fleet_kv_clean(&a));
    assert_eq!(a.records, b.records, "hedged runs must rerun bit-identically");
    assert!(a.hedges_issued > 0, "an 8x-slow replica must trigger hedges");
    assert!(a.hedges_won <= a.hedges_issued);
    assert_eq!(
        a.records.iter().filter(|r| r.hedged).count(),
        a.hedges_issued,
        "hedge counter must agree with the per-record flags"
    );
    assert_eq!(a.records.len(), unhedged.records.len());
    for (h, u) in a.records.iter().zip(&unhedged.records) {
        assert_eq!(
            h.tokens, u.tokens,
            "request {}: hedging changed the stream",
            h.request_id
        );
    }
}

/// `--fault-plan` composes with `--replicas`: the pool-level plan is
/// applied to EACH replica identically (worker indices are per-replica,
/// so `slow=0x…` slows worker 0 of every replica). Transient faults
/// under the retry budget are fully masked — streams stay bit-identical
/// to the fault-free baseline while the per-replica reports show the
/// injections actually happened.
#[test]
fn pool_fault_plan_applies_per_replica_under_cluster() {
    let wl = cwl(2000.0, 48, 0.5, 1000.0, ArrivalTrace::Uniform, 71);
    let mut cc = ClusterConfig::new(
        2,
        VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step_model()),
    );
    cc.pool.faults =
        FaultPlan::parse("seed=5,transient=0.05,retries=4,backoff=0.0001").unwrap();

    let fleet = run_virtual_cluster(&wl, &cc).unwrap();
    invariants::require(invariants::cluster_well_formed(&fleet));
    invariants::require(invariants::fleet_kv_clean(&fleet));

    let injected: u64 =
        fleet.replicas.iter().flatten().map(|r| r.faults_injected).sum();
    let retried: u64 = fleet.replicas.iter().flatten().map(|r| r.retries).sum();
    assert!(injected > 0, "the pool plan must fire on the replicas");
    assert!(retried > 0, "transient faults must be retried in place");

    let baseline = run_virtual_plan(
        &wl.base.model,
        wl.base.vocab,
        wl.base.rate,
        strip_deadlines(&wl.generate()),
        &ClusterConfig::new(2, VirtualConfig::new(
            SchedulerPolicy::RoundRobin,
            1,
            4,
            step_model(),
        ))
        .pool,
    )
    .unwrap();
    invariants::require(invariants::cluster_streams_match_baseline(&fleet, &baseline));
}

/// Property `cluster-chaos-streams`: over random replica counts and
/// random fault plans (crash, partition, slowdown — always leaving the
/// last replica fault-free so the fleet survives), every request
/// completes, streams are bit-identical to the fault-free baseline with
/// exactly-once delivery, no replica leaks KV, and the recovery replays
/// bit-identically.
#[test]
fn prop_cluster_chaos_streams() {
    check("cluster-chaos-streams", Config { cases: 64, ..Config::default() }, |rng| {
        let seed = rng.next_u64();
        let n = rng.range(12, 33);
        let rate = rng.range_f64(500.0, 4000.0);
        let frac = rng.range_f64(0.0, 1.0);
        let wl = cwl(rate, n, frac, 1000.0, ArrivalTrace::Uniform, seed);

        let replicas = rng.range(2, 5);
        let mut cc = ClusterConfig::new(
            replicas,
            VirtualConfig::new(
                SchedulerPolicy::RoundRobin,
                rng.range(1, 3),
                rng.range(2, 7),
                step_model(),
            ),
        );
        // Random plan; replica indices stay in [0, replicas-1) so the
        // LAST replica is never faulted — the fleet always has a
        // routable survivor.
        let mut faults = ClusterFaultPlan { probe_interval_s: 0.05, ..Default::default() };
        if rng.bool(0.7) {
            faults.crashes.push(ReplicaCrashSpec {
                replica: rng.range(0, replicas - 1),
                at_s: rng.range_f64(0.005, 0.06),
            });
        }
        if rng.bool(0.7) {
            let from_s = rng.range_f64(0.01, 0.08);
            faults.partitions.push(PartitionSpec {
                replica: rng.range(0, replicas - 1),
                from_s,
                until_s: from_s + rng.range_f64(0.1, 0.4),
            });
        }
        if rng.bool(0.5) {
            faults.slow.push(ReplicaSlowSpec {
                replica: rng.range(0, replicas - 1),
                factor: rng.range_f64(1.5, 6.0),
            });
        }
        cc.faults = faults;
        if rng.bool(0.3) {
            cc.hedge_fraction = rng.range_f64(0.0, 0.5);
        }

        let plan = wl.generate();
        let fleet = run_virtual_cluster_plan(
            &wl.base.model,
            wl.base.vocab,
            rate,
            plan.clone(),
            &cc,
        )?;
        let rerun = run_virtual_cluster_plan(
            &wl.base.model,
            wl.base.vocab,
            rate,
            plan.clone(),
            &cc,
        )?;
        invariants::cluster_well_formed(&fleet)?;
        invariants::fleet_kv_clean(&fleet)?;
        if fleet.records != rerun.records {
            return Err("chaos recovery diverged between reruns".into());
        }
        if let Some(lost) = fleet.records.iter().find(|r| !r.completed()) {
            return Err(format!(
                "request {} lost under chaos (shed {}, tokens {})",
                lost.request_id,
                lost.shed,
                lost.tokens.len()
            ));
        }

        let baseline = run_virtual_plan(
            &wl.base.model,
            wl.base.vocab,
            rate,
            strip_deadlines(&plan),
            &cc.pool,
        )?;
        invariants::well_formed(&baseline)?;
        invariants::no_duplicate_or_reordered_tokens(&fleet, &baseline)?;
        invariants::cluster_streams_match_baseline(&fleet, &baseline)
    });
}
