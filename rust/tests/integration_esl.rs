//! Integration: ESL scalability (Fig 7c) and reconfigurable rings
//! (Fig 4b), end to end through compiler + simulator.

use lpu::config::LpuConfig;
use lpu::esl::cluster::{multi_model_deployment, scaling_sweep, speedup_per_doubling};
use lpu::esl::{LinkModel, RingConfig, Router};
use lpu::gpu::{scaling_speedups, GpuConfig};
use lpu::model::by_name;

/// Paper headline: LPU achieves 1.75x per doubling (5.43x at 8 devices)
/// on GPT3-20B, vs DGX A100's 1.38x (2.65x at 8).
#[test]
fn fig7c_lpu_scaling_near_paper() {
    let m = by_name("gpt3-20b").unwrap();
    let cfg = LpuConfig::asic_3_28tbs();
    let pts = scaling_sweep(&m, &cfg, 8, true, 32, 128).unwrap();
    let s8 = pts.last().unwrap().speedup;
    assert!((4.6..=7.0).contains(&s8), "8-device speedup {s8:.2} vs paper 5.43");
    let per2 = speedup_per_doubling(&pts);
    assert!((1.55..=1.95).contains(&per2), "per-doubling {per2:.2} vs paper 1.75");
}

#[test]
fn fig7c_lpu_beats_dgx_scaling() {
    let m = by_name("gpt3-20b").unwrap();
    let lpu = scaling_sweep(&m, &LpuConfig::asic_3_28tbs(), 8, true, 32, 128).unwrap();
    let dgx = scaling_speedups(&GpuConfig::a100(), &m, 8, 100);
    let lpu8 = lpu.last().unwrap().speedup;
    let dgx8 = dgx.last().unwrap().1;
    assert!(lpu8 > 1.5 * dgx8, "LPU {lpu8:.2} vs DGX {dgx8:.2}");
}

/// Without ESL overlap (blocking sync), scaling collapses toward the
/// GPU's regime — the ablation that isolates the paper's contribution.
#[test]
fn overlap_ablation_isolates_esl_benefit() {
    let m = by_name("gpt3-20b").unwrap();
    let cfg = LpuConfig::asic_3_28tbs();
    let with = scaling_sweep(&m, &cfg, 8, true, 32, 64).unwrap();
    let without = scaling_sweep(&m, &cfg, 8, false, 32, 64).unwrap();
    let s_with = with.last().unwrap().speedup;
    let s_without = without.last().unwrap().speedup;
    assert!(
        s_with > s_without + 0.4,
        "overlap {s_with:.2} should clearly beat blocking {s_without:.2}"
    );
}

/// Fig 4(b): an 8-device server reconfigures into two 4-rings serving
/// two different models concurrently; both make progress with sane
/// latency, and rings never share devices.
#[test]
fn reconfigurable_rings_serve_two_models() {
    let m1 = by_name("opt-mini").unwrap();
    let m2 = by_name("opt-tiny").unwrap();
    let cfg = LpuConfig::fpga_u55c();
    let reports = multi_model_deployment(8, 4, &[&m1, &m2], &cfg, 64).unwrap();
    assert_eq!(reports.len(), 2);
    for (_, r) in &reports {
        assert!(r.ms_per_token > 0.0 && r.ms_per_token < 100.0);
        assert_eq!(r.n_devices, 4);
    }
    // The smaller model must be faster on its ring.
    assert!(reports[1].1.ms_per_token < reports[0].1.ms_per_token);
}

#[test]
fn ring_reconfig_all_sizes_cover_disjointly() {
    for size in [2, 4, 8] {
        let rc = RingConfig::new(8, size).unwrap();
        rc.validate().unwrap();
        // Routing stays within each ring.
        for r in 0..rc.n_rings() {
            let members = rc.members(r);
            let router = Router::new(members[0], rc.clone());
            for &d in &members[1..] {
                let (hops, _) = router.route(d).unwrap();
                assert!(hops <= size / 2);
            }
        }
    }
}

/// Wire-level check: the visible ESL all-reduce tail is a small fraction
/// of the blocking cost for realistic hidden sizes.
#[test]
fn allreduce_tail_fraction() {
    let l = LinkModel { bw: 25e9, hop_latency: 500e-9 };
    for d in [2048u64, 9216, 6144] {
        let bytes = d * 2;
        let tail = l.overlapped_allreduce_tail(bytes, 8);
        let blocking = l.blocking_allreduce_time(bytes, 8);
        assert!(tail <= blocking);
    }
}
