//! Integration: compiler → cycle simulator on the paper's evaluation
//! points. These assertions pin the reproduction to the paper's headline
//! numbers (methodology: in=32, out=2016 tokens, 3.28 TB/s config).

use lpu::config::LpuConfig;
use lpu::model::by_name;
use lpu::sim::{simulate_generation, simulate_prefill};

const IN: usize = 32;
const OUT: usize = 2016;

fn run(model: &str, devices: usize) -> lpu::sim::GenerationReport {
    simulate_generation(
        &by_name(model).unwrap(),
        &LpuConfig::asic_3_28tbs(),
        devices,
        IN,
        OUT,
        true,
    )
    .unwrap()
}

/// Paper: 1.25 ms/token for OPT-1.3B on one LPU.
#[test]
fn opt_1_3b_latency_near_paper() {
    let r = run("opt-1.3b", 1);
    assert!(
        (1.0..=1.5).contains(&r.ms_per_token),
        "1.3B: {:.3} ms/token vs paper 1.25",
        r.ms_per_token
    );
    // Paper: 63.3% bandwidth utilization.
    assert!(
        (0.55..=0.75).contains(&r.bandwidth_util),
        "1.3B util {:.3} vs paper 0.633",
        r.bandwidth_util
    );
}

/// Paper: 4.62 ms/token for OPT-6.7B.
#[test]
fn opt_6_7b_latency_near_paper() {
    let r = run("opt-6.7b", 1);
    assert!(
        (4.2..=5.4).contains(&r.ms_per_token),
        "6.7B: {:.3} ms/token vs paper 4.62",
        r.ms_per_token
    );
}

/// Paper: 90.2% utilization on OPT-30B (latency not quoted; util implies
/// ~20.3 ms/token).
#[test]
fn opt_30b_utilization_near_paper() {
    let r = run("opt-30b", 1);
    assert!(
        (0.84..=0.95).contains(&r.bandwidth_util),
        "30B util {:.3} vs paper 0.902",
        r.bandwidth_util
    );
    assert!((18.0..=23.0).contains(&r.ms_per_token), "30B {:.2} ms", r.ms_per_token);
}

/// Paper: 22.2 ms/token, 90.6% util for OPT-66B on two LPUs.
#[test]
fn opt_66b_two_devices_near_paper() {
    let r = run("opt-66b", 2);
    assert!(
        (20.0..=25.0).contains(&r.ms_per_token),
        "66B x2: {:.2} ms/token vs paper 22.2",
        r.ms_per_token
    );
    assert!(
        (0.84..=0.95).contains(&r.bandwidth_util),
        "66B util {:.3} vs paper 0.906",
        r.bandwidth_util
    );
}

/// Utilization must *rise* with model size (the LPU's key property —
/// and the small-model regime is where the GPU collapses).
#[test]
fn utilization_monotone_in_model_size() {
    let u13 = run("opt-1.3b", 1).bandwidth_util;
    let u67 = run("opt-6.7b", 1).bandwidth_util;
    let u30 = run("opt-30b", 1).bandwidth_util;
    assert!(u13 < u67 && u67 < u30, "{u13:.3} {u67:.3} {u30:.3}");
}

/// The three ASIC configs keep utilization roughly flat for a model that
/// fits them all — "maximum performance regardless of the model size".
#[test]
fn bandwidth_scaling_across_asic_configs() {
    let m = by_name("opt-1.3b").unwrap();
    let small = simulate_generation(&m, &LpuConfig::asic_819gbs(), 1, IN, 256, true).unwrap();
    let big = simulate_generation(&m, &LpuConfig::asic_3_28tbs(), 1, IN, 256, true).unwrap();
    // 4x bandwidth should buy ~3.2-4x latency improvement.
    let ratio = small.ms_per_token / big.ms_per_token;
    assert!((2.8..=4.4).contains(&ratio), "819GB/s vs 3.28TB/s ratio {ratio:.2}");
}

/// FPGA config (Orion building block): 1.3B at 460 GB/s should land in
/// the several-ms range, slower than the ASIC by roughly the BW ratio.
#[test]
fn fpga_config_sane() {
    let m = by_name("opt-1.3b").unwrap();
    let r = simulate_generation(&m, &LpuConfig::fpga_u55c(), 1, IN, 256, true).unwrap();
    assert!((5.0..=10.0).contains(&r.ms_per_token), "fpga 1.3B {:.2} ms", r.ms_per_token);
}

/// Multi-token (summarization) mode: prefill of the 32-token prompt must
/// be much cheaper than 32 serial decode steps (paper future work,
/// "reduce the latency significantly for user requests with long input").
#[test]
fn prefill_mode_speedup() {
    let m = by_name("opt-1.3b").unwrap();
    let cfg = LpuConfig::asic_3_28tbs();
    let (prefill_s, _) = simulate_prefill(&m, &cfg, 1, 32, 4).unwrap();
    let serial = simulate_generation(&m, &cfg, 1, 0, 32, true).unwrap();
    let serial_s = serial.ms_per_token * 1e-3 * 32.0;
    let speedup = serial_s / prefill_s;
    assert!(speedup > 2.0, "multi-token prefill speedup {speedup:.2}");
}

/// Latency grows with context position (KV reads), roughly linearly.
#[test]
fn latency_linear_in_position() {
    let r = run("opt-1.3b", 1);
    let (p0, c0) = r.samples[0];
    let (p1, c1) = *r.samples.last().unwrap();
    let slope = (c1 as f64 - c0 as f64) / (p1 - p0) as f64;
    assert!(slope > 0.0);
    // Mid-sample should sit near the line (linearity).
    let mid = r.samples[r.samples.len() / 2];
    let interp = c0 as f64 + slope * (mid.0 - p0) as f64;
    let rel = (mid.1 as f64 - interp).abs() / interp;
    assert!(rel < 0.02, "nonlinear latency growth: rel {rel:.4}");
}
