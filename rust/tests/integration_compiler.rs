//! Integration: HyperDex compiler across the model zoo, including binary
//! round-trips through the on-disk format and the assembler.

use lpu::compiler::{compile, verify_chains, CompileOpts, ParallelMode};
use lpu::config::LpuConfig;
use lpu::isa::{asm, Program};
use lpu::model::{by_name, paper_eval_models};

fn opts(devices: usize, pos: usize) -> CompileOpts {
    CompileOpts { n_devices: devices, position: pos, ..Default::default() }
}

#[test]
fn all_paper_models_compile_on_flagship_config() {
    let cfg = LpuConfig::asic_3_28tbs();
    for m in paper_eval_models() {
        let devices = m.devices_needed(cfg.hbm.capacity());
        let c = compile(&m, &cfg, &opts(devices, 100)).unwrap();
        assert!(c.stats.peak_live_regs <= 64, "{}", m.name);
        verify_chains(&c.program).unwrap();
    }
}

#[test]
fn gpt3_20b_compiles_at_all_ring_sizes() {
    let cfg = LpuConfig::asic_3_28tbs();
    let m = by_name("gpt3-20b").unwrap();
    for n in [1, 2, 4, 8] {
        let c = compile(&m, &cfg, &opts(n, 50)).unwrap();
        assert!(c.program.len() > 100, "n={n}");
    }
}

#[test]
fn compiled_binary_roundtrips_through_disk() {
    let cfg = LpuConfig::asic_819gbs();
    let m = by_name("opt-tiny").unwrap();
    let c = compile(&m, &cfg, &opts(1, 7)).unwrap();
    let path = std::env::temp_dir().join("lpu_test_prog.lpubin");
    std::fs::write(&path, c.program.to_bytes().unwrap()).unwrap();
    let back = Program::from_bytes(&std::fs::read(&path).unwrap()).unwrap();
    assert_eq!(back, c.program);
    std::fs::remove_file(&path).ok();
}

#[test]
fn compiled_program_disassembles_and_reassembles() {
    let cfg = LpuConfig::asic_819gbs();
    let m = by_name("opt-tiny").unwrap();
    let c = compile(&m, &cfg, &opts(1, 3)).unwrap();
    let text = asm::disasm_program(&c.program);
    let body: String = text
        .lines()
        .map(|l| l.splitn(2, ": ").nth(1).unwrap())
        .collect::<Vec<_>>()
        .join("\n");
    let back = asm::assemble(&body).unwrap();
    assert_eq!(back, c.program);
}

#[test]
fn program_size_scales_with_layers_not_position() {
    let cfg = LpuConfig::asic_3_28tbs();
    let tiny = compile(&by_name("opt-tiny").unwrap(), &cfg, &opts(1, 0)).unwrap();
    let mini = compile(&by_name("opt-mini").unwrap(), &cfg, &opts(1, 0)).unwrap();
    assert!(mini.program.len() > tiny.program.len());
    // Position does NOT change instruction count (only stream lengths).
    let far = compile(&by_name("opt-tiny").unwrap(), &cfg, &opts(1, 200)).unwrap();
    assert_eq!(far.program.len(), tiny.program.len());
}

#[test]
fn memory_map_weight_bytes_track_shard_fraction() {
    let cfg = LpuConfig::asic_3_28tbs();
    let m = by_name("opt-6.7b").unwrap();
    let c1 = compile(&m, &cfg, &opts(1, 0)).unwrap();
    let c4 = compile(&m, &cfg, &opts(4, 0)).unwrap();
    let frac = c4.map.weight_bytes() as f64 / c1.map.weight_bytes() as f64;
    // Sharded weights -> ~1/4 plus replicated embeddings.
    assert!((0.25..=0.45).contains(&frac), "shard fraction {frac:.3}");
}

#[test]
fn batch_and_multitoken_modes_compile_and_verify() {
    let cfg = LpuConfig::asic_819gbs();
    let m = by_name("opt-tiny").unwrap();
    for mode in [ParallelMode::Batch { batch: 4 }, ParallelMode::MultiToken { tokens: 8 }] {
        let o = CompileOpts { mode, sxe_sets: 2, ..opts(1, 10) };
        let c = compile(&m, &cfg, &o).unwrap();
        verify_chains(&c.program).unwrap();
        assert!(c.stats.peak_live_regs <= 64);
    }
}

#[test]
fn esl_overlap_flag_changes_net_instruction_count() {
    let cfg = LpuConfig::asic_3_28tbs();
    let m = by_name("opt-1.3b").unwrap();
    let with = compile(&m, &cfg, &CompileOpts { esl_overlap: true, ..opts(2, 10) }).unwrap();
    let without = compile(&m, &cfg, &CompileOpts { esl_overlap: false, ..opts(2, 10) }).unwrap();
    let net = |p: &Program| p.category_histogram()[2].1;
    // Blocking mode emits the explicit 2(n-1)-step ring all-reduce.
    assert!(net(&without.program) > net(&with.program));
}

#[test]
fn compile_stats_chain_interleave_positive() {
    let cfg = LpuConfig::asic_3_28tbs();
    let m = by_name("opt-1.3b").unwrap();
    let c = compile(&m, &cfg, &opts(1, 50)).unwrap();
    // MEM and COMP chains alternate heavily in the decoder body.
    assert!(c.stats.chain.interleave > 10.0, "interleave {}", c.stats.chain.interleave);
    assert!(c.stats.chain.peak_streams >= 1);
}
