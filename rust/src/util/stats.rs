//! Streaming and batch statistics: Welford accumulation, percentiles,
//! histograms. Used by the simulator's bandwidth/occupancy counters, the
//! coordinator's latency metrics, and the bench harness.

/// Streaming mean/variance accumulator (Welford's algorithm) with
/// min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample set via linear interpolation between closest
/// ranks. `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Summary of a latency-style sample set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "summary of empty sample set");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &s in samples {
            w.add(s);
        }
        Summary {
            count: samples.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            p50: percentile(&sorted, 50.0),
            p90: percentile(&sorted, 90.0),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Fixed-width histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram { lo, hi, buckets: vec![0; nbuckets], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let last = self.buckets.len() - 1;
            let i = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            self.buckets[i.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Render a compact ASCII sparkline of the histogram.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&c| BARS[(c * (BARS.len() as u64 - 1) / max) as usize])
            .collect()
    }
}

/// Log-spaced histogram for latency-style samples: fixed bucket bounds
/// at `buckets_per_decade` per decade over `[lo, hi)` seconds, plus an
/// underflow bucket below `lo` and an overflow bucket at `hi` and
/// above. The full `bounds + counts` arrays export through the server's
/// `metrics` op and the bench JSONs (not just p50/p95/p99), so a
/// scraper can rebuild the whole distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    /// Upper bucket edges, ascending. Bucket `i` counts samples in
    /// `[bounds[i-1], bounds[i])` (bucket 0: `(-inf, bounds[0])`); the
    /// final count is the overflow bucket `[bounds.last(), inf)`.
    bounds: Vec<f64>,
    /// Bucket counts; always `bounds.len() + 1` entries.
    counts: Vec<u64>,
}

impl Default for LogHistogram {
    /// The standard latency histogram ([`LogHistogram::latency`]), so
    /// metric structs holding one can keep deriving `Default`.
    fn default() -> Self {
        LogHistogram::latency()
    }
}

impl LogHistogram {
    /// A histogram with log-spaced bounds from `lo` to `hi` seconds at
    /// `per_decade` edges per decade.
    pub fn log_spaced(lo: f64, hi: f64, per_decade: usize) -> LogHistogram {
        assert!(lo > 0.0 && hi > lo && per_decade > 0);
        let mut bounds = Vec::new();
        let exp = lo.log10();
        let step = 1.0 / per_decade as f64;
        // Recompute each edge from lo's exponent so the bounds are a
        // pure function of (lo, hi, per_decade) — no accumulation
        // drift between two histograms built the same way.
        let mut i = 0usize;
        loop {
            let edge = 10f64.powf(exp + step * i as f64);
            if edge > hi * (1.0 + 1e-12) {
                break;
            }
            bounds.push(edge);
            i += 1;
        }
        let counts = vec![0; bounds.len() + 1];
        LogHistogram { bounds, counts }
    }

    /// The standard latency histogram: 1 µs to 1000 s, 4 buckets per
    /// decade (37 edges, 38 counts) — wide enough for queueing tails
    /// under overload and fine enough to see a p99 shift of ~2x.
    pub fn latency() -> LogHistogram {
        LogHistogram::log_spaced(1e-6, 1e3, 4)
    }

    /// Build the standard latency histogram over a sample set.
    pub fn of(samples: &[f64]) -> LogHistogram {
        let mut h = LogHistogram::latency();
        for &s in samples {
            h.add(s);
        }
        h
    }

    /// Count one sample (non-finite samples are dropped, matching the
    /// metrics hub's reservoir hygiene).
    pub fn add(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let i = self.bounds.partition_point(|&b| b <= x);
        self.counts[i] += 1;
    }

    /// Total samples counted.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Upper bucket edges, ascending.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Bucket counts (`bounds().len() + 1` entries; last = overflow).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Merge another histogram built with identical bounds.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.bounds, other.bounds, "histogram bounds mismatch");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
    }

    /// `{"bounds_s": [...], "counts": [...]}` for the metrics op and
    /// bench JSON exports.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(vec![
            (
                "bounds_s",
                Json::Arr(self.bounds.iter().map(|&b| b.into()).collect()),
            ),
            (
                "counts",
                Json::Arr(self.counts.iter().map(|&c| c.into()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_merge_equals_single_stream() {
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
            all.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p95 > 94.0 && s.p95 <= 96.5);
        assert!(s.p99 > 98.0 && s.p99 <= 100.0);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn log_histogram_buckets_and_determinism() {
        let mut h = LogHistogram::latency();
        assert_eq!(h.bounds().len(), 37);
        assert_eq!(h.counts().len(), 38);
        h.add(0.0); // below lo -> underflow bucket 0
        h.add(0.01);
        h.add(1e9); // above hi -> overflow (last bucket)
        h.add(f64::NAN); // dropped
        assert_eq!(h.total(), 3);
        assert_eq!(h.counts()[0], 1);
        assert_eq!(*h.counts().last().unwrap(), 1);
        // Bounds are a pure function of (lo, hi, per_decade): two
        // independently built histograms are bitwise-mergeable.
        let mut other = LogHistogram::latency();
        other.add(0.01);
        h.merge(&other);
        assert_eq!(h.total(), 4);
        let j = h.to_json();
        assert_eq!(j.get("bounds_s").as_arr().unwrap().len(), 37);
        assert_eq!(j.get("counts").as_arr().unwrap().len(), 38);
        assert_eq!(LogHistogram::default().bounds(), LogHistogram::latency().bounds());
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.total(), 12);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
