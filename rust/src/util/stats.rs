//! Streaming and batch statistics: Welford accumulation, percentiles,
//! histograms. Used by the simulator's bandwidth/occupancy counters, the
//! coordinator's latency metrics, and the bench harness.

/// Streaming mean/variance accumulator (Welford's algorithm) with
/// min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample set via linear interpolation between closest
/// ranks. `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Summary of a latency-style sample set.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "summary of empty sample set");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &s in samples {
            w.add(s);
        }
        Summary {
            count: samples.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            p50: percentile(&sorted, 50.0),
            p90: percentile(&sorted, 90.0),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Fixed-width histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram { lo, hi, buckets: vec![0; nbuckets], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let last = self.buckets.len() - 1;
            let i = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            self.buckets[i.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    /// Render a compact ASCII sparkline of the histogram.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&c| BARS[(c * (BARS.len() as u64 - 1) / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_merge_equals_single_stream() {
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
            all.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p95 > 94.0 && s.p95 <= 96.5);
        assert!(s.p99 > 98.0 && s.p99 <= 100.0);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.total(), 12);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
