//! Tiny declarative CLI argument parser (offline `clap` substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, positional arguments, and auto-generated `--help`.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program/subcommand names).
    pub fn parse(raw: &[String]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends option parsing.
                    a.positional.extend(raw[i + 1..].iter().cloned());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    a.opts.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A subcommand description used for help text and dispatch.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub usage: &'static str,
}

/// Render help for a command set.
pub fn render_help(prog: &str, about: &str, commands: &[Command]) -> String {
    let mut s = String::new();
    s.push_str(&format!("{prog} — {about}\n\nUSAGE:\n  {prog} <command> [options]\n\nCOMMANDS:\n"));
    let width = commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
    for c in commands {
        s.push_str(&format!("  {:<width$}  {}\n", c.name, c.about, width = width));
    }
    s.push_str("\nRun with '<command> --help' for command options.\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positional() {
        let a = Args::parse(&sv(&["--model", "opt-1.3b", "--fast", "--n=4", "file.json"])).unwrap();
        assert_eq!(a.opt("model"), Some("opt-1.3b"));
        assert!(a.flag("fast"));
        assert_eq!(a.opt_usize("n", 0).unwrap(), 4);
        assert_eq!(a.positional(), &["file.json".to_string()]);
    }

    #[test]
    fn double_dash_ends_options() {
        let a = Args::parse(&sv(&["--x", "1", "--", "--not-an-opt"])).unwrap();
        assert_eq!(a.opt("x"), Some("1"));
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }

    #[test]
    fn typed_option_errors() {
        let a = Args::parse(&sv(&["--n", "abc"])).unwrap();
        assert!(a.opt_usize("n", 0).is_err());
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&sv(&["--a", "--b"])).unwrap();
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }

    #[test]
    fn help_lists_commands() {
        let cmds = [
            Command { name: "serve", about: "run the server", usage: "" },
            Command { name: "sim", about: "run the simulator", usage: "" },
        ];
        let h = render_help("lpu", "LPU toolkit", &cmds);
        assert!(h.contains("serve"));
        assert!(h.contains("run the simulator"));
    }
}
