//! In-tree infrastructure substrates.
//!
//! The build environment is fully offline with zero external crates.
//! The usual ecosystem crates (serde, rand, criterion, proptest, clap,
//! anyhow) are therefore reimplemented here as small, well-tested
//! modules. Each is a real substrate with its own unit tests, not a shim.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a byte count with binary-prefix units (KiB/MiB/GiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration given in seconds with an adaptive unit.
pub fn fmt_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn seconds_formatting() {
        assert_eq!(fmt_seconds(1.5), "1.500 s");
        assert_eq!(fmt_seconds(0.00125), "1.250 ms");
        assert_eq!(fmt_seconds(2.5e-7), "250.0 ns");
    }
}
