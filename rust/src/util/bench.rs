//! Micro-benchmark harness (offline `criterion` substitute).
//!
//! Provides warmup, adaptive iteration-count selection, outlier-robust
//! statistics, and optional throughput reporting. All `cargo bench`
//! targets (`rust/benches/*.rs`, `harness = false`) run through this.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One benchmark's measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub summary: Summary,
    /// Optional throughput: (unit label, units per iteration).
    pub throughput: Option<(String, f64)>,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<44} {:>12}/iter  (p50 {:>12}, p99 {:>12}, n={})",
            self.name,
            super::fmt_seconds(s.mean),
            super::fmt_seconds(s.p50),
            super::fmt_seconds(s.p99),
            s.count,
        );
        if let Some((unit, per_iter)) = &self.throughput {
            let rate = per_iter / s.mean;
            line.push_str(&format!("  [{:.3e} {}/s]", rate, unit));
        }
        line
    }
}

/// Bench runner with criterion-like defaults.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // LPU_BENCH_FAST=1 shortens runs for CI/tests.
        let fast = std::env::var("LPU_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Bencher {
            warmup: if fast { Duration::from_millis(20) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(100) } else { Duration::from_secs(2) },
            max_samples: if fast { 30 } else { 200 },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs one logical iteration and returns a
    /// value (black-boxed to defeat dead-code elimination).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_throughput(name, None, move || {
            f();
        })
    }

    /// Benchmark with a throughput annotation: `units` of `unit` happen
    /// per call of `f`.
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        unit: &str,
        units: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_throughput(name, Some((unit.to_string(), units)), move || {
            f();
        })
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        throughput: Option<(String, f64)>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // Warmup, and estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(&mut f)();
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose inner batch so one sample takes ~measure/max_samples.
        let target_sample = self.measure.as_secs_f64() / self.max_samples as f64;
        let batch = ((target_sample / est.max(1e-9)).round() as u64).max(1);

        let mut samples = Vec::with_capacity(self.max_samples);
        let run_start = Instant::now();
        while samples.len() < self.max_samples && run_start.elapsed() < self.measure {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(&mut f)();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        if samples.is_empty() {
            // Pathologically slow iteration: take one sample anyway.
            let t = Instant::now();
            black_box(&mut f)();
            samples.push(t.elapsed().as_secs_f64());
        }

        let result = BenchResult { name: name.to_string(), summary: Summary::of(&samples), throughput };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Opaque value sink; prevents the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_timing() {
        std::env::set_var("LPU_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.mean < 0.01, "1k mults should be well under 10ms");
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("LPU_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench_throughput("tokens", "token", 8.0, || 42u64);
        let (unit, per) = r.throughput.clone().unwrap();
        assert_eq!(unit, "token");
        assert_eq!(per, 8.0);
        assert!(r.report_line().contains("token/s"));
    }
}
