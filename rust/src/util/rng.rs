//! Deterministic pseudo-random number generation (offline `rand`
//! substitute): SplitMix64 for seeding and xoshiro256** as the main
//! generator. Used by workload generators, the sampler, property tests,
//! and synthetic weight initialization.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [lo, hi) using Lemire-style rejection.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = hi - lo;
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson
    /// request-arrival processes in the serving workload generator.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for parallel workers with stable seeds).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        for _ in 0..10_000 {
            let v = r.range(5, 8);
            assert!((5..8).contains(&v));
            saw_lo |= v == 5;
        }
        assert!(saw_lo);
    }

    #[test]
    fn range_mean_is_centered() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: u64 = (0..n).map(|_| r.range_u64(0, 100)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 49.5).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(17);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
