//! Mini property-based testing harness (offline `proptest` substitute).
//!
//! Runs a property over many generated cases with a deterministic base
//! seed, reports the failing seed/case, and performs bounded shrinking for
//! integer-vector inputs. Used by `rust/tests/proptests.rs` and module
//! unit tests for invariants (ISA round-trips, mapper disjointness,
//! scheduler conservation, ring delivery).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Seed can be overridden for reproduction via LPU_PROPTEST_SEED.
        let seed = std::env::var("LPU_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 256, seed }
    }
}

/// Run `prop` over `cfg.cases` generated cases. The property receives a
/// per-case RNG; return `Err(msg)` to fail. Panics with the case number
/// and seed on failure so the case is reproducible.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {case_seed:#x}, base {:#x}): {msg}\n\
                 reproduce with LPU_PROPTEST_SEED={}",
                cfg.cases, cfg.seed, cfg.seed
            );
        }
    }
}

/// Shorthand with the default config.
pub fn quick<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, Config::default(), prop);
}

/// Generate a vector of length in [min_len, max_len) with elements from
/// `gen`.
pub fn vec_of<T>(rng: &mut Rng, min_len: usize, max_len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = rng.range(min_len, max_len.max(min_len + 1));
    (0..n).map(|_| gen(rng)).collect()
}

/// Assert two f64s are within `tol` relative error (abs for tiny values).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    let rel = (a - b).abs() / denom;
    if rel <= tol || (a - b).abs() <= tol * 1e-6 {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rel err {rel:.3e} > {tol:.1e})"))
    }
}

/// Shrink a failing `Vec<u64>` input: try removing chunks and halving
/// elements while the property still fails; returns the smallest failing
/// input found within `budget` attempts.
pub fn shrink_vec<F>(mut input: Vec<u64>, budget: usize, mut fails: F) -> Vec<u64>
where
    F: FnMut(&[u64]) -> bool,
{
    debug_assert!(fails(&input), "shrink_vec requires a failing input");
    let mut attempts = 0;
    // Phase 1: delete chunks (binary-search style).
    let mut chunk = input.len() / 2;
    while chunk > 0 && attempts < budget {
        let mut i = 0;
        while i + chunk <= input.len() && attempts < budget {
            let mut candidate = input.clone();
            candidate.drain(i..i + chunk);
            attempts += 1;
            if fails(&candidate) {
                input = candidate;
            } else {
                i += chunk;
            }
        }
        chunk /= 2;
    }
    // Phase 2: halve individual elements toward zero.
    let mut progress = true;
    while progress && attempts < budget {
        progress = false;
        for i in 0..input.len() {
            if attempts >= budget {
                break;
            }
            if input[i] == 0 {
                continue;
            }
            let mut candidate = input.clone();
            candidate[i] /= 2;
            attempts += 1;
            if fails(&candidate) {
                input = candidate;
                progress = true;
            }
        }
    }
    input
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_true_property() {
        quick("add-commutes", |rng| {
            let a = rng.range_u64(0, 1000);
            let b = rng.range_u64(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn check_panics_with_seed_info() {
        check("always-fails", Config { cases: 4, seed: 1 }, |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut seen_a = Vec::new();
        check("collect-a", Config { cases: 8, seed: 99 }, |rng| {
            seen_a.push(rng.next_u64());
            Ok(())
        });
        let mut seen_b = Vec::new();
        check("collect-b", Config { cases: 8, seed: 99 }, |rng| {
            seen_b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
        assert!(close(0.0, 0.0, 1e-9).is_ok());
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property fails iff the vector contains an element >= 100.
        let fails = |xs: &[u64]| xs.iter().any(|&x| x >= 100);
        let input = vec![3, 7, 250, 12, 9, 180, 4];
        let shrunk = shrink_vec(input, 10_000, fails);
        assert_eq!(shrunk.len(), 1);
        assert!(shrunk[0] >= 100 && shrunk[0] < 200);
    }

    #[test]
    fn vec_of_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = vec_of(&mut rng, 2, 5, |r| r.next_u64());
            assert!((2..5).contains(&v.len()));
        }
    }
}
