//! Aligned ASCII table rendering for bench/report output. Every figure
//! and table bench prints through this so EXPERIMENTS.md rows can be
//! pasted directly from bench output.

/// A simple column-aligned table with a title and header row.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-able values.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string (also used by tests).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&format!("\n== {} ==\n", self.title));
        let sep: String = "-".repeat(total);
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&render_row(&self.header, &widths));
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row, &widths));
        }
        out.push_str(&sep);
        out.push('\n');
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as a GitHub-flavored markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.header.len())));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n*{n}*\n"));
        }
        out
    }
}

fn render_row(cells: &[String], widths: &[usize]) -> String {
    let mut line = String::from("|");
    for (c, w) in cells.iter().zip(widths) {
        let pad = w - c.chars().count();
        line.push(' ');
        line.push_str(c);
        line.push_str(&" ".repeat(pad + 1));
        line.push('|');
    }
    line.push('\n');
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "ms/token"]);
        t.row(&["opt-1.3b".into(), "1.25".into()]);
        t.row(&["opt-66b".into(), "22.2".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("| opt-1.3b | 1.25     |"));
        assert!(r.contains("| opt-66b  | 22.2     |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("md", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.note("hello");
        let md = t.to_markdown();
        assert!(md.starts_with("### md"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("*hello*"));
    }
}
