//! Minimal JSON parser/serializer (offline `serde_json` substitute).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) with precise error positions. Object key order
//! is preserved (insertion order) so serialized configs diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects preserve insertion order via a parallel key list.
    Obj(JsonObj),
}

/// Insertion-ordered string→Json map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        JsonObj::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

/// Parse error with byte offset and line/column.
#[derive(Debug, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at line {}, col {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        let mut line = 1;
        let mut col = 1;
        for &b in &self.src[..self.pos.min(self.src.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Err(JsonError { msg: msg.into(), line, col })
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            self.err(format!("expected '{kw}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("expected low surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or(()).or_else(|_| {
                                self.err::<char>("invalid surrogate pair")
                            })?);
                        } else {
                            s.push(char::from_u32(cp).ok_or(()).or_else(|_| {
                                self.err::<char>("invalid unicode escape")
                            })?);
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        if start + len > self.src.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.src[start..start + len]) {
                            Ok(frag) => {
                                s.push_str(frag);
                                self.pos = start + len;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            let d = (c as char).to_digit(16);
            match d {
                Some(d) => v = v * 16 + d,
                None => return self.err("invalid hex digit"),
            }
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("invalid number '{text}'")),
        }
    }
}

impl Json {
    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return p.err("trailing data after document");
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in pairs {
        o.insert(k, v);
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse(r#""héllo 世界""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn error_positions() {
        let e = Json::parse("{\n  \"a\": x\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 8);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{} []").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"model":"opt-66b","devices":2,"bw":3.28,"tags":["a","b"],"esl":{"overlap":true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn object_preserves_insertion_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn get_missing_returns_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
    }

    #[test]
    fn builder_obj() {
        let v = obj(vec![("a", 1u64.into()), ("b", "x".into())]);
        assert_eq!(v.get("a").as_u64(), Some(1));
        assert_eq!(v.get("b").as_str(), Some("x"));
    }
}
