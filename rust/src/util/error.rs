//! Minimal error substrate (offline `anyhow` substitute).
//!
//! A string-backed error with context chaining, plus the [`crate::err!`]
//! and [`crate::bail!`] macros. The serving runtime and backends use
//! this instead of an external error crate so the workspace builds with
//! zero dependencies.

use std::fmt;

/// A message-carrying error. Context frames added via
/// [`Context::with_context`] render outermost-first, separated by ": ".
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prepend a context frame.
    pub fn context(self, frame: impl Into<String>) -> Error {
        Error { msg: format!("{}: {}", frame.into(), self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazily-built context to a fallible result.
pub trait Context<T> {
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e)))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_debug_show_message() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = Error::msg("file missing").context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest: file missing");
    }

    #[test]
    fn with_context_on_io_errors() {
        let r: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| "reading weights".to_string()).unwrap_err();
        assert!(format!("{e}").starts_with("reading weights: "));
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: usize) -> Result<()> {
            if n > 3 {
                bail!("n too big: {n}");
            }
            Err(err!("always fails with n={n}"))
        }
        assert_eq!(format!("{}", fails(9).unwrap_err()), "n too big: 9");
        assert_eq!(format!("{}", fails(1).unwrap_err()), "always fails with n=1");
    }

    #[test]
    fn conversions() {
        let _e: Error = "static".into();
        let _e: Error = String::from("owned").into();
        let io = std::io::Error::new(std::io::ErrorKind::Other, "io");
        let e: Error = io.into();
        assert!(format!("{e}").contains("io"));
    }
}
