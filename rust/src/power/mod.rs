//! ASIC area/power model (Fig 6(a)) and server-level power/efficiency.
//!
//! The paper synthesizes the LPU in Samsung 4nm at three HBM
//! configurations and reports chip area/power (0.548/0.646/0.824 mm²,
//! 81.10/149.70/284.31 mW) plus system power including HBM stacks
//! (22/43/86 W). We reproduce those totals with a per-module linear
//! model — SXE cost per MAC tree, SMA per HBM channel group, LMU per KB
//! of SRAM, fixed ICP/OIU/VXE — with coefficients fit to the three
//! synthesized points ("SXE dominates the area and power consumption of
//! the LPU ... followed by SMA and LMU"). Residuals vs the paper are
//! asserted < 2% in tests and printed by the fig6 bench.

use crate::config::LpuConfig;

/// Per-module area (mm²) and power (mW) at 4nm/1 GHz.
#[derive(Clone, Debug, PartialEq)]
pub struct ModuleCost {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_mw: f64,
}

/// Full chip estimate.
#[derive(Clone, Debug)]
pub struct ChipEstimate {
    pub modules: Vec<ModuleCost>,
    pub config: String,
}

// Fit coefficients (4nm, 1 GHz): each module has a fixed part (control,
// base datapath, buffering) and a per-MAC-tree part (the paper scales
// MAC trees with HBM stacks, so per-tree terms absorb the SMA channel
// interfaces and LMU banking that grow alongside). Fixed parts sum to
// 0.456 mm^2 / 13.36 mW; per-tree parts to 0.0115 mm^2 / 8.467 mW —
// the least-squares fit through the paper's three synthesized configs.
const SXE_AREA_FIX: f64 = 0.150;
const SXE_AREA_PER_TREE: f64 = 0.0080;
const SMA_AREA_FIX: f64 = 0.090;
const SMA_AREA_PER_TREE: f64 = 0.0025;
const LMU_AREA_FIX: f64 = 0.060;
const LMU_AREA_PER_TREE: f64 = 0.0010;
const ICP_AREA: f64 = 0.042;
const OIU_AREA: f64 = 0.024;
const VXE_AREA: f64 = 0.090;

const SXE_POWER_FIX: f64 = 4.0;
const SXE_POWER_PER_TREE: f64 = 6.00;
const SMA_POWER_FIX: f64 = 3.0;
const SMA_POWER_PER_TREE: f64 = 1.50;
const LMU_POWER_FIX: f64 = 2.5;
const LMU_POWER_PER_TREE: f64 = 0.967;
const ICP_POWER: f64 = 1.0;
const OIU_POWER: f64 = 0.66;
const VXE_POWER: f64 = 2.2;

/// Power per HBM3 stack incl. PHY + board periphery (W), and board base.
const HBM_STACK_POWER_W: f64 = 21.43;
const BOARD_BASE_POWER_W: f64 = 0.5;

/// Estimate chip area/power for an LPU configuration.
pub fn chip_estimate(cfg: &LpuConfig) -> ChipEstimate {
    let t = cfg.mac_trees as f64;
    // Frequency/process derating for non-ASIC configs (the FPGA variant
    // is not a 4nm chip; scale dynamic power with frequency for
    // what-if sweeps only).
    let f_scale = cfg.freq_hz / 1e9;
    let modules = vec![
        ModuleCost {
            name: "SXE",
            area_mm2: SXE_AREA_FIX + SXE_AREA_PER_TREE * t,
            power_mw: (SXE_POWER_FIX + SXE_POWER_PER_TREE * t) * f_scale,
        },
        ModuleCost {
            name: "SMA",
            area_mm2: SMA_AREA_FIX + SMA_AREA_PER_TREE * t,
            power_mw: (SMA_POWER_FIX + SMA_POWER_PER_TREE * t) * f_scale,
        },
        ModuleCost {
            name: "LMU",
            area_mm2: LMU_AREA_FIX + LMU_AREA_PER_TREE * t,
            power_mw: (LMU_POWER_FIX + LMU_POWER_PER_TREE * t) * f_scale,
        },
        ModuleCost { name: "VXE", area_mm2: VXE_AREA, power_mw: VXE_POWER * f_scale },
        ModuleCost { name: "ICP", area_mm2: ICP_AREA, power_mw: ICP_POWER * f_scale },
        ModuleCost { name: "OIU", area_mm2: OIU_AREA, power_mw: OIU_POWER * f_scale },
    ];
    ChipEstimate { modules, config: cfg.name.clone() }
}

impl ChipEstimate {
    pub fn total_area_mm2(&self) -> f64 {
        self.modules.iter().map(|m| m.area_mm2).sum()
    }

    pub fn total_power_mw(&self) -> f64 {
        self.modules.iter().map(|m| m.power_mw).sum()
    }

    /// Largest module by area (the paper: SXE).
    pub fn dominant_module(&self) -> &ModuleCost {
        self.modules
            .iter()
            .max_by(|a, b| a.area_mm2.partial_cmp(&b.area_mm2).unwrap())
            .unwrap()
    }
}

/// Total LPU *system* power (chip + HBM stacks + board), watts —
/// the paper's 22/43/86 W rows.
pub fn system_power_w(cfg: &LpuConfig) -> f64 {
    chip_estimate(cfg).total_power_mw() / 1e3
        + BOARD_BASE_POWER_W
        + HBM_STACK_POWER_W * cfg.hbm.stacks as f64
}

/// FPGA accelerator-card power (Alveo U55C class, W) — used for Orion.
pub const FPGA_CARD_POWER_W: f64 = 53.5;

/// Orion server wall power: N cards + host (chassis, CPU, NIC).
pub fn orion_power_w(n_cards: usize, host_power_w: f64) -> f64 {
    n_cards as f64 * FPGA_CARD_POWER_W + host_power_w
}

/// Energy efficiency in tokens/s/kW.
pub fn tokens_per_s_per_kw(tokens_per_s: f64, power_w: f64) -> f64 {
    tokens_per_s / (power_w / 1e3)
}

/// Paper-quoted reference values for calibration tests/benches.
pub mod paper {
    /// (mac_trees, area mm², power mW) for the three ASIC configs.
    pub const CHIPS: [(usize, f64, f64); 3] =
        [(8, 0.548, 81.10), (16, 0.646, 149.70), (32, 0.824, 284.31)];
    /// (stacks, system W).
    pub const SYSTEMS: [(usize, f64); 3] = [(1, 22.0), (2, 43.0), (4, 86.0)];
    /// Orion-cloud wall power running OPT-66B (W).
    pub const ORION_CLOUD_POWER_W: f64 = 608.0;
    /// 2×H100 server wall power on OPT-66B (W).
    pub const H100_SERVER_POWER_W: f64 = 1100.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn configs() -> [LpuConfig; 3] {
        [LpuConfig::asic_819gbs(), LpuConfig::asic_1_64tbs(), LpuConfig::asic_3_28tbs()]
    }

    #[test]
    fn chip_totals_match_paper_within_2pct() {
        for (cfg, (trees, area, power)) in configs().iter().zip(paper::CHIPS) {
            assert_eq!(cfg.mac_trees, trees);
            let est = chip_estimate(cfg);
            let da = (est.total_area_mm2() - area).abs() / area;
            let dp = (est.total_power_mw() - power).abs() / power;
            assert!(da < 0.02, "{}: area {:.3} vs paper {area} (rel {da:.3})", cfg.name, est.total_area_mm2());
            assert!(dp < 0.02, "{}: power {:.2} vs paper {power} (rel {dp:.3})", cfg.name, est.total_power_mw());
        }
    }

    #[test]
    fn sxe_dominates() {
        for cfg in configs() {
            let est = chip_estimate(&cfg);
            assert_eq!(est.dominant_module().name, "SXE", "{}", cfg.name);
            // SXE followed by SMA and LMU among scaling modules.
            let get = |n: &str| est.modules.iter().find(|m| m.name == n).unwrap().area_mm2;
            assert!(get("SXE") > get("SMA") && get("SMA") > get("LMU"));
        }
    }

    #[test]
    fn system_power_matches_paper() {
        for (cfg, (stacks, watts)) in configs().iter().zip(paper::SYSTEMS) {
            assert_eq!(cfg.hbm.stacks, stacks);
            let p = system_power_w(cfg);
            let rel = (p - watts).abs() / watts;
            assert!(rel < 0.03, "{}: system {p:.1} W vs paper {watts} W", cfg.name);
        }
    }

    #[test]
    fn lpu_system_fraction_of_h100() {
        // Paper: "the LPU system requires only 15.2% of the power
        // consumption [of H100] when running OPT 30B" (86 W vs ~565 W).
        let lpu = system_power_w(&LpuConfig::asic_3_28tbs());
        let h100 = crate::gpu::GpuConfig::h100()
            .decode_power(&crate::model::by_name("opt-30b").unwrap(), 1);
        let frac = lpu / h100;
        assert!((0.12..=0.19).contains(&frac), "fraction {frac:.3}");
    }

    #[test]
    fn orion_cloud_power_near_paper() {
        let p = orion_power_w(8, crate::config::ServerConfig::orion_cloud().host_power_w);
        let rel = (p - paper::ORION_CLOUD_POWER_W).abs() / paper::ORION_CLOUD_POWER_W;
        assert!(rel < 0.03, "orion-cloud {p:.0} W vs paper 608 W");
    }

    #[test]
    fn efficiency_helper() {
        assert!((tokens_per_s_per_kw(45.0, 608.0) - 74.0).abs() < 0.1);
    }

    #[test]
    fn area_scales_sublinearly_with_trees() {
        // Fixed ICP/OIU/VXE means 4x trees << 4x area (paper: 0.548 ->
        // 0.824 for 8 -> 32 trees).
        let a8 = chip_estimate(&LpuConfig::asic_819gbs()).total_area_mm2();
        let a32 = chip_estimate(&LpuConfig::asic_3_28tbs()).total_area_mm2();
        assert!(a32 / a8 < 2.0);
    }
}
