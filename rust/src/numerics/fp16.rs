//! IEEE-754 binary16 (FP16), implemented bit-exactly in software.
//!
//! The LPU stores all weights and activations in FP16 ("LPU supports the
//! standard FP16 data precision ... no accuracy loss on popular
//! datasets"). This module provides conversions with round-to-nearest-
//! even, the arithmetic helpers the MAC-tree model needs (exponent /
//! mantissa extraction), and a reference add/mul used in tests.

/// An IEEE-754 half-precision value stored as its raw 16-bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct F16(pub u16);

#[allow(dead_code)]
const EXP_BITS: u32 = 5;
const MAN_BITS: u32 = 10;
const EXP_BIAS: i32 = 15;

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const NEG_ZERO: F16 = F16(0x8000);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    /// Largest finite value, 65504.
    pub const MAX: F16 = F16(0x7BFF);
    /// Smallest positive normal, 2^-14.
    pub const MIN_POSITIVE: F16 = F16(0x0400);

    /// Convert from f32 with round-to-nearest-even (the hardware rounding
    /// mode). Handles subnormals, overflow to infinity, and NaN payloads.
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // Inf or NaN.
            return if man == 0 {
                F16(sign | 0x7C00)
            } else {
                // Quiet NaN, preserve a nonzero payload bit.
                F16(sign | 0x7C00 | 0x0200 | ((man >> 13) as u16 & 0x3FF).max(1) & 0x3FF)
            };
        }

        // Unbiased exponent.
        let e = exp - 127;
        if e > 15 {
            return F16(sign | 0x7C00); // overflow -> inf
        }
        if e >= -14 {
            // Normal range. 23-bit mantissa -> 10-bit with RNE.
            let man16 = man >> 13;
            let rem = man & 0x1FFF;
            let mut h = sign | (((e + EXP_BIAS) as u16) << MAN_BITS) | man16 as u16;
            // Round to nearest even.
            if rem > 0x1000 || (rem == 0x1000 && (man16 & 1) == 1) {
                h = h.wrapping_add(1); // may carry into exponent: correct (rounds up to inf)
            }
            return F16(h);
        }
        if e >= -25 {
            // Subnormal half. Implicit leading 1 becomes explicit.
            let full = man | 0x80_0000;
            let shift = (-14 - e) as u32 + 13;
            let man16 = full >> shift;
            let rem = full & ((1 << shift) - 1);
            let half = 1u32 << (shift - 1);
            let mut h = sign | man16 as u16;
            if rem > half || (rem == half && (man16 & 1) == 1) {
                h = h.wrapping_add(1);
            }
            return F16(h);
        }
        F16(sign) // underflow to signed zero
    }

    /// Convert to f32 exactly (every f16 is representable).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> MAN_BITS) & 0x1F) as u32;
        let man = (self.0 & 0x3FF) as u32;
        let bits = if exp == 0 {
            if man == 0 {
                sign // signed zero
            } else {
                // Subnormal: normalize. man = 1.x * 2^(b - 24) where b is
                // the highest set bit; f32 exponent field = 103 + b.
                let lz = man.leading_zeros() - 21; // zeros within the 10-bit field
                // Shift the leading 1 to bit 10 (the implicit-bit slot);
                // bits below it become the f32 mantissa's top bits.
                let shifted = man << lz;
                let e = 113 - lz; // f32 exponent field = 103 + highest-set-bit
                sign | (e << 23) | ((shifted & 0x3FF) << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (man << 13) // inf/nan
        } else {
            sign | ((exp + 127 - 15) << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }

    pub fn is_sign_negative(self) -> bool {
        self.0 & 0x8000 != 0
    }

    /// Raw biased exponent field (0..=31).
    pub fn biased_exp(self) -> u16 {
        (self.0 >> MAN_BITS) & 0x1F
    }

    /// Unbiased exponent of the value interpreted with its implicit bit;
    /// subnormals report -14 (their effective scale).
    pub fn effective_exp(self) -> i32 {
        let e = self.biased_exp();
        if e == 0 { 1 - EXP_BIAS } else { e as i32 - EXP_BIAS }
    }

    /// Significand including the implicit bit, as an 11-bit integer
    /// (subnormals have no implicit bit).
    pub fn significand(self) -> u16 {
        let man = self.0 & 0x3FF;
        if self.biased_exp() == 0 { man } else { man | 0x400 }
    }

    /// FP16 multiplication modelled as f32 multiply + RNE demotion — this
    /// matches an exact-significand hardware multiplier (11×11-bit product
    /// fits in f32's 24-bit significand exactly, so no double rounding).
    pub fn mul(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() * rhs.to_f32())
    }

    /// FP16 addition with intermediate f32 (exact for f16 operands).
    pub fn add(self, rhs: F16) -> F16 {
        F16::from_f32(self.to_f32() + rhs.to_f32())
    }
}

impl From<f32> for F16 {
    fn from(x: f32) -> F16 {
        F16::from_f32(x)
    }
}

impl From<F16> for f32 {
    fn from(h: F16) -> f32 {
        h.to_f32()
    }
}

/// Quantize an f32 slice to FP16 bits (storage format of weights in HBM).
pub fn quantize(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| F16::from_f32(x).0).collect()
}

/// Dequantize FP16 bits to f32.
pub fn dequantize(bits: &[u16]) -> Vec<f32> {
    bits.iter().map(|&b| F16(b).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_constants() {
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(1.0).0, 0x3C00);
        assert_eq!(F16::from_f32(-2.0).0, 0xC000);
        assert_eq!(F16::from_f32(65504.0).0, 0x7BFF);
        assert_eq!(F16::from_f32(0.5).0, 0x3800);
        assert_eq!(F16::from_f32(f32::INFINITY).0, 0x7C00);
        assert!(F16::from_f32(f32::NAN).is_nan());
    }

    #[test]
    fn overflow_rounds_to_infinity() {
        assert_eq!(F16::from_f32(65520.0).0, 0x7C00); // rounds up past MAX
        assert_eq!(F16::from_f32(-1e9).0, 0xFC00);
        // 65519.996 rounds down to MAX
        assert_eq!(F16::from_f32(65519.0), F16::MAX);
    }

    #[test]
    fn subnormals_roundtrip() {
        let tiny = 2.0f32.powi(-24); // smallest positive subnormal
        let h = F16::from_f32(tiny);
        assert_eq!(h.0, 0x0001);
        assert_eq!(h.to_f32(), tiny);
        // Below half of the smallest subnormal underflows to zero.
        assert_eq!(F16::from_f32(tiny / 4.0).0, 0);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 -> rounds to even (1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(x).0, 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> rounds to even (1+2^-9... check lsb).
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(y).0, 0x3C02);
    }

    #[test]
    fn all_f16_values_roundtrip_exactly() {
        // Every finite f16 -> f32 -> f16 must be the identity.
        for bits in 0u16..=0xFFFF {
            let h = F16(bits);
            if h.is_nan() {
                assert!(F16::from_f32(h.to_f32()).is_nan());
            } else {
                let back = F16::from_f32(h.to_f32());
                assert_eq!(back.0, bits, "bits {bits:#06x} -> {} -> {:#06x}", h.to_f32(), back.0);
            }
        }
    }

    #[test]
    fn conversion_matches_rounding_oracle() {
        // Random f32s: conversion must land on the nearest representable
        // f16 (ties to even), verified by scanning neighbors.
        let mut rng = Rng::new(2024);
        for _ in 0..20_000 {
            let x = (rng.f32() - 0.5) * 130000.0;
            let h = F16::from_f32(x);
            if h.is_infinite() || h.is_nan() {
                continue;
            }
            let fx = h.to_f32();
            let err = (fx - x).abs();
            // Any adjacent representable value must not be strictly closer.
            for delta in [-1i32, 1] {
                let nb = F16(h.0.wrapping_add(delta as u16));
                if nb.is_finite() && nb.is_sign_negative() == h.is_sign_negative() {
                    let nerr = (nb.to_f32() - x).abs();
                    assert!(nerr >= err - err * 1e-6, "x={x}: chose {fx}, neighbor {} closer", nb.to_f32());
                }
            }
        }
    }

    #[test]
    fn significand_and_exponent_fields() {
        let h = F16::from_f32(3.0); // 1.5 * 2^1
        assert_eq!(h.effective_exp(), 1);
        assert_eq!(h.significand(), 0x600); // 1.1_2 << 10
        let sub = F16(0x0001);
        assert_eq!(sub.effective_exp(), -14);
        assert_eq!(sub.significand(), 1);
    }

    #[test]
    fn mul_add_basic() {
        let a = F16::from_f32(1.5);
        let b = F16::from_f32(2.0);
        assert_eq!(a.mul(b).to_f32(), 3.0);
        assert_eq!(a.add(b).to_f32(), 3.5);
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let xs = vec![0.1f32, -2.5, 100.0, 0.0];
        let back = dequantize(&quantize(&xs));
        for (x, b) in xs.iter().zip(&back) {
            assert!((x - b).abs() <= x.abs() * 1e-3 + 1e-6);
        }
    }
}
