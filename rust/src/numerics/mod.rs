//! Bit-accurate arithmetic models for the LPU datapath.
//!
//! The paper's SXE executes FP16 vector–matrix multiplication with MAC
//! trees that "preprocess the operands based on the exponent and mantissa
//! of the larger floating-point operand [to] enable fixed-point
//! multiplication and accumulation", summed by a Wallace-tree adder.
//! [`fp16`] implements IEEE-754 binary16 conversion exactly; [`mactree`]
//! implements the shared-exponent fixed-point accumulation scheme and
//! bounds its error against an f64 oracle; [`sampler`] implements the
//! VXE's logit sampler (temperature / top-k / top-p with sort).

pub mod fp16;
pub mod mactree;
pub mod sampler;

pub use fp16::F16;
pub use mactree::MacTree;
pub use sampler::{SampleParams, Sampler};
