//! Functional model of the SXE MAC tree.
//!
//! Paper (SXE §): each MAC tree consumes `v` FP16 operand pairs per cycle.
//! "The preprocessing of the operands based on the exponent and mantissa
//! of the larger floating-point operand enables the fixed-point
//! multiplication and accumulation", and "the fixed-point adder tree for
//! mantissa utilizes a Wallace tree for high-speed addition".
//!
//! We model that scheme bit-accurately:
//!   1. each pair (a, b) produces an exact 22-bit significand product with
//!      exponent ea + eb (FP16 significands are ≤ 11 bits, so products
//!      are exact in 22 bits);
//!   2. products are aligned to the *largest* product exponent in the
//!      group (the "larger floating-point operand" preprocessing) and
//!      accumulated in a wide two's-complement fixed-point register (the
//!      Wallace-tree model — associativity-free integer addition, so the
//!      result is independent of summation order, unlike float adds);
//!   3. the final sum is renormalized and rounded once to FP16 (or kept
//!      in FP32 for the partial-sum path that feeds the psum buffers).
//!
//! The accumulator carries `ACC_GUARD` guard bits; products whose aligned
//! magnitude falls entirely below the guard range are truncated, exactly
//! as a hardware right-shifter would.

use super::fp16::F16;

/// Guard bits kept below the largest product's LSB during alignment.
/// 2·11-bit significand products aligned with 40 guard bits cover the
/// entire finite FP16 exponent range (e_max - e_min = 30+30), so with
/// v ≤ 4096 the accumulation is *exact* for all finite inputs.
const ACC_GUARD: u32 = 80;

/// A `v`-wide MAC tree.
#[derive(Clone, Debug)]
pub struct MacTree {
    /// Number of FP16 operand pairs consumed per cycle (paper: v = 64).
    pub width: usize,
}

impl MacTree {
    pub fn new(width: usize) -> Self {
        assert!(width > 0 && width <= 4096);
        MacTree { width }
    }

    /// One MAC-tree reduction: dot(a, b) over exactly `width` pairs,
    /// computed with the shared-exponent fixed-point scheme. Returns the
    /// full-precision result as f64 (the psum path) — callers round to
    /// FP16/FP32 where the hardware writes back.
    pub fn reduce(&self, a: &[F16], b: &[F16]) -> f64 {
        assert_eq!(a.len(), self.width, "operand a width");
        assert_eq!(b.len(), self.width, "operand b width");

        // Step 1: exact signed significand products + exponents.
        let mut prods: Vec<(i64, i32)> = Vec::with_capacity(self.width);
        let mut max_exp = i32::MIN;
        for (&x, &y) in a.iter().zip(b) {
            debug_assert!(x.is_finite() && y.is_finite(), "MAC tree operands must be finite");
            let sig = x.significand() as i64 * y.significand() as i64; // <= 22 bits
            if sig == 0 {
                continue;
            }
            // Product exponent: value = sig * 2^(ex + ey - 20)
            let e = x.effective_exp() + y.effective_exp() - 20;
            let neg = x.is_sign_negative() ^ y.is_sign_negative();
            prods.push((if neg { -sig } else { sig }, e));
            max_exp = max_exp.max(e);
        }
        if prods.is_empty() {
            return 0.0;
        }

        // Step 2: align to max exponent and accumulate in fixed point.
        // acc holds units of 2^(max_exp - ACC_GUARD).
        let mut acc: i128 = 0;
        for (sig, e) in prods {
            let shift = ACC_GUARD as i32 - (max_exp - e);
            if shift >= 0 {
                acc += (sig as i128) << shift;
            } else if shift > -63 {
                // Hardware truncation of bits below the guard range.
                acc += (sig as i128) >> (-shift);
            }
            // else: product entirely below guard range -> dropped.
        }

        // Step 3: renormalize.
        acc as f64 * 2f64.powi(max_exp - ACC_GUARD as i32)
    }

    /// Dot product of an activation vector with one matrix column tile,
    /// rounding the final result to FP16 (register-file writeback path).
    pub fn reduce_f16(&self, a: &[F16], b: &[F16]) -> F16 {
        F16::from_f32(self.reduce(a, b) as f32)
    }

    /// Full vector–matrix multiply as executed over tiles: `x` (len k) ×
    /// `w` (k×n, column-major tiles of `width` rows). Accumulates tile
    /// partial sums in f64 psum registers (the paper's vertical tile
    /// order: a column's dot product finishes before the next begins).
    pub fn vecmat(&self, x: &[F16], w: &[F16], n: usize) -> Vec<f64> {
        let k = x.len();
        assert_eq!(w.len(), k * n, "weight shape");
        assert_eq!(k % self.width, 0, "k must tile by MAC width");
        let tiles = k / self.width;
        let mut out = vec![0.0f64; n];
        for (j, o) in out.iter_mut().enumerate() {
            let col = &w[j * k..(j + 1) * k];
            let mut psum = 0.0f64;
            for t in 0..tiles {
                let lo = t * self.width;
                let hi = lo + self.width;
                psum += self.reduce(&x[lo..hi], &col[lo..hi]);
            }
            *o = psum;
        }
        out
    }

    /// Cycles to stream a k×n vecmat through `trees` parallel MAC trees
    /// (one tile of `width` elements per tree per cycle) plus pipeline
    /// fill. This is the SXE timing contract the cycle simulator uses.
    pub fn vecmat_cycles(&self, k: usize, n: usize, trees: usize, pipeline_depth: u64) -> u64 {
        let tiles_per_col = k.div_ceil(self.width) as u64;
        let col_groups = n.div_ceil(trees) as u64;
        tiles_per_col * col_groups + pipeline_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, quick};
    use crate::util::rng::Rng;

    fn f16v(xs: &[f32]) -> Vec<F16> {
        xs.iter().map(|&x| F16::from_f32(x)).collect()
    }

    #[test]
    fn reduce_matches_exact_small() {
        let t = MacTree::new(4);
        let a = f16v(&[1.0, 2.0, 3.0, 4.0]);
        let b = f16v(&[0.5, 0.25, -1.0, 2.0]);
        // 0.5 + 0.5 - 3 + 8 = 6
        assert_eq!(t.reduce(&a, &b), 6.0);
    }

    #[test]
    fn reduce_zero_vectors() {
        let t = MacTree::new(8);
        let z = vec![F16::ZERO; 8];
        assert_eq!(t.reduce(&z, &z), 0.0);
    }

    #[test]
    fn reduce_is_exact_vs_f64_oracle() {
        // With 80 guard bits the fixed-point accumulation is exact for
        // FP16 inputs, so it must match the f64 dot product exactly.
        let mut rng = Rng::new(7);
        let t = MacTree::new(64);
        for _ in 0..200 {
            let a: Vec<F16> = (0..64).map(|_| F16::from_f32((rng.f32() - 0.5) * 8.0)).collect();
            let b: Vec<F16> = (0..64).map(|_| F16::from_f32((rng.f32() - 0.5) * 8.0)).collect();
            let oracle: f64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| x.to_f32() as f64 * y.to_f32() as f64)
                .sum();
            let got = t.reduce(&a, &b);
            assert!(
                (got - oracle).abs() <= oracle.abs() * 1e-12 + 1e-15,
                "got {got}, oracle {oracle}"
            );
        }
    }

    #[test]
    fn reduce_order_invariant() {
        // Fixed-point accumulation is associative: shuffling pairs must
        // give bit-identical results (floats would not).
        let mut rng = Rng::new(11);
        let t = MacTree::new(32);
        let a: Vec<F16> = (0..32).map(|_| F16::from_f32((rng.f32() - 0.5) * 100.0)).collect();
        let b: Vec<F16> = (0..32).map(|_| F16::from_f32((rng.f32() - 0.5) * 100.0)).collect();
        let base = t.reduce(&a, &b);
        let mut idx: Vec<usize> = (0..32).collect();
        for _ in 0..10 {
            rng.shuffle(&mut idx);
            let ap: Vec<F16> = idx.iter().map(|&i| a[i]).collect();
            let bp: Vec<F16> = idx.iter().map(|&i| b[i]).collect();
            assert_eq!(t.reduce(&ap, &bp).to_bits(), base.to_bits());
        }
    }

    #[test]
    fn reduce_extreme_exponent_spread() {
        let t = MacTree::new(3);
        // max normal * 1 + tiny subnormal products: exact sum.
        let a = vec![F16::MAX, F16(0x0001), F16(0x0001)];
        let b = vec![F16::ONE, F16(0x0001), F16::ONE];
        let oracle = 65504.0 + 2f64.powi(-48) + 2f64.powi(-24);
        let got = t.reduce(&a, &b);
        assert!((got - oracle).abs() / oracle < 1e-12);
    }

    #[test]
    fn vecmat_matches_columnwise_reduce() {
        let mut rng = Rng::new(3);
        let t = MacTree::new(16);
        let k = 32;
        let n = 5;
        let x: Vec<F16> = (0..k).map(|_| F16::from_f32(rng.f32() - 0.5)).collect();
        let w: Vec<F16> = (0..k * n).map(|_| F16::from_f32(rng.f32() - 0.5)).collect();
        let out = t.vecmat(&x, &w, n);
        for (j, &o) in out.iter().enumerate() {
            let oracle: f64 = (0..k)
                .map(|i| x[i].to_f32() as f64 * w[j * k + i].to_f32() as f64)
                .sum();
            assert!((o - oracle).abs() <= oracle.abs() * 1e-12 + 1e-15);
        }
    }

    #[test]
    fn vecmat_cycles_formula() {
        let t = MacTree::new(64);
        // k=128 (2 tiles/col), n=32 over 32 trees (1 col group), depth 10.
        assert_eq!(t.vecmat_cycles(128, 32, 32, 10), 2 * 1 + 10);
        // n=33 needs 2 col groups.
        assert_eq!(t.vecmat_cycles(128, 33, 32, 10), 2 * 2 + 10);
        // non-multiple k rounds up.
        assert_eq!(t.vecmat_cycles(100, 32, 32, 0), 2);
    }

    #[test]
    fn prop_reduce_linear_in_scalar() {
        // reduce(2a, b) == 2 reduce(a, b) when 2a stays representable.
        quick("mactree-scaling", |rng| {
            let t = MacTree::new(8);
            let a: Vec<F16> = (0..8).map(|_| F16::from_f32((rng.f32() - 0.5) * 4.0)).collect();
            let b: Vec<F16> = (0..8).map(|_| F16::from_f32((rng.f32() - 0.5) * 4.0)).collect();
            let a2: Vec<F16> = a.iter().map(|&x| F16::from_f32(x.to_f32() * 2.0)).collect();
            close(t.reduce(&a2, &b), 2.0 * t.reduce(&a, &b), 1e-9)
        });
    }
}
