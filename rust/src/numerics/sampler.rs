//! The VXE sampler: "a sampler that sorts logits and selects an output
//! token based on temperature, top-p, and top-k values."
//!
//! This is both the functional model used by the cycle simulator's VXE
//! and the *actual* sampler the serving runtime applies to logits coming
//! back from the PJRT-executed decoder, so its numerics matter.

use crate::util::rng::Rng;

/// Sampling hyperparameters, HuggingFace-compatible semantics.
#[derive(Clone, Copy, Debug)]
pub struct SampleParams {
    /// Softmax temperature; 0.0 (or `do_sample = false`) means greedy.
    pub temperature: f32,
    /// Keep only the k highest logits (0 = disabled).
    pub top_k: usize,
    /// Nucleus sampling threshold in (0, 1]; 1.0 = disabled.
    pub top_p: f32,
    /// If false, always pick the argmax.
    pub do_sample: bool,
}

impl Default for SampleParams {
    fn default() -> Self {
        SampleParams { temperature: 1.0, top_k: 0, top_p: 1.0, do_sample: false }
    }
}

impl SampleParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn sampled(temperature: f32, top_k: usize, top_p: f32) -> Self {
        SampleParams { temperature, top_k, top_p, do_sample: true }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.do_sample {
            if !(self.temperature > 0.0) {
                return Err(format!("temperature must be > 0 when sampling, got {}", self.temperature));
            }
            if !(self.top_p > 0.0 && self.top_p <= 1.0) {
                return Err(format!("top_p must be in (0,1], got {}", self.top_p));
            }
        }
        Ok(())
    }
}

/// Stateful sampler (owns its RNG stream for reproducible generation).
#[derive(Clone, Debug)]
pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    pub fn new(seed: u64) -> Self {
        Sampler { rng: Rng::new(seed) }
    }

    /// Select a token id from raw logits.
    pub fn sample(&mut self, logits: &[f32], p: &SampleParams) -> usize {
        assert!(!logits.is_empty());
        if !p.do_sample || p.temperature == 0.0 {
            return argmax(logits);
        }
        // Sort candidate indices by logit, descending — the paper's VXE
        // "sorts logits" in hardware; we do the same then cut by k and p.
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal));

        let keep_k = if p.top_k == 0 { idx.len() } else { p.top_k.min(idx.len()) };
        let idx = &idx[..keep_k];

        // Temperature softmax over the kept set (numerically stabilized).
        let max = logits[idx[0]];
        let mut probs: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - max) / p.temperature) as f64).exp())
            .collect();
        let sum: f64 = probs.iter().sum();
        for q in &mut probs {
            *q /= sum;
        }

        // Nucleus cut: smallest prefix with cumulative prob >= top_p.
        let mut keep = probs.len();
        if p.top_p < 1.0 {
            let mut cum = 0.0;
            for (i, &q) in probs.iter().enumerate() {
                cum += q;
                if cum >= p.top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
        }
        let probs = &probs[..keep];
        let renorm: f64 = probs.iter().sum();

        // Inverse-CDF draw.
        let mut u = self.rng.f64() * renorm;
        for (i, &q) in probs.iter().enumerate() {
            u -= q;
            if u <= 0.0 {
                return idx[i];
            }
        }
        idx[keep - 1]
    }
}

/// Argmax with first-wins tie-breaking (matches jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Numerically-stable softmax (VXE reference; also used in tests against
/// the XLA-computed softmax).
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|&x| (x - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(1);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        for _ in 0..10 {
            assert_eq!(s.sample(&logits, &SampleParams::greedy()), 1);
        }
    }

    #[test]
    fn temperature_zero_is_greedy_even_when_sampling() {
        let mut s = Sampler::new(2);
        let p = SampleParams { temperature: 0.0, top_k: 0, top_p: 1.0, do_sample: true };
        assert_eq!(s.sample(&[0.0, 5.0, 1.0], &p), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(3);
        let logits = vec![10.0, 9.0, -50.0, -60.0];
        let p = SampleParams::sampled(1.0, 2, 1.0);
        for _ in 0..200 {
            let t = s.sample(&logits, &p);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        let mut s = Sampler::new(4);
        // softmax ~ [0.665, 0.245, 0.09]; top_p=0.6 keeps only token 0.
        let logits = vec![2.0, 1.0, 0.0];
        let p = SampleParams::sampled(1.0, 0, 0.6);
        for _ in 0..200 {
            assert_eq!(s.sample(&logits, &p), 0);
        }
    }

    #[test]
    fn sampling_frequencies_track_softmax() {
        let mut s = Sampler::new(5);
        let logits = vec![1.0f32, 0.0, -1.0];
        let probs = softmax(&logits);
        let p = SampleParams::sampled(1.0, 0, 1.0);
        let n = 50_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[s.sample(&logits, &p)] += 1;
        }
        for i in 0..3 {
            let freq = counts[i] as f32 / n as f32;
            assert!((freq - probs[i]).abs() < 0.01, "token {i}: freq {freq} vs prob {}", probs[i]);
        }
    }

    #[test]
    fn high_temperature_flattens() {
        let mut s = Sampler::new(6);
        let logits = vec![2.0f32, 0.0];
        let hot = SampleParams::sampled(100.0, 0, 1.0);
        let n = 20_000;
        let picks0 = (0..n).filter(|_| s.sample(&logits, &hot) == 0).count();
        let frac = picks0 as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "hot sampling should be ~uniform, got {frac}");
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let xs = vec![1000.0f32, 999.0, 998.0];
        let p = softmax(&xs);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[0] > p[1] && p[1] > p[2]);
        assert!(p.iter().all(|q| q.is_finite()));
    }

    #[test]
    fn validate_params() {
        assert!(SampleParams::sampled(0.0, 0, 1.0).validate().is_err());
        assert!(SampleParams::sampled(1.0, 0, 0.0).validate().is_err());
        assert!(SampleParams::sampled(0.7, 50, 0.9).validate().is_ok());
        assert!(SampleParams::greedy().validate().is_ok());
    }

    #[test]
    fn deterministic_stream() {
        let logits = vec![0.5f32, 0.4, 0.3, 0.2];
        let p = SampleParams::sampled(1.0, 0, 1.0);
        let mut a = Sampler::new(42);
        let mut b = Sampler::new(42);
        let sa: Vec<usize> = (0..64).map(|_| a.sample(&logits, &p)).collect();
        let sb: Vec<usize> = (0..64).map(|_| b.sample(&logits, &p)).collect();
        assert_eq!(sa, sb);
    }
}
