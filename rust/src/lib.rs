//! # LPU — Latency Processing Unit (full-system reproduction)
//!
//! This crate reproduces HyperAccel's LPU (IEEE Micro 2024): a
//! latency-optimized, highly scalable processor for large language model
//! inference, together with every substrate the paper depends on:
//!
//! * [`isa`] — the custom LPU instruction set (Table 1) with an
//!   assembler/disassembler and binary encoding.
//! * [`hbm`] — an HBM3 timing model (the paper integrates ramulator; we
//!   implement an equivalent channel/bank/burst-timing simulator).
//! * [`sim`] — the cycle-accurate LPU core simulator: SMA, OIU, SXE
//!   (MAC trees), VXE, ICP (scoreboard + out-of-order dispatch), LMU.
//! * [`esl`] — the Expandable Synchronization Link: ring P2P interconnect
//!   with compute/communication overlap and reconfigurable 2/4/8-device
//!   rings.
//! * [`compiler`] — the HyperDex compilation layer: model & memory mapper,
//!   instruction generator, register allocator, instruction chaining.
//! * [`model`] — LLM architecture descriptions (OPT/GPT/Llama families)
//!   and parameter/FLOP/byte accounting.
//! * [`gpu`] — analytical GPU baselines (H100/A100/L4) calibrated to the
//!   paper's measured utilization/power, incl. the NVLink sync model.
//! * [`power`] — ASIC area/power model reproducing Figure 6(a).
//! * [`runtime`] — artifact manifests for the AOT-lowered JAX/Pallas
//!   decoder; PJRT execution is gated off in this offline build.
//! * [`coordinator`] — the **continuous-batching serving layer**:
//!   **affinity-aware request routing** (per-worker addressable queues
//!   with spill/steal, a cross-worker prefix registry, and pluggable
//!   round-robin / least-loaded / prefix-affinity steering), per-worker
//!   slot tables with mid-decode admission bounded by
//!   a KV-memory budget (worst-case reservation or a **paged
//!   reserve-as-you-grow allocator** with lowest-progress preemption and
//!   recompute-on-readmit), batched fused decode steps (weights stream
//!   once per step), **single-pass or chunked prefill** (token-budgeted
//!   prompt chunks interleaved with decode steps so long prompts stop
//!   inflating neighbors' TPOT), **copy-on-write prefix caching**
//!   (refcounted blocks + a block-granular prefix index, so shared
//!   prompt prefixes hold one physical copy and skip their prefill),
//!   pluggable scheduler policies (FCFS /
//!   round-robin / shortest-first), **deterministic fault injection
//!   with bounded retry and worker failover** (seeded transient step
//!   errors, whole-worker crashes with lane salvage onto healthy
//!   siblings, slow workers — same plan, same recovery, both serving
//!   paths), p50/p95/p99 TTFT+TPOT metrics with
//!   KV-utilization, preemption, prefill, routing-balance, and fault
//!   gauges, a
//!   seeded Poisson load generator, and a deterministic virtual-time
//!   load harness.
//!   Submodules: [`coordinator::lane`] (the shared lane-state core both
//!   serving paths drive), [`coordinator::router`] (steering, queues,
//!   and the prefix registry — also shared by both paths),
//!   [`coordinator::faults`] (the fault plan + taxonomy driving both
//!   paths' recovery),
//!   [`coordinator::cluster`] (the SLO-aware replica fleet: tier
//!   classification, deadline-aware admission with load shedding, and
//!   step-driven autoscaling over N pools — one front-end decision core
//!   shared by both paths),
//!   [`coordinator::scheduler`],
//!   [`coordinator::backend`], [`coordinator::metrics`],
//!   [`coordinator::workload`]. See `ARCHITECTURE.md` at the repo root
//!   for the request lifecycle and a where-to-add-a-feature map.
//! * [`server`] — a minimal threaded TCP/JSON-line server + client.
//! * [`numerics`] — bit-accurate FP16 and the MAC-tree arithmetic model.
//! * [`util`] — in-tree substrates: JSON, PRNG, stats, errors, mini
//!   property testing, bench harness (offline environment: zero external
//!   crates).

pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod esl;
pub mod gpu;
pub mod hbm;
pub mod isa;
pub mod model;
pub mod numerics;
pub mod power;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;

pub use config::LpuConfig;
pub use model::ModelConfig;
