//! System configurations: the three synthesized ASIC variants of Fig 6(a),
//! the Alveo U55C FPGA variant, and the two Orion server products.
//!
//! Configs serialize to/from JSON (via the in-tree [`crate::util::json`])
//! so deployments are file-driven like any production launcher.

use crate::util::json::{obj, Json};

/// HBM generation (timing preset selector for the [`crate::hbm`] model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HbmGen {
    Hbm2,
    Hbm3,
}

/// Memory subsystem configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct HbmConfig {
    pub gen: HbmGen,
    /// Number of HBM stacks.
    pub stacks: usize,
    /// Peak bandwidth per stack, bytes/s (HBM3 Icebolt: 819 GB/s).
    pub bw_per_stack: f64,
    /// Capacity per stack, bytes (HBM3 Icebolt: 24 GB).
    pub cap_per_stack: u64,
    /// Pseudo-channels per stack (HBM3: 16).
    pub channels_per_stack: usize,
}

impl HbmConfig {
    pub fn peak_bw(&self) -> f64 {
        self.bw_per_stack * self.stacks as f64
    }

    pub fn capacity(&self) -> u64 {
        self.cap_per_stack * self.stacks as u64
    }

    pub fn channels(&self) -> usize {
        self.channels_per_stack * self.stacks
    }
}

/// One LPU device configuration (chip + memory + link).
#[derive(Clone, Debug, PartialEq)]
pub struct LpuConfig {
    pub name: String,
    /// Core clock, Hz (ASIC: 1 GHz; FPGA: 220 MHz).
    pub freq_hz: f64,
    /// MAC-tree vector width v (paper fixes 64).
    pub vec_dim: usize,
    /// Number of MAC trees l (8/16/32 for the ASIC configs).
    pub mac_trees: usize,
    /// SXE pipeline depth in cycles (superpipelined MAC + writeback).
    pub pipeline_depth: u64,
    /// VXE throughput, elements/cycle.
    pub vxe_lanes: usize,
    /// VXE fixed startup latency per vector op, cycles.
    pub vxe_latency: u64,
    /// ICP dispatch overhead per instruction chain, cycles.
    pub icp_dispatch: u64,
    pub hbm: HbmConfig,
    /// ESL link bandwidth per direction, bytes/s (dual QSFP28 = 2×100Gb/s
    /// on Orion; ASIC assumes the same board-level links).
    pub esl_bw: f64,
    /// ESL per-hop router latency, seconds.
    pub esl_hop_latency: f64,
    /// On-chip SRAM (LMU + buffers), bytes — from Fig 6(a).
    pub sram_bytes: u64,
}

impl LpuConfig {
    /// Engine streaming bandwidth = l × v × 2B × freq; the paper chooses
    /// `mac_trees` so this exactly matches HBM peak bandwidth.
    pub fn engine_bw(&self) -> f64 {
        self.mac_trees as f64 * self.vec_dim as f64 * 2.0 * self.freq_hz
    }

    /// Bandwidth balance ratio (≈1.0 when engines match memory).
    pub fn balance(&self) -> f64 {
        self.engine_bw() / self.hbm.peak_bw()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.vec_dim == 0 || self.mac_trees == 0 {
            return Err("degenerate SXE config".into());
        }
        let b = self.balance();
        if !(0.5..=2.0).contains(&b) {
            return Err(format!(
                "{}: engine/memory bandwidth imbalance {b:.2} (engines {:.2e} B/s vs HBM {:.2e} B/s)",
                self.name,
                self.engine_bw(),
                self.hbm.peak_bw()
            ));
        }
        Ok(())
    }

    // ---- presets ----

    fn hbm3(stacks: usize) -> HbmConfig {
        HbmConfig {
            gen: HbmGen::Hbm3,
            stacks,
            bw_per_stack: 819e9,
            cap_per_stack: 24_000_000_000,
            channels_per_stack: 16,
        }
    }

    /// ASIC, 1 HBM3 stack: 819 GB/s, 8 MAC trees (Fig 6a col 1).
    pub fn asic_819gbs() -> LpuConfig {
        LpuConfig {
            name: "lpu-asic-819gbs".into(),
            freq_hz: 1e9,
            vec_dim: 64,
            mac_trees: 8,
            pipeline_depth: 12,
            vxe_lanes: 16,
            vxe_latency: 24,
            icp_dispatch: 4,
            hbm: Self::hbm3(1),
            esl_bw: 25e9, // 2×100 Gb/s full duplex
            // QSFP28 serdes + RS-FEC + router traversal per hop.
            esl_hop_latency: 1.0e-6,
            sram_bytes: 812 * 1024,
        }
    }

    /// ASIC, 2 HBM3 stacks: 1.64 TB/s, 16 MAC trees (Fig 6a col 2).
    pub fn asic_1_64tbs() -> LpuConfig {
        LpuConfig {
            name: "lpu-asic-1.64tbs".into(),
            mac_trees: 16,
            hbm: Self::hbm3(2),
            sram_bytes: 910 * 1024,
            ..Self::asic_819gbs()
        }
    }

    /// ASIC, 4 HBM3 stacks: 3.28 TB/s, 32 MAC trees (Fig 6a col 3; the
    /// configuration compared against H100 in Fig 7).
    pub fn asic_3_28tbs() -> LpuConfig {
        LpuConfig {
            name: "lpu-asic-3.28tbs".into(),
            mac_trees: 32,
            hbm: Self::hbm3(4),
            sram_bytes: 1_107 * 1024,
            ..Self::asic_819gbs()
        }
    }

    /// Alveo U55C FPGA implementation: 220 MHz, 16 MAC trees, HBM2
    /// 460 GB/s / 16 GB (the Orion building block).
    pub fn fpga_u55c() -> LpuConfig {
        LpuConfig {
            name: "lpu-fpga-u55c".into(),
            freq_hz: 220e6,
            vec_dim: 64,
            mac_trees: 16,
            pipeline_depth: 16,
            vxe_lanes: 16,
            vxe_latency: 32,
            icp_dispatch: 4,
            hbm: HbmConfig {
                gen: HbmGen::Hbm2,
                stacks: 2,
                bw_per_stack: 230e9,
                // "16 GB" is 16 GiB physically (paper: "memory space is
                // labeled in decimal prefix but has physical capacity
                // based on the binary prefix") — the 66B-on-Orion fit
                // depends on it.
                cap_per_stack: 8 << 30,
                channels_per_stack: 16,
            },
            esl_bw: 25e9,
            esl_hop_latency: 1.2e-6,
            sram_bytes: 910 * 1024,
        }
    }

    pub fn by_name(name: &str) -> Option<LpuConfig> {
        match name {
            "lpu-asic-819gbs" | "819gbs" => Some(Self::asic_819gbs()),
            "lpu-asic-1.64tbs" | "1.64tbs" => Some(Self::asic_1_64tbs()),
            "lpu-asic-3.28tbs" | "3.28tbs" | "asic" => Some(Self::asic_3_28tbs()),
            "lpu-fpga-u55c" | "fpga" => Some(Self::fpga_u55c()),
            _ => None,
        }
    }

    // ---- JSON ----

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", self.name.clone().into()),
            ("freq_hz", self.freq_hz.into()),
            ("vec_dim", self.vec_dim.into()),
            ("mac_trees", self.mac_trees.into()),
            ("pipeline_depth", (self.pipeline_depth as u64).into()),
            ("vxe_lanes", self.vxe_lanes.into()),
            ("vxe_latency", (self.vxe_latency as u64).into()),
            ("icp_dispatch", (self.icp_dispatch as u64).into()),
            (
                "hbm",
                obj(vec![
                    ("gen", if self.hbm.gen == HbmGen::Hbm3 { "hbm3" } else { "hbm2" }.into()),
                    ("stacks", self.hbm.stacks.into()),
                    ("bw_per_stack", self.hbm.bw_per_stack.into()),
                    ("cap_per_stack", self.hbm.cap_per_stack.into()),
                    ("channels_per_stack", self.hbm.channels_per_stack.into()),
                ]),
            ),
            ("esl_bw", self.esl_bw.into()),
            ("esl_hop_latency", self.esl_hop_latency.into()),
            ("sram_bytes", self.sram_bytes.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<LpuConfig, String> {
        let req_f = |k: &str| j.get(k).as_f64().ok_or_else(|| format!("missing/invalid '{k}'"));
        let req_u = |k: &str| j.get(k).as_u64().ok_or_else(|| format!("missing/invalid '{k}'"));
        let h = j.get("hbm");
        let gen = match h.get("gen").as_str() {
            Some("hbm3") => HbmGen::Hbm3,
            Some("hbm2") => HbmGen::Hbm2,
            other => return Err(format!("invalid hbm.gen {other:?}")),
        };
        Ok(LpuConfig {
            name: j.get("name").as_str().ok_or("missing 'name'")?.to_string(),
            freq_hz: req_f("freq_hz")?,
            vec_dim: req_u("vec_dim")? as usize,
            mac_trees: req_u("mac_trees")? as usize,
            pipeline_depth: req_u("pipeline_depth")?,
            vxe_lanes: req_u("vxe_lanes")? as usize,
            vxe_latency: req_u("vxe_latency")?,
            icp_dispatch: req_u("icp_dispatch")?,
            hbm: HbmConfig {
                gen,
                stacks: h.get("stacks").as_usize().ok_or("missing hbm.stacks")?,
                bw_per_stack: h.get("bw_per_stack").as_f64().ok_or("missing hbm.bw_per_stack")?,
                cap_per_stack: h.get("cap_per_stack").as_u64().ok_or("missing hbm.cap_per_stack")?,
                channels_per_stack: h.get("channels_per_stack").as_usize().ok_or("missing hbm.channels_per_stack")?,
            },
            esl_bw: req_f("esl_bw")?,
            esl_hop_latency: req_f("esl_hop_latency")?,
            sram_bytes: req_u("sram_bytes")?,
        })
    }
}

/// A server product: N LPU devices on an ESL ring (Fig 6b).
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    pub name: String,
    pub device: LpuConfig,
    pub n_devices: usize,
    /// Board/host power overhead beyond the LPU systems, watts.
    pub host_power_w: f64,
}

impl ServerConfig {
    /// Orion-cloud: 8 FPGA LPUs, 128 GB, ~3.3 TB/s aggregate HBM (2U).
    pub fn orion_cloud() -> ServerConfig {
        ServerConfig {
            name: "orion-cloud".into(),
            device: LpuConfig::fpga_u55c(),
            n_devices: 8,
            host_power_w: 180.0,
        }
    }

    /// Orion-edge: 2 FPGA LPUs, 32 GB, ~960 GB/s aggregate HBM.
    pub fn orion_edge() -> ServerConfig {
        ServerConfig {
            name: "orion-edge".into(),
            device: LpuConfig::fpga_u55c(),
            n_devices: 2,
            // Edge chassis (CPU, PSU losses) amortized over two cards.
            host_power_w: 200.0,
        }
    }

    pub fn total_capacity(&self) -> u64 {
        self.device.hbm.capacity() * self.n_devices as u64
    }

    pub fn aggregate_bw(&self) -> f64 {
        self.device.hbm.peak_bw() * self.n_devices as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_bandwidth_balanced() {
        // Paper: "a number of compute units are placed to exactly match
        // the total HBM bandwidth". l × v × 2B × freq ≈ HBM BW.
        for cfg in [
            LpuConfig::asic_819gbs(),
            LpuConfig::asic_1_64tbs(),
            LpuConfig::asic_3_28tbs(),
            LpuConfig::fpga_u55c(),
        ] {
            cfg.validate().unwrap();
            let b = cfg.balance();
            assert!((0.95..=1.35).contains(&b), "{}: balance {b:.3}", cfg.name);
        }
    }

    #[test]
    fn asic_bandwidths_match_fig6() {
        assert!((LpuConfig::asic_819gbs().hbm.peak_bw() - 819e9).abs() < 1e6);
        assert!((LpuConfig::asic_1_64tbs().hbm.peak_bw() - 1.638e12).abs() < 1e9);
        assert!((LpuConfig::asic_3_28tbs().hbm.peak_bw() - 3.276e12).abs() < 1e9);
        assert_eq!(LpuConfig::asic_3_28tbs().mac_trees, 32);
        assert_eq!(LpuConfig::asic_3_28tbs().hbm.capacity(), 96_000_000_000);
    }

    #[test]
    fn fpga_matches_paper_u55c() {
        let f = LpuConfig::fpga_u55c();
        // 16 × 64 × 2B × 220 MHz ≈ 450 GB/s ≈ 460 GB/s HBM2.
        assert!((f.engine_bw() - 450.56e9).abs() < 1e9);
        assert!((f.hbm.peak_bw() - 460e9).abs() < 1e9);
        assert_eq!(f.hbm.capacity(), 16 << 30); // 16 GiB physical
    }

    #[test]
    fn orion_configs_match_paper() {
        let c = ServerConfig::orion_cloud();
        assert_eq!(c.n_devices, 8);
        assert_eq!(c.total_capacity(), 128 << 30); // "128 GB" = 128 GiB
        assert!((c.aggregate_bw() - 3.68e12).abs() < 0.4e12); // ~3.3-3.7 TB/s
        let e = ServerConfig::orion_edge();
        assert_eq!(e.total_capacity(), 32 << 30); // "32 GB" = 32 GiB
        assert!((e.aggregate_bw() - 920e9).abs() < 50e9); // ~960 GB/s
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [LpuConfig::asic_3_28tbs(), LpuConfig::fpga_u55c()] {
            let j = cfg.to_json();
            let back = LpuConfig::from_json(&j).unwrap();
            assert_eq!(back, cfg);
            // Also through text.
            let text = j.to_string_pretty();
            let back2 = LpuConfig::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back2, cfg);
        }
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let j = crate::util::json::Json::parse(r#"{"name":"x"}"#).unwrap();
        let e = LpuConfig::from_json(&j).unwrap_err();
        assert!(e.contains("hbm.gen"), "{e}");
        let j2 = crate::util::json::Json::parse(r#"{"name":"x","hbm":{"gen":"hbm3"}}"#).unwrap();
        let e2 = LpuConfig::from_json(&j2).unwrap_err();
        assert!(e2.contains("freq_hz") || e2.contains("stacks"), "{e2}");
    }

    #[test]
    fn by_name_aliases() {
        assert_eq!(LpuConfig::by_name("asic").unwrap().mac_trees, 32);
        assert_eq!(LpuConfig::by_name("fpga").unwrap().freq_hz, 220e6);
        assert!(LpuConfig::by_name("nope").is_none());
    }
}
