//! Functional runtime: artifact manifests for the AOT-compiled JAX/Pallas
//! decoder, and a **gated** PJRT execution engine.
//!
//! Build-time Python (`python/compile/aot.py`) lowers the L2 JAX model
//! (which calls the L1 Pallas kernels) to HLO text and emits per model:
//!
//! * `<model>.decode.hlo.txt` — the single-token decode step,
//! * `<model>.manifest.json`  — argument order/shapes, model shape, and a
//!   golden test vector (inputs + expected logits) for bridge validation,
//! * `<model>.weights.bin`    — the concatenated f32 parameters.
//!
//! The manifest/artifact layer below is fully functional and tested; it
//! is what the serving coordinator's PJRT backend descriptor resolves
//! against. Actual HLO execution requires the `xla_extension` PJRT
//! toolchain, which this offline image does not ship — so
//! [`Engine::load`] parses and validates artifacts, then fails with a
//! clear gating error instead of linking XLA. The serving layer runs on
//! the deterministic sim backend (`crate::coordinator::backend`), which
//! exercises the identical request path (sessions, batched decode,
//! sampling, streaming).

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{bail, err};

/// One executable argument described by the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
    /// Byte offset into weights.bin (parameters only; runtime args have
    /// `offset == None`).
    pub offset: Option<u64>,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Golden test vector generated at AOT time.
#[derive(Clone, Debug, PartialEq)]
pub struct TestVector {
    pub prompt: Vec<i64>,
    /// Expected greedy continuation tokens after the prompt.
    pub expected_tokens: Vec<i64>,
    /// First elements of the logits after consuming the prompt.
    pub logits_prefix: Vec<f64>,
}

/// Parsed `<model>.manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub args: Vec<ArgSpec>,
    pub test: Option<TestVector>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| err!("manifest: {e}"))?;
        let get_usize =
            |k: &str| j.get(k).as_usize().ok_or_else(|| err!("manifest: missing '{k}'"));
        let args_json = j.get("args").as_arr().ok_or_else(|| err!("manifest: missing 'args'"))?;
        let mut args = Vec::with_capacity(args_json.len());
        for a in args_json {
            args.push(ArgSpec {
                name: a
                    .get("name")
                    .as_str()
                    .ok_or_else(|| err!("arg missing name"))?
                    .to_string(),
                shape: a
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| err!("arg missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| err!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: a.get("dtype").as_str().unwrap_or("f32").to_string(),
                offset: a.get("offset").as_u64(),
            });
        }
        let test = match j.get("test") {
            Json::Null => None,
            t => Some(TestVector {
                prompt: t
                    .get("prompt")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as i64))
                    .collect(),
                expected_tokens: t
                    .get("expected_tokens")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as i64))
                    .collect(),
                logits_prefix: t
                    .get("logits_prefix")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .collect(),
            }),
        };
        Ok(Manifest {
            model: j.get("model").as_str().unwrap_or("?").to_string(),
            d_model: get_usize("d_model")?,
            n_layers: get_usize("n_layers")?,
            n_heads: get_usize("n_heads")?,
            max_seq: get_usize("max_seq")?,
            vocab: get_usize("vocab")?,
            args,
            test,
        })
    }

    /// Arguments that are parameters (have a weights.bin offset).
    pub fn param_args(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.offset.is_some())
    }

    /// Check the weights blob covers every parameter argument.
    pub fn validate_weights(&self, weights_len: usize) -> Result<()> {
        for a in self.param_args() {
            let off = a.offset.unwrap() as usize;
            let nbytes = a.elems() * 4;
            if off + nbytes > weights_len {
                bail!(
                    "weights.bin too small for {} (need {nbytes} bytes at offset {off}, have {weights_len})",
                    a.name
                );
            }
        }
        Ok(())
    }
}

/// The compiled model + resident weights. The full PJRT implementation
/// (compile HLO once, upload weights to device buffers, round-trip the
/// KV cache as device buffers per step) lives behind the gate described
/// in the module docs; this build validates artifacts and reports the
/// gate instead of executing.
pub struct Engine {
    pub manifest: Manifest,
}

/// Per-request generation state for the PJRT engine (device-resident KV
/// cache buffers in a PJRT-enabled build).
pub struct Session {
    pub pos: usize,
}

/// The single message every gated entry point reports.
const GATE_MSG: &str = "PJRT/XLA execution is gated: this offline build has no xla_extension \
     toolchain. Serve with the sim backend (`--backend sim`), which runs the same \
     coordinator/session/batching path";

impl Engine {
    /// Expected artifact paths for a model.
    pub fn artifact_paths(dir: &Path, model: &str) -> (PathBuf, PathBuf, PathBuf) {
        (
            dir.join(format!("{model}.decode.hlo.txt")),
            dir.join(format!("{model}.manifest.json")),
            dir.join(format!("{model}.weights.bin")),
        )
    }

    /// True if all artifacts for `model` exist under `dir`.
    pub fn artifacts_present(dir: &Path, model: &str) -> bool {
        let (h, m, w) = Self::artifact_paths(dir, model);
        h.exists() && m.exists() && w.exists()
    }

    /// Load and validate a model's artifacts, then fail on the PJRT gate.
    /// Errors mention the missing piece (manifest, weights, gate) so
    /// operators can tell a deployment problem from the toolchain gate.
    pub fn load(dir: &Path, model: &str) -> Result<Engine> {
        let (_hlo_path, manifest_path, weights_path) = Self::artifact_paths(dir, model);
        let manifest_src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&manifest_src)?;
        let raw =
            std::fs::read(&weights_path).with_context(|| format!("reading {weights_path:?}"))?;
        manifest.validate_weights(raw.len())?;
        bail!("{GATE_MSG} (artifacts for '{model}' parsed OK)");
    }

    /// Fresh session with zeroed KV cache.
    pub fn new_session(&self) -> Result<Session> {
        bail!("{GATE_MSG}");
    }

    /// Run one decode step: feed `token` at the session's position,
    /// return the next-token logits and advance the KV cache in place.
    pub fn decode_step(&self, s: &mut Session, _token: i64) -> Result<Vec<f32>> {
        if s.pos >= self.manifest.max_seq {
            bail!("session exceeded max_seq {}", self.manifest.max_seq);
        }
        bail!("{GATE_MSG}");
    }

    /// Greedy-decode `n` tokens starting from `prompt`.
    pub fn generate_greedy(&self, _prompt: &[i64], _n: usize) -> Result<Vec<i64>> {
        bail!("{GATE_MSG}");
    }

    /// Validate the compiled bridge against the manifest's golden vector.
    pub fn validate(&self) -> Result<()> {
        self.manifest
            .test
            .as_ref()
            .ok_or_else(|| err!("manifest has no test vector"))?;
        bail!("{GATE_MSG}");
    }
}

/// Default artifacts directory (repo-root relative, overridable).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("LPU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
        "model": "opt-tiny",
        "d_model": 256, "n_layers": 4, "n_heads": 8,
        "max_seq": 256, "vocab": 512,
        "args": [
            {"name": "embed", "shape": [512, 256], "dtype": "f32", "offset": 0},
            {"name": "qkv_0", "shape": [256, 768], "dtype": "f32", "offset": 524288},
            {"name": "token", "shape": [1], "dtype": "i32"},
            {"name": "pos", "shape": [1], "dtype": "i32"},
            {"name": "k", "shape": [4, 256, 256], "dtype": "f32"},
            {"name": "v", "shape": [4, 256, 256], "dtype": "f32"}
        ],
        "test": {
            "prompt": [1, 2, 3],
            "expected_tokens": [7, 8],
            "logits_prefix": [0.25, -1.5]
        }
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.model, "opt-tiny");
        assert_eq!(m.d_model, 256);
        assert_eq!(m.args.len(), 6);
        assert_eq!(m.param_args().count(), 2);
        assert_eq!(m.args[1].offset, Some(524288));
        assert_eq!(m.args[1].elems(), 256 * 768);
        let t = m.test.unwrap();
        assert_eq!(t.prompt, vec![1, 2, 3]);
        assert_eq!(t.expected_tokens, vec![7, 8]);
        assert_eq!(t.logits_prefix, vec![0.25, -1.5]);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn weights_bounds_checked() {
        let m = Manifest::parse(MANIFEST).unwrap();
        // embed needs 512*256*4 B at 0; qkv_0 needs 256*768*4 B at 524288.
        let need = 524288 + 256 * 768 * 4;
        assert!(m.validate_weights(need).is_ok());
        let e = m.validate_weights(need - 1).unwrap_err();
        assert!(format!("{e}").contains("weights.bin too small"), "{e}");
    }

    #[test]
    fn artifact_paths_layout() {
        let (h, m, w) = Engine::artifact_paths(Path::new("artifacts"), "opt-tiny");
        assert_eq!(h, Path::new("artifacts/opt-tiny.decode.hlo.txt"));
        assert_eq!(m, Path::new("artifacts/opt-tiny.manifest.json"));
        assert_eq!(w, Path::new("artifacts/opt-tiny.weights.bin"));
        assert!(!Engine::artifacts_present(Path::new("/nonexistent"), "x"));
    }

    #[test]
    fn load_without_artifacts_mentions_manifest() {
        let e = Engine::load(Path::new("/nonexistent-dir"), "opt-tiny").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("manifest") || msg.contains("reading"), "{msg}");
    }
}
