//! PJRT functional runtime: loads the AOT-compiled JAX/Pallas decoder and
//! executes real token generation from the Rust request path.
//!
//! Build-time Python (`python/compile/aot.py`) lowers the L2 JAX model
//! (which calls the L1 Pallas kernels) to **HLO text** — the only
//! interchange format the image's xla_extension 0.5.1 accepts from
//! jax ≥ 0.5 (serialized protos carry 64-bit instruction ids it rejects)
//! — and emits for each model:
//!
//! * `<model>.decode.hlo.txt` — the single-token decode step,
//! * `<model>.manifest.json`  — argument order/shapes, model shape, and a
//!   golden test vector (inputs + expected logits) for bridge validation,
//! * `<model>.weights.bin`    — the concatenated f32 parameters.
//!
//! At startup [`Engine::load`] compiles the HLO once on the PJRT CPU
//! client and uploads the weights to device buffers; each
//! [`Session::decode_step`] then uploads only the token/position scalars
//! and round-trips the KV cache as device buffers. Python never runs on
//! the request path.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One executable argument described by the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
    /// Byte offset into weights.bin (parameters only; runtime args have
    /// `offset == None`).
    pub offset: Option<u64>,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Golden test vector generated at AOT time.
#[derive(Clone, Debug, PartialEq)]
pub struct TestVector {
    pub prompt: Vec<i64>,
    /// Expected greedy continuation tokens after the prompt.
    pub expected_tokens: Vec<i64>,
    /// First elements of the logits after consuming the prompt.
    pub logits_prefix: Vec<f64>,
}

/// Parsed `<model>.manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub args: Vec<ArgSpec>,
    pub test: Option<TestVector>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("manifest: {e}"))?;
        let get_usize = |k: &str| {
            j.get(k).as_usize().ok_or_else(|| anyhow!("manifest: missing '{k}'"))
        };
        let args_json = j.get("args").as_arr().ok_or_else(|| anyhow!("manifest: missing 'args'"))?;
        let mut args = Vec::with_capacity(args_json.len());
        for a in args_json {
            args.push(ArgSpec {
                name: a.get("name").as_str().ok_or_else(|| anyhow!("arg missing name"))?.to_string(),
                shape: a
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("arg missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: a.get("dtype").as_str().unwrap_or("f32").to_string(),
                offset: a.get("offset").as_u64(),
            });
        }
        let test = match j.get("test") {
            Json::Null => None,
            t => Some(TestVector {
                prompt: t
                    .get("prompt")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as i64))
                    .collect(),
                expected_tokens: t
                    .get("expected_tokens")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as i64))
                    .collect(),
                logits_prefix: t
                    .get("logits_prefix")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .collect(),
            }),
        };
        Ok(Manifest {
            model: j.get("model").as_str().unwrap_or("?").to_string(),
            d_model: get_usize("d_model")?,
            n_layers: get_usize("n_layers")?,
            n_heads: get_usize("n_heads")?,
            max_seq: get_usize("max_seq")?,
            vocab: get_usize("vocab")?,
            args,
            test,
        })
    }

    /// Arguments that are parameters (have a weights.bin offset).
    pub fn param_args(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.offset.is_some())
    }
}

/// The compiled model + resident weights. One per model; `Send`-able
/// behind an `Arc` (PJRT objects are internally refcounted).
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    /// Device-resident parameter buffers, in argument order.
    weights: Vec<xla::PjRtBuffer>,
}

/// Per-request generation state: device-resident KV cache buffers.
pub struct Session {
    k: xla::PjRtBuffer,
    v: xla::PjRtBuffer,
    pub pos: usize,
}

impl Engine {
    /// Expected artifact paths for a model.
    pub fn artifact_paths(dir: &Path, model: &str) -> (PathBuf, PathBuf, PathBuf) {
        (
            dir.join(format!("{model}.decode.hlo.txt")),
            dir.join(format!("{model}.manifest.json")),
            dir.join(format!("{model}.weights.bin")),
        )
    }

    /// True if all artifacts for `model` exist under `dir`.
    pub fn artifacts_present(dir: &Path, model: &str) -> bool {
        let (h, m, w) = Self::artifact_paths(dir, model);
        h.exists() && m.exists() && w.exists()
    }

    /// Load + compile a model's artifacts.
    pub fn load(dir: &Path, model: &str) -> Result<Engine> {
        let (hlo_path, manifest_path, weights_path) = Self::artifact_paths(dir, model);
        let manifest_src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Manifest::parse(&manifest_src)?;

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("XLA compile: {e:?}"))?;

        let raw = std::fs::read(&weights_path)
            .with_context(|| format!("reading {weights_path:?}"))?;
        let mut weights = Vec::new();
        for a in manifest.param_args() {
            let off = a.offset.unwrap() as usize;
            let nbytes = a.elems() * 4;
            if off + nbytes > raw.len() {
                bail!("weights.bin too small for {} (need {} at {off})", a.name, nbytes);
            }
            let floats: Vec<f32> = raw[off..off + nbytes]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let buf = client
                .buffer_from_host_buffer::<f32>(&floats, &a.shape, None)
                .map_err(|e| anyhow!("uploading {}: {e:?}", a.name))?;
            weights.push(buf);
        }
        Ok(Engine { client, exe, manifest, weights })
    }

    /// Fresh session with zeroed KV cache.
    pub fn new_session(&self) -> Result<Session> {
        let m = &self.manifest;
        let kv_shape = [m.n_layers, m.max_seq, m.d_model];
        let zeros = vec![0f32; kv_shape.iter().product()];
        let k = self
            .client
            .buffer_from_host_buffer::<f32>(&zeros, &kv_shape, None)
            .map_err(|e| anyhow!("kv alloc: {e:?}"))?;
        let v = self
            .client
            .buffer_from_host_buffer::<f32>(&zeros, &kv_shape, None)
            .map_err(|e| anyhow!("kv alloc: {e:?}"))?;
        Ok(Session { k, v, pos: 0 })
    }

    /// Run one decode step: feed `token` at the session's position,
    /// return the next-token logits and advance the KV cache in place.
    pub fn decode_step(&self, s: &mut Session, token: i64) -> Result<Vec<f32>> {
        if s.pos >= self.manifest.max_seq {
            bail!("session exceeded max_seq {}", self.manifest.max_seq);
        }
        let tok = self
            .client
            .buffer_from_host_buffer::<i32>(&[token as i32], &[1], None)
            .map_err(|e| anyhow!("token upload: {e:?}"))?;
        let pos = self
            .client
            .buffer_from_host_buffer::<i32>(&[s.pos as i32], &[1], None)
            .map_err(|e| anyhow!("pos upload: {e:?}"))?;

        // Argument order: params..., token, pos, k, v (manifest order).
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&pos);
        args.push(&s.k);
        args.push(&s.v);

        let mut outs = self.exe.execute_b(&args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let mut row = outs.pop().ok_or_else(|| anyhow!("no output rows"))?;
        // Lowered with return_tuple=True: PJRT flattens the 3-tuple
        // (logits, k', v') into separate output buffers.
        if row.len() == 3 {
            let v_new = row.pop().unwrap();
            let k_new = row.pop().unwrap();
            let logits_buf = row.pop().unwrap();
            let logits = logits_buf
                .to_literal_sync()
                .map_err(|e| anyhow!("logits readback: {e:?}"))?
                .to_vec::<f32>()
                .map_err(|e| anyhow!("logits to_vec: {e:?}"))?;
            s.k = k_new;
            s.v = v_new;
            s.pos += 1;
            Ok(logits)
        } else if row.len() == 1 {
            // Tuple kept intact: decompose on host.
            let lit = row
                .pop()
                .unwrap()
                .to_literal_sync()
                .map_err(|e| anyhow!("tuple readback: {e:?}"))?;
            let (logits, k_new, v_new) =
                lit.to_tuple3().map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
            let logits = logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            // Host round-trip for the caches (slow path).
            let m = &self.manifest;
            let kv_shape = [m.n_layers, m.max_seq, m.d_model];
            let kv: Vec<f32> = k_new.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            s.k = self
                .client
                .buffer_from_host_buffer::<f32>(&kv, &kv_shape, None)
                .map_err(|e| anyhow!("{e:?}"))?;
            let vv: Vec<f32> = v_new.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            s.v = self
                .client
                .buffer_from_host_buffer::<f32>(&vv, &kv_shape, None)
                .map_err(|e| anyhow!("{e:?}"))?;
            s.pos += 1;
            Ok(logits)
        } else {
            bail!("unexpected output arity {}", row.len());
        }
    }

    /// Greedy-decode `n` tokens starting from `prompt`. Returns generated
    /// token ids. Used by the E2E example and the bridge validation test.
    pub fn generate_greedy(&self, prompt: &[i64], n: usize) -> Result<Vec<i64>> {
        let mut session = self.new_session()?;
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.decode_step(&mut session, t)?;
        }
        let mut out = Vec::with_capacity(n);
        let mut next = crate::numerics::sampler::argmax(&logits) as i64;
        out.push(next);
        for _ in 1..n {
            logits = self.decode_step(&mut session, next)?;
            next = crate::numerics::sampler::argmax(&logits) as i64;
            out.push(next);
        }
        Ok(out)
    }

    /// Validate the compiled bridge against the manifest's golden vector.
    pub fn validate(&self) -> Result<()> {
        let test = self
            .manifest
            .test
            .clone()
            .ok_or_else(|| anyhow!("manifest has no test vector"))?;
        let mut session = self.new_session()?;
        let mut logits = Vec::new();
        for &t in &test.prompt {
            logits = self.decode_step(&mut session, t)?;
        }
        for (i, &expect) in test.logits_prefix.iter().enumerate() {
            let got = logits[i] as f64;
            let tol = 1e-3 * expect.abs().max(1.0);
            if (got - expect).abs() > tol {
                bail!("logits[{i}] = {got} but python reference says {expect}");
            }
        }
        let got_tokens = self.generate_greedy(&test.prompt, test.expected_tokens.len())?;
        if got_tokens != test.expected_tokens {
            bail!("greedy tokens {got_tokens:?} != python reference {:?}", test.expected_tokens);
        }
        Ok(())
    }
}

/// Default artifacts directory (repo-root relative, overridable).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("LPU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
        "model": "opt-tiny",
        "d_model": 256, "n_layers": 4, "n_heads": 8,
        "max_seq": 256, "vocab": 512,
        "args": [
            {"name": "embed", "shape": [512, 256], "dtype": "f32", "offset": 0},
            {"name": "qkv_0", "shape": [256, 768], "dtype": "f32", "offset": 524288},
            {"name": "token", "shape": [1], "dtype": "i32"},
            {"name": "pos", "shape": [1], "dtype": "i32"},
            {"name": "k", "shape": [4, 256, 256], "dtype": "f32"},
            {"name": "v", "shape": [4, 256, 256], "dtype": "f32"}
        ],
        "test": {
            "prompt": [1, 2, 3],
            "expected_tokens": [7, 8],
            "logits_prefix": [0.25, -1.5]
        }
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.model, "opt-tiny");
        assert_eq!(m.d_model, 256);
        assert_eq!(m.args.len(), 6);
        assert_eq!(m.param_args().count(), 2);
        assert_eq!(m.args[1].offset, Some(524288));
        assert_eq!(m.args[1].elems(), 256 * 768);
        let t = m.test.unwrap();
        assert_eq!(t.prompt, vec![1, 2, 3]);
        assert_eq!(t.expected_tokens, vec![7, 8]);
        assert_eq!(t.logits_prefix, vec![0.25, -1.5]);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn artifact_paths_layout() {
        let (h, m, w) = Engine::artifact_paths(Path::new("artifacts"), "opt-tiny");
        assert_eq!(h, Path::new("artifacts/opt-tiny.decode.hlo.txt"));
        assert_eq!(m, Path::new("artifacts/opt-tiny.manifest.json"));
        assert_eq!(w, Path::new("artifacts/opt-tiny.weights.bin"));
        assert!(!Engine::artifacts_present(Path::new("/nonexistent"), "x"));
    }
}
