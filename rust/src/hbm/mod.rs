//! HBM timing model — the ramulator substitute.
//!
//! The paper integrates ramulator to simulate Samsung HBM3 Icebolt
//! (819 GB/s / 24 GB per stack). The LPU's SMA issues long sequential
//! burst streams (weights, KV) plus occasional short writes, so the
//! behaviour that matters is *streaming efficiency*: how close a
//! bank-interleaved sequential read stream gets to the pin bandwidth
//! once row activation, refresh, read/write turnaround, and command
//! overheads are charged. This module models exactly that, at
//! per-request granularity, from JEDEC-style timing parameters — the
//! same quantities a full ramulator configuration would specify.

use crate::config::{HbmConfig, HbmGen};

/// DRAM timing parameters (nanoseconds unless noted).
#[derive(Clone, Copy, Debug)]
pub struct HbmTimings {
    /// Row activate to column command.
    pub t_rcd: f64,
    /// Precharge.
    pub t_rp: f64,
    /// CAS latency.
    pub t_cl: f64,
    /// Column-to-column delay, same bank group.
    pub t_ccd_l: f64,
    /// Column-to-column delay, different bank group (gapless when ≤ burst time).
    pub t_ccd_s: f64,
    /// Refresh cycle time.
    pub t_rfc: f64,
    /// Refresh interval.
    pub t_refi: f64,
    /// Write-to-read turnaround.
    pub t_wtr: f64,
    /// Read-to-write turnaround.
    pub t_rtw: f64,
    /// Bytes transferred per burst per pseudo-channel.
    pub burst_bytes: u64,
    /// Row (page) size per pseudo-channel, bytes.
    pub row_bytes: u64,
    /// Banks per pseudo-channel (for interleave hiding of tRCD/tRP).
    pub banks: usize,
}

impl HbmTimings {
    /// HBM3 (Icebolt-class, 6.4 Gb/s/pin): 64-bit pseudo-channel, BL8.
    pub fn hbm3() -> HbmTimings {
        HbmTimings {
            t_rcd: 14.0,
            t_rp: 14.0,
            t_cl: 18.0,
            t_ccd_l: 3.3,
            t_ccd_s: 1.25,
            t_rfc: 260.0,
            t_refi: 3900.0,
            t_wtr: 8.0,
            t_rtw: 6.0,
            burst_bytes: 64,
            row_bytes: 1024,
            banks: 16,
        }
    }

    /// HBM2 (Alveo U55C class, 1.8 Gb/s/pin-ish effective).
    pub fn hbm2() -> HbmTimings {
        HbmTimings {
            t_rcd: 16.0,
            t_rp: 16.0,
            t_cl: 20.0,
            t_ccd_l: 4.0,
            t_ccd_s: 2.0,
            t_rfc: 350.0,
            t_refi: 3900.0,
            t_wtr: 10.0,
            t_rtw: 8.0,
            burst_bytes: 32,
            row_bytes: 1024,
            banks: 16,
        }
    }

    pub fn for_gen(gen: HbmGen) -> HbmTimings {
        match gen {
            HbmGen::Hbm3 => Self::hbm3(),
            HbmGen::Hbm2 => Self::hbm2(),
        }
    }
}

/// Aggregate HBM subsystem model for one LPU device.
#[derive(Clone, Debug)]
pub struct HbmModel {
    pub cfg: HbmConfig,
    pub timings: HbmTimings,
    /// Peak bytes/s across all channels (pin bandwidth).
    peak_bw: f64,
    /// Derived streaming efficiency in (0, 1].
    stream_eff: f64,
    /// Total bytes serviced (stats).
    bytes_read: u64,
    bytes_written: u64,
}

impl HbmModel {
    pub fn new(cfg: &HbmConfig) -> HbmModel {
        let timings = HbmTimings::for_gen(cfg.gen);
        let peak_bw = cfg.peak_bw();
        let stream_eff = streaming_efficiency(&timings, peak_bw, cfg.channels());
        HbmModel { cfg: cfg.clone(), timings, peak_bw, stream_eff, bytes_read: 0, bytes_written: 0 }
    }

    pub fn peak_bw(&self) -> f64 {
        self.peak_bw
    }

    /// Sustained sequential-stream bandwidth (bytes/s).
    pub fn stream_bw(&self) -> f64 {
        self.peak_bw * self.stream_eff
    }

    pub fn stream_efficiency(&self) -> f64 {
        self.stream_eff
    }

    /// Time (seconds) to stream `bytes` sequentially across all channels
    /// (the SMA "Read Parameters"/"Read Key/Value" path). Charges fixed
    /// first-access latency plus sustained-rate transfer.
    pub fn stream_read_time(&mut self, bytes: u64) -> f64 {
        self.bytes_read += bytes;
        if bytes == 0 {
            return 0.0;
        }
        self.first_access_latency() + bytes as f64 / self.stream_bw()
    }

    /// Same, in core cycles at `freq` Hz (rounded up).
    pub fn stream_read_cycles(&mut self, bytes: u64, freq: f64) -> u64 {
        (self.stream_read_time(bytes) * freq).ceil() as u64
    }

    /// Short write (KV append): charged the turnaround + burst time; the
    /// SMA's strobe-transpose writes add no extra latency (paper).
    pub fn write_time(&mut self, bytes: u64) -> f64 {
        self.bytes_written += bytes;
        if bytes == 0 {
            return 0.0;
        }
        let turnaround = (self.timings.t_rtw + self.timings.t_wtr) * 1e-9;
        turnaround + bytes as f64 / self.stream_bw()
    }

    pub fn write_cycles(&mut self, bytes: u64, freq: f64) -> u64 {
        (self.write_time(bytes) * freq).ceil() as u64
    }

    /// First-word latency for a fresh stream: activate + CAS.
    pub fn first_access_latency(&self) -> f64 {
        (self.timings.t_rcd + self.timings.t_cl) * 1e-9
    }

    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    pub fn reset_stats(&mut self) {
        self.bytes_read = 0;
        self.bytes_written = 0;
    }
}

/// Derive sustained streaming efficiency from the timing parameters.
///
/// A sequential stream with ≥2 banks ping-pongs activations so tRCD/tRP
/// hide behind data transfer, except a residual bubble when the activate
/// pipeline cannot keep up: per row of `row_bytes`, the bank must spend
/// `t_rcd + t_rp` off the bus, overlapped across `banks` banks. Refresh
/// steals `t_rfc / t_refi`. Command-bus and ECC overhead is a small
/// constant factor.
fn streaming_efficiency(t: &HbmTimings, peak_bw: f64, channels: usize) -> f64 {
    let per_chan_bw = peak_bw / channels as f64; // bytes/s
    let row_transfer_ns = t.row_bytes as f64 / per_chan_bw * 1e9;
    // Time a bank needs off the bus per row, divided across other banks'
    // transfers: with B banks, (B-1) rows transfer while one re-activates.
    let overlap_window = row_transfer_ns * (t.banks as f64 - 1.0);
    let bubble_ns = (t.t_rcd + t.t_rp - overlap_window).max(0.0);
    let row_eff = row_transfer_ns / (row_transfer_ns + bubble_ns);
    let refresh_eff = 1.0 - t.t_rfc / t.t_refi;
    let cmd_eff = 0.99; // command/ECC slot overhead
    row_eff * refresh_eff * cmd_eff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LpuConfig;

    fn hbm3_model() -> HbmModel {
        HbmModel::new(&LpuConfig::asic_3_28tbs().hbm)
    }

    #[test]
    fn peak_bandwidth_matches_config() {
        let m = hbm3_model();
        assert!((m.peak_bw() - 3.276e12).abs() < 1e9);
    }

    #[test]
    fn streaming_efficiency_in_expected_band() {
        // HBM3 bank-interleaved sequential streams sustain 90-97% of pin
        // bandwidth in practice; the model must land there.
        let m = hbm3_model();
        let eff = m.stream_efficiency();
        assert!((0.88..=0.97).contains(&eff), "HBM3 stream eff {eff}");
        let m2 = HbmModel::new(&LpuConfig::fpga_u55c().hbm);
        let eff2 = m2.stream_efficiency();
        assert!((0.85..=0.97).contains(&eff2), "HBM2 stream eff {eff2}");
    }

    #[test]
    fn stream_time_scales_linearly() {
        let mut m = hbm3_model();
        let t1 = m.stream_read_time(1_000_000_000);
        let t2 = m.stream_read_time(2_000_000_000);
        // Fixed latency is tiny relative to 1 GB transfers.
        assert!((t2 / t1 - 2.0).abs() < 0.01, "t1={t1} t2={t2}");
    }

    #[test]
    fn opt_1_3b_weight_stream_in_right_ballpark() {
        // 2.6 GB at ~3.1 TB/s sustained ≈ 0.85 ms — the floor under the
        // paper's 1.25 ms/token.
        let mut m = hbm3_model();
        let t = m.stream_read_time(2_630_000_000);
        assert!((0.00078..=0.00095).contains(&t), "stream time {t}");
    }

    #[test]
    fn small_read_dominated_by_first_access() {
        let mut m = hbm3_model();
        let t = m.stream_read_time(64);
        let fa = m.first_access_latency();
        assert!(t >= fa && t < fa * 2.0);
    }

    #[test]
    fn write_includes_turnaround() {
        let mut m = hbm3_model();
        let tw = m.write_time(4096);
        let tr_equiv = 4096.0 / m.stream_bw();
        assert!(tw > tr_equiv, "write must pay turnaround");
        assert!(tw < tr_equiv + 50e-9, "turnaround bounded by ~tens of ns");
    }

    #[test]
    fn cycles_round_up() {
        let mut m = hbm3_model();
        let c = m.stream_read_cycles(1, 1e9);
        assert!(c >= 1);
        assert_eq!(m.stream_read_cycles(0, 1e9), 0);
    }

    #[test]
    fn stats_accumulate() {
        let mut m = hbm3_model();
        m.stream_read_time(100);
        m.stream_read_time(200);
        m.write_time(50);
        assert_eq!(m.bytes_read(), 300);
        assert_eq!(m.bytes_written(), 50);
        m.reset_stats();
        assert_eq!(m.bytes_read(), 0);
    }

    #[test]
    fn hbm2_slower_than_hbm3() {
        let mut h3 = hbm3_model();
        let mut h2 = HbmModel::new(&LpuConfig::fpga_u55c().hbm);
        let b = 1_000_000_000;
        assert!(h2.stream_read_time(b) > h3.stream_read_time(b));
    }

    #[test]
    fn efficiency_degrades_with_fewer_banks() {
        let mut t = HbmTimings::hbm3();
        let base = streaming_efficiency(&t, 819e9, 16);
        t.banks = 1;
        let single = streaming_efficiency(&t, 819e9, 16);
        assert!(single < base, "single bank {single} vs interleaved {base}");
    }
}
