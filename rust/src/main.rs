//! `lpu` — command-line launcher for the LPU reproduction.
//!
//! Subcommands mirror the deployment workflow: compile a model with the
//! HyperDex stack, simulate latency/scaling on the cycle-accurate
//! simulator, estimate chip area/power, serve real tokens over TCP via
//! the PJRT runtime, and poke a running server as a client.

use std::sync::Arc;

use lpu::compiler::{compile, CompileOpts, ParallelMode};
use lpu::config::LpuConfig;
use lpu::coordinator::{
    perfetto_json, validate_perfetto, ArrivalTrace, AutoscaleConfig, BackendFactory,
    Cluster, ClusterConfig, ClusterFaultPlan, Coordinator, CoordinatorConfig, FaultPlan,
    HostTierConfig, KvPolicy, PrefixCacheConfig, RequestTimeline, RouterPolicy,
    SchedulerPolicy, SloTierSpec, StepModel, VirtualConfig, DEFAULT_TRACE_RING,
};
use lpu::esl::cluster::{scaling_sweep, speedup_per_doubling};
use lpu::isa::asm;
use lpu::model::by_name;
use lpu::power::{chip_estimate, system_power_w};
use lpu::runtime::{default_artifacts_dir, Engine};
use lpu::server;
use lpu::sim::simulate_generation;
use lpu::util::cli::{render_help, Args, Command};
use lpu::util::table::Table;

const COMMANDS: &[Command] = &[
    Command { name: "simulate", about: "cycle-accurate decode-latency simulation", usage: "--model opt-1.3b [--devices 1] [--config asic] [--in 32] [--out 2016] [--no-overlap]" },
    Command { name: "scaling", about: "strong-scaling sweep over 1..N devices", usage: "--model gpt3-20b [--max 8]" },
    Command { name: "compile", about: "HyperDex compile; prints stats, optionally dumps asm/binary", usage: "--model opt-1.3b [--devices 1] [--pos 0] [--emit-asm] [--out prog.lpubin]" },
    Command { name: "asm", about: "assemble LPU assembly to a binary", usage: "<in.s> <out.lpubin>" },
    Command { name: "disasm", about: "disassemble an LPU binary", usage: "<in.lpubin>" },
    Command { name: "chip", about: "ASIC area/power estimate (Fig 6a)", usage: "[--config asic]" },
    Command { name: "serve", about: "serve models over TCP JSON-lines", usage: "--model opt-tiny [--backend pjrt|sim] [--addr 127.0.0.1:7071] [--workers 2] [--policy rr|fcfs|sjf] [--router round-robin|least-loaded|prefix-affinity] [--max-active 8] [--max-batch 0] [--kv-budget-mb N] [--kv-policy reserve|paged|paged:<tokens>] [--kv-host-mb N] [--prefill-chunk N] [--prefix-cache on|off|on:<blocks>] [--fault-plan seed=S,transient=R,retries=N,backoff=S,crash=W@K,slow=WxF] [--replicas N] [--slo-tier batch|interactive:<ttft_s>] [--autoscale min=..,max=..,interval=..,warmup=..,up=..,down=..] [--cluster-fault-plan probe=S,crash=R@T,partition=R@T1..T2,slow=RxF] [--hedge <deadline_fraction>] [--trace-out FILE]" },
    Command { name: "client", about: "send a generate request to a server", usage: "--addr 127.0.0.1:7071 --model opt-tiny --prompt 1,2,3 [--tokens 16]" },
    Command { name: "validate", about: "validate the PJRT bridge against the python golden vector", usage: "--model opt-tiny" },
    Command { name: "loadtest", about: "open-loop Poisson load study against an in-process pool", usage: "--model opt-tiny [--backend sim|pjrt] [--rates 50,200,1000] [--requests 100] [--policy rr|fcfs|sjf] [--router round-robin|least-loaded|prefix-affinity] [--prefill-chunk N] [--kv-budget-mb N] [--kv-policy reserve|paged|paged:<tokens>] [--kv-host-mb N] [--prefix-cache on|off|on:<blocks>] [--fault-plan seed=S,transient=R,retries=N,backoff=S,crash=W@K,slow=WxF] [--replicas N] [--slo-tier batch|interactive:<ttft_s>|mixed:<ttft_s>:<fraction>] [--autoscale min=..,max=..,interval=..,warmup=..,up=..,down=..] [--trace uniform|diurnal:<period_s>:<depth>|flash:<at_s>:<dur_s>:<mag>] [--cluster-fault-plan probe=S,crash=R@T,partition=R@T1..T2,slow=RxF] [--hedge <deadline_fraction>] [--trace-out FILE]" },
];

fn policy_arg(args: &Args) -> Result<SchedulerPolicy, String> {
    let name = args.opt_or("policy", "rr");
    SchedulerPolicy::parse(name)
        .ok_or_else(|| format!("unknown policy '{name}' (fcfs|rr|sjf)"))
}

fn router_arg(args: &Args) -> Result<RouterPolicy, String> {
    let name = args.opt_or("router", "round-robin");
    RouterPolicy::parse(name).ok_or_else(|| {
        format!("unknown router policy '{name}' (round-robin|least-loaded|prefix-affinity)")
    })
}

/// Parse `--fault-plan` (shared by `serve` and `loadtest`): a
/// deterministic fault-injection spec, e.g.
/// `seed=7,transient=0.01,retries=3,backoff=0.001,crash=0@200,slow=1x2.5`.
/// Absent flag = inert plan. A malformed spec is refused, not ignored.
/// Composes with `--replicas`: the pool-level plan applies to EACH
/// replica identically (worker indices are per-replica), while
/// `--cluster-fault-plan` injects replica-level faults.
fn fault_arg(args: &Args) -> Result<FaultPlan, String> {
    match args.opt("fault-plan") {
        Some(spec) => FaultPlan::parse(spec).map_err(|e| e.to_string()),
        None => Ok(FaultPlan::default()),
    }
}

/// Parse the KV-accounting flags shared by `serve` and `loadtest`:
/// `--kv-budget-mb`, `--kv-policy`, `--prefix-cache`, `--kv-host-mb`.
/// Returns `(kv_bytes_per_token, kv_budget_bytes, kv_policy,
/// prefix_cache, host_tier)`.
fn kv_args(
    args: &Args,
    model: &str,
) -> Result<(u64, u64, KvPolicy, PrefixCacheConfig, HostTierConfig), String> {
    let kv_budget_mb = args.opt_u64("kv-budget-mb", 0)?;
    let kv_bytes_per_token = if kv_budget_mb == 0 {
        0
    } else {
        // A budget without per-token accounting would silently disable
        // admission control; refuse rather than no-op the flag.
        by_name(model).map(|m| m.kv_bytes_per_token()).ok_or_else(|| {
            format!(
                "--kv-budget-mb needs a registry model for KV accounting; '{model}' is unknown"
            )
        })?
    };
    let kv_policy_name = args.opt_or("kv-policy", "reserve");
    let kv_policy = KvPolicy::parse(kv_policy_name).ok_or_else(|| {
        format!("unknown kv policy '{kv_policy_name}' (reserve|paged|paged:<tokens>)")
    })?;
    if matches!(kv_policy, KvPolicy::Paged { .. }) && kv_budget_mb == 0 {
        // An unbounded pager never pages: refuse rather than silently
        // no-op the flag (same stance as --kv-budget-mb with an
        // unknown model above).
        return Err("--kv-policy paged needs --kv-budget-mb to bound the pager".into());
    }
    let prefix_name = args.opt_or("prefix-cache", "off");
    let prefix_cache = PrefixCacheConfig::parse(prefix_name).ok_or_else(|| {
        format!("unknown prefix-cache setting '{prefix_name}' (on|off|on:<blocks>)")
    })?;
    if prefix_cache.enabled && !matches!(kv_policy, KvPolicy::Paged { .. }) {
        // Shared blocks live in the pager; the reserve policy has no
        // block identities to share.
        return Err(
            "--prefix-cache on needs --kv-policy paged (shared blocks live in the pager)"
                .into(),
        );
    }
    let kv_host_mb = args.opt_u64("kv-host-mb", 0)?;
    let host_tier = if kv_host_mb == 0 {
        HostTierConfig::off()
    } else {
        // The host tier swaps pager blocks; under the reserve policy
        // there are no block identities to demote. Refuse rather than
        // silently no-op the flag.
        let KvPolicy::Paged { block_tokens } = kv_policy else {
            return Err(
                "--kv-host-mb needs --kv-policy paged (the host tier swaps pager blocks)".into()
            );
        };
        let m = by_name(model).ok_or_else(|| {
            format!("--kv-host-mb needs a registry model for KV accounting; '{model}' is unknown")
        })?;
        let block_bytes = m.kv_bytes_per_token() * block_tokens as u64;
        let blocks = ((kv_host_mb << 20) / block_bytes.max(1)) as usize;
        if blocks == 0 {
            return Err(format!(
                "--kv-host-mb {kv_host_mb} holds less than one {block_tokens}-token KV block \
                 for '{model}'"
            ));
        }
        // Price restore vs recompute from the same step model the
        // virtual harness clocks with, so the decision and the reported
        // latencies agree.
        let device = LpuConfig::by_name("asic").expect("registry device config");
        HostTierConfig::from_step(&StepModel::from_config(&m, &device, 1), blocks)
    };
    let kv_budget_bytes = if kv_budget_mb == 0 { u64::MAX } else { kv_budget_mb << 20 };
    Ok((kv_bytes_per_token, kv_budget_bytes, kv_policy, prefix_cache, host_tier))
}

/// The resolved cluster-fleet flags (None = single-pool mode).
struct FleetArgs {
    replicas: usize,
    tier: SloTierSpec,
    autoscale: Option<AutoscaleConfig>,
    trace: ArrivalTrace,
    faults: ClusterFaultPlan,
    hedge_fraction: f64,
}

/// The cluster-fleet flags shared by `serve` and `loadtest`:
/// `--replicas`, `--slo-tier`, `--autoscale`, `--trace`,
/// `--cluster-fault-plan`, `--hedge`. Returns None when `--replicas`
/// is absent (single-pool mode); the other cluster flags without
/// `--replicas` are refused, not ignored.
fn cluster_args(args: &Args) -> Result<Option<FleetArgs>, String> {
    if args.opt("replicas").is_none() {
        for flag in ["slo-tier", "autoscale", "trace", "cluster-fault-plan", "hedge"] {
            if args.opt(flag).is_some() {
                return Err(format!("--{flag} needs --replicas (cluster mode)"));
            }
        }
        return Ok(None);
    }
    let replicas = args.opt_usize("replicas", 1)?;
    if replicas == 0 {
        return Err("--replicas must be >= 1".into());
    }
    let tier = SloTierSpec::parse(args.opt_or("slo-tier", "batch"))?;
    let autoscale = args.opt("autoscale").map(AutoscaleConfig::parse).transpose()?;
    let trace = ArrivalTrace::parse(args.opt_or("trace", "uniform"))?;
    let faults = match args.opt("cluster-fault-plan") {
        Some(spec) => ClusterFaultPlan::parse(spec).map_err(|e| e.to_string())?,
        None => ClusterFaultPlan::default(),
    };
    let hedge_fraction = args.opt_f64("hedge", 0.0)?;
    if !(0.0..=1.0).contains(&hedge_fraction) {
        return Err(format!(
            "--hedge must be a deadline fraction in [0, 1], got {hedge_fraction}"
        ));
    }
    Ok(Some(FleetArgs { replicas, tier, autoscale, trace, faults, hedge_fraction }))
}

/// Price the cluster front-end's admission estimates from the same
/// registry model + device config the virtual harness clocks with.
fn cluster_step_model(model: &str) -> Result<StepModel, String> {
    let m = by_name(model).ok_or_else(|| {
        format!("--replicas needs a registry model to price admission; '{model}' is unknown")
    })?;
    let device = LpuConfig::by_name("asic").expect("registry device config");
    Ok(StepModel::from_config(&m, &device, 1))
}

/// Export request timelines as Chrome/Perfetto trace_events JSON,
/// self-validate the document (well-formed, nonempty, every flow id
/// resolves), and spot-check the attribution identity on one request.
/// Prints a `trace-ok:` marker on success (ci greps for it).
fn write_trace_out(path: &str, timelines: &[RequestTimeline]) -> Result<(), String> {
    let src = perfetto_json(timelines).to_string();
    let events = validate_perfetto(&src)
        .map_err(|e| format!("exported trace failed self-validation: {e}"))?;
    if let Some(a) = timelines.iter().find_map(|t| t.attribution) {
        if a.component_sum().to_bits() != a.total_s().to_bits() {
            return Err(format!(
                "attribution identity broken in exported trace: components sum to {} \
                 but ttft+decode is {}",
                a.component_sum(),
                a.total_s()
            ));
        }
    }
    std::fs::write(path, &src).map_err(|e| format!("writing {path}: {e}"))?;
    println!(
        "trace-ok: {events} trace events ({} timelines) -> {path}; open at \
         https://ui.perfetto.dev",
        timelines.len()
    );
    Ok(())
}

/// Background flusher for `serve --trace-out`: every couple of seconds
/// rewrite FILE with a Perfetto export of whatever the flight recorder
/// currently holds (the ring is bounded, so the file is a rolling
/// last-N window, not an append log).
fn spawn_trace_flusher(
    path: String,
    collect: impl Fn() -> Vec<RequestTimeline> + Send + 'static,
) -> Result<(), String> {
    std::thread::Builder::new()
        .name("lpu-trace-flush".into())
        .spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(2));
            let src = perfetto_json(&collect()).to_string();
            let _ = std::fs::write(&path, src);
        })
        .map(|_| ())
        .map_err(|e| e.to_string())
}

/// Gather completed timelines across a fleet: the cluster's own tracer
/// plus each replica coordinator's. Replica-local request ids collide
/// across replicas, so each replica's ids are rebased onto a disjoint
/// range to keep Perfetto flow ids distinct.
fn collect_cluster_timelines(cluster: &Cluster) -> Vec<RequestTimeline> {
    let mut tls = cluster.tracer.completed();
    for (i, c) in cluster.replicas().iter().enumerate() {
        for mut tl in c.tracer.completed() {
            tl.request_id |= (i as u64 + 1) << 32;
            tls.push(tl);
        }
    }
    tls
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<(), String> {
    let Some(cmd) = argv.first().cloned() else {
        print!("{}", render_help("lpu", "latency processing unit toolkit", COMMANDS));
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    if args.flag("help") {
        print!("{}", render_help("lpu", "latency processing unit toolkit", COMMANDS));
        return Ok(());
    }
    match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "scaling" => cmd_scaling(&args),
        "compile" => cmd_compile(&args),
        "asm" => cmd_asm(&args),
        "disasm" => cmd_disasm(&args),
        "chip" => cmd_chip(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "validate" => cmd_validate(&args),
        "loadtest" => cmd_loadtest(&args),
        other => {
            print!("{}", render_help("lpu", "latency processing unit toolkit", COMMANDS));
            Err(format!("unknown command '{other}'"))
        }
    }
}

fn model_arg(args: &Args) -> Result<lpu::ModelConfig, String> {
    let name = args.opt("model").ok_or("--model is required")?;
    by_name(name).ok_or_else(|| {
        let names: Vec<String> = lpu::model::registry().into_iter().map(|m| m.name).collect();
        format!("unknown model '{name}'; known: {names:?}")
    })
}

fn config_arg(args: &Args) -> Result<LpuConfig, String> {
    let name = args.opt_or("config", "asic");
    LpuConfig::by_name(name).ok_or_else(|| format!("unknown config '{name}' (asic|819gbs|1.64tbs|3.28tbs|fpga)"))
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let model = model_arg(args)?;
    let cfg = config_arg(args)?;
    let devices = args.opt_usize("devices", 1)?;
    let input = args.opt_usize("in", 32)?;
    let output = args.opt_usize("out", 2016)?;
    let overlap = !args.flag("no-overlap");
    let r = simulate_generation(&model, &cfg, devices, input, output, overlap)
        .map_err(|e| e.to_string())?;
    let mut t = Table::new(
        format!("{} on {}x {}", model.name, devices, cfg.name),
        &["ms/token", "tokens/s", "bw util %", "cycles/token"],
    );
    t.row(&[
        format!("{:.3}", r.ms_per_token),
        format!("{:.1}", r.tokens_per_s),
        format!("{:.1}", r.bandwidth_util * 100.0),
        format!("{:.0}", r.cycles_per_token),
    ]);
    t.note(format!("in={input} out={output} esl_overlap={overlap}"));
    t.print();
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<(), String> {
    let model = model_arg(args)?;
    let cfg = config_arg(args)?;
    let max = args.opt_usize("max", 8)?;
    let pts = scaling_sweep(&model, &cfg, max, !args.flag("no-overlap"), 32, 128)
        .map_err(|e| e.to_string())?;
    let mut t = Table::new(format!("strong scaling: {}", model.name), &["devices", "ms/token", "speedup"]);
    for p in &pts {
        t.row(&[p.devices.to_string(), format!("{:.3}", p.ms_per_token), format!("{:.2}x", p.speedup)]);
    }
    t.note(format!("speedup per doubling: {:.2}x", speedup_per_doubling(&pts)));
    t.print();
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<(), String> {
    let model = model_arg(args)?;
    let cfg = config_arg(args)?;
    let opts = CompileOpts {
        n_devices: args.opt_usize("devices", 1)?,
        position: args.opt_usize("pos", 0)?,
        esl_overlap: !args.flag("no-overlap"),
        mode: match args.opt_usize("batch", 1)? {
            1 => ParallelMode::Single,
            b => ParallelMode::Batch { batch: b },
        },
        sxe_sets: args.opt_usize("sxe-sets", 1)?,
    };
    let c = compile(&model, &cfg, &opts).map_err(|e| e.to_string())?;
    let mut t = Table::new(
        format!("compiled {} for {}", model.name, cfg.name),
        &["instrs", "virtual regs", "peak live regs", "chains", "map bytes"],
    );
    t.row(&[
        c.stats.instrs.to_string(),
        c.stats.virtual_regs.to_string(),
        c.stats.peak_live_regs.to_string(),
        c.stats.chain.chains.to_string(),
        lpu::util::fmt_bytes(c.map.total_bytes()),
    ]);
    t.print();
    if args.flag("emit-asm") {
        print!("{}", asm::disasm_program(&c.program));
    }
    if let Some(out) = args.opt("out") {
        let bytes = c.program.to_bytes().map_err(|e| e.to_string())?;
        std::fs::write(out, &bytes).map_err(|e| e.to_string())?;
        println!("wrote {} ({} bytes)", out, bytes.len());
    }
    Ok(())
}

fn cmd_asm(args: &Args) -> Result<(), String> {
    let [input, output] = args.positional() else {
        return Err("usage: lpu asm <in.s> <out.lpubin>".into());
    };
    let src = std::fs::read_to_string(input).map_err(|e| e.to_string())?;
    let prog = asm::assemble(&src).map_err(|e| e.to_string())?;
    std::fs::write(output, prog.to_bytes().map_err(|e| e.to_string())?).map_err(|e| e.to_string())?;
    println!("assembled {} instructions -> {}", prog.len(), output);
    Ok(())
}

fn cmd_disasm(args: &Args) -> Result<(), String> {
    let [input] = args.positional() else {
        return Err("usage: lpu disasm <in.lpubin>".into());
    };
    let bytes = std::fs::read(input).map_err(|e| e.to_string())?;
    let prog = lpu::isa::Program::from_bytes(&bytes)?;
    print!("{}", asm::disasm_program(&prog));
    Ok(())
}

fn cmd_chip(args: &Args) -> Result<(), String> {
    let cfg = config_arg(args)?;
    let est = chip_estimate(&cfg);
    let mut t = Table::new(format!("chip estimate: {}", cfg.name), &["module", "area mm^2", "power mW"]);
    for m in &est.modules {
        t.row(&[m.name.to_string(), format!("{:.3}", m.area_mm2), format!("{:.2}", m.power_mw)]);
    }
    t.row(&["TOTAL".into(), format!("{:.3}", est.total_area_mm2()), format!("{:.2}", est.total_power_mw())]);
    t.note(format!("system power incl. HBM: {:.1} W", system_power_w(&cfg)));
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let model = args.opt_or("model", "opt-tiny").to_string();
    let backend = args.opt_or("backend", "pjrt");
    let workers = args.opt_usize("workers", 2)?;
    let addr = args.opt_or("addr", "127.0.0.1:7071");
    let vocab = by_name(&model).map(|m| m.vocab).unwrap_or(512);
    // Validate the backend choice once up front; a fleet then builds
    // one factory per replica from the same spec.
    let dir = default_artifacts_dir();
    match backend {
        "sim" => {}
        "pjrt" => {
            if !Engine::artifacts_present(&dir, &model) {
                return Err(format!(
                    "artifacts for '{model}' not found in {dir:?}; run `make artifacts` or use --backend sim"
                ));
            }
        }
        other => return Err(format!("unknown backend '{other}' (pjrt|sim)")),
    }
    let make_factory = || match backend {
        "sim" => BackendFactory::sim(&model, vocab),
        _ => BackendFactory::pjrt(dir.clone(), &model),
    };
    let policy = policy_arg(args)?;
    let router = router_arg(args)?;
    let faults = fault_arg(args)?;
    let (kv_bytes_per_token, kv_budget_bytes, kv_policy, prefix_cache, host_tier) =
        kv_args(args, &model)?;
    // Chunked prefill: 0 (default) = single-pass prompts; N = at most N
    // prompt tokens per fused step, interleaved with decode steps so a
    // long prompt stops inflating co-batched streams' TPOT.
    let prefill_chunk = args.opt_usize("prefill-chunk", 0)?;
    // --trace-out FILE: turn the request-lifecycle tracer on and keep
    // FILE refreshed with a Perfetto export of the flight-recorder ring
    // (distinct from loadtest's --trace, which shapes arrival traces).
    let trace_out = args.opt("trace-out").map(String::from);
    let fault_desc = if faults.is_active() {
        ", fault injection ON".to_string()
    } else {
        String::new()
    };
    let cfg = CoordinatorConfig {
        max_active_per_worker: args.opt_usize("max-active", 8)?,
        policy,
        kv_bytes_per_token,
        kv_budget_bytes,
        kv_policy,
        max_batch: args.opt_usize("max-batch", 0)?,
        prefill_chunk,
        prefix_cache,
        router,
        host_tier,
        faults,
        trace: trace_out.is_some(),
        ..CoordinatorConfig::default()
    };

    if let Some(fleet) = cluster_args(args)? {
        // Fleet mode: N replicas behind the SLO-aware front-end.
        if args.opt("trace").is_some() {
            return Err(
                "--trace shapes generated workloads; it applies to loadtest, not serve \
                 (for Perfetto span export use --trace-out FILE)"
                    .into(),
            );
        }
        let FleetArgs { replicas, tier, autoscale, faults: cfaults, hedge_fraction, .. } =
            fleet;
        let default_deadline_s = match tier {
            SloTierSpec::Batch => None,
            SloTierSpec::Interactive { ttft_s } => Some(ttft_s),
            SloTierSpec::Mixed { .. } => {
                return Err(
                    "serve: --slo-tier mixed is a workload-generator mix; use batch or \
                     interactive:<ttft_s> (clients opt in per request via deadline_s)"
                        .into(),
                )
            }
        };
        let mut pool = VirtualConfig::new(
            cfg.policy,
            workers,
            cfg.max_active_per_worker,
            cluster_step_model(&model)?,
        );
        pool.max_batch = cfg.max_batch;
        // --fault-plan composes with --replicas: the pool-level plan
        // applies to each replica identically (each coordinator below
        // is built from the same cfg, faults included).
        pool.faults = cfg.faults.clone();
        let mut cc = ClusterConfig::new(replicas, pool);
        cc.autoscale = autoscale;
        cc.default_deadline_s = default_deadline_s;
        cc.faults = cfaults;
        cc.hedge_fraction = hedge_fraction;
        cc.trace = trace_out.is_some();
        let autoscale_desc = cc.autoscale.map_or("autoscale off".to_string(), |a| {
            format!("autoscale {}..{}", a.min_replicas, a.max_replicas)
        });
        let chaos_desc = if cc.faults.is_active() {
            format!(
                ", chaos: {} crash(es) {} partition(s) {} slow",
                cc.faults.crashes.len(),
                cc.faults.partitions.len(),
                cc.faults.slow.len()
            )
        } else {
            String::new()
        };
        let hedge_desc = if cc.hedge_fraction > 0.0 {
            format!(", hedging at {:.0}% of deadline", cc.hedge_fraction * 100.0)
        } else {
            String::new()
        };
        let tier_desc = match default_deadline_s {
            None => "batch tier".to_string(),
            Some(d) => format!("interactive tier, TTFT budget {d}s"),
        };
        let cluster = Arc::new(Cluster::threaded(&cc, &model, || {
            let mut c = Coordinator::new(cfg.clone());
            c.add_pool(&model, workers, make_factory());
            c
        })?);
        if let Some(path) = trace_out.clone() {
            spawn_trace_flusher(path, {
                let cl = Arc::clone(&cluster);
                move || collect_cluster_timelines(&cl)
            })?;
        }
        let (slots, active) = (cluster.replica_count(), cluster.active_replicas());
        let handle = server::serve_cluster(Arc::clone(&cluster), addr)
            .map_err(|e| e.to_string())?;
        println!(
            "serving '{model}' fleet ({backend}, {active}/{slots} replicas active, \
             {tier_desc}, {autoscale_desc}{fault_desc}{chaos_desc}{hedge_desc}) on {} \
             with {workers} worker(s) per replica; Ctrl-C to stop",
            handle.addr
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    let mut coord = Coordinator::new(cfg);
    coord.add_pool(&model, workers, make_factory());
    let coord = Arc::new(coord);
    if let Some(path) = trace_out.clone() {
        spawn_trace_flusher(path, {
            let tracer = Arc::clone(&coord.tracer);
            move || tracer.completed()
        })?;
    }
    let handle = server::serve(Arc::clone(&coord), addr).map_err(|e| e.to_string())?;
    let prefill_desc = if prefill_chunk == 0 {
        "single-pass prefill".to_string()
    } else {
        format!("{prefill_chunk}-token chunked prefill")
    };
    let host_desc = if host_tier.enabled() {
        format!("{}-block host tier", host_tier.capacity_blocks)
    } else {
        "host tier off".to_string()
    };
    println!(
        "serving '{model}' ({backend}, {} scheduling, {} routing, {} KV, prefix cache {}, {host_desc}, {prefill_desc}{fault_desc}) on {} with {workers} worker(s); Ctrl-C to stop",
        policy.name(),
        router.name(),
        kv_policy.name(),
        prefix_cache.name(),
        handle.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &Args) -> Result<(), String> {
    let addr: std::net::SocketAddr = args
        .opt_or("addr", "127.0.0.1:7071")
        .parse()
        .map_err(|e| format!("bad --addr: {e}"))?;
    let model = args.opt_or("model", "opt-tiny");
    let prompt: Vec<i64> = args
        .opt_or("prompt", "1,2,3")
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| format!("bad token '{s}'")))
        .collect::<Result<_, _>>()?;
    let tokens = args.opt_usize("tokens", 16)?;
    let mut c = server::Client::connect(&addr).map_err(|e| e.to_string())?;
    let r = c.generate(model, &prompt, tokens, true)?;
    println!("tokens: {:?} (reason: {})", r.tokens, r.reason);
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<(), String> {
    let model = args.opt_or("model", "opt-tiny");
    let dir = default_artifacts_dir();
    if !Engine::artifacts_present(&dir, model) {
        return Err(format!("artifacts for '{model}' not found in {dir:?}; run `make artifacts`"));
    }
    let engine = Engine::load(&dir, model).map_err(|e| e.to_string())?;
    engine.validate().map_err(|e| e.to_string())?;
    println!("bridge OK: rust/PJRT decode matches the python/JAX golden vector for '{model}'");
    Ok(())
}

fn cmd_loadtest(args: &Args) -> Result<(), String> {
    use lpu::coordinator::{run_cluster_open_loop, run_open_loop, ClusterWorkload, LenDist, Workload};
    let model = args.opt_or("model", "opt-tiny").to_string();
    let backend = args.opt_or("backend", "sim");
    let n_requests = args.opt_usize("requests", 100)?;
    let vocab = by_name(&model).map(|m| m.vocab).unwrap_or(512);
    let make_factory = || match backend {
        "sim" => BackendFactory::sim(&model, vocab),
        _ => BackendFactory::pjrt(default_artifacts_dir(), &model),
    };
    if !matches!(backend, "sim" | "pjrt") {
        return Err(format!("unknown backend '{backend}'"));
    }
    let policy = policy_arg(args)?;
    let router = router_arg(args)?;
    let faults = fault_arg(args)?;
    let (kv_bytes_per_token, kv_budget_bytes, kv_policy, prefix_cache, host_tier) =
        kv_args(args, &model)?;
    let workers = args.opt_usize("workers", 2)?;
    let rates: Vec<f64> = args
        .opt_or("rates", "50,200,1000")
        .split(',')
        .map(|r| r.trim().parse().map_err(|_| format!("bad rate '{r}'")))
        .collect::<Result<_, _>>()?;
    // --trace-out FILE: record request lifecycles and export a
    // Perfetto trace of the whole study after the last rate (distinct
    // from --trace, which shapes cluster arrival intensity). The ring
    // is sized to hold every request so nothing is evicted mid-study.
    let trace_out = args.opt("trace-out").map(String::from);
    let cfg = CoordinatorConfig {
        max_active_per_worker: args.opt_usize("max-active", 4)?,
        policy,
        kv_bytes_per_token,
        kv_budget_bytes,
        kv_policy,
        prefill_chunk: args.opt_usize("prefill-chunk", 0)?,
        prefix_cache,
        router,
        host_tier,
        faults,
        trace: trace_out.is_some(),
        trace_ring: n_requests.saturating_mul(rates.len().max(1)).max(DEFAULT_TRACE_RING),
        ..CoordinatorConfig::default()
    };

    if let Some(fleet) = cluster_args(args)? {
        // Fleet mode: a fresh threaded cluster per offered rate, fed a
        // tiered, trace-shaped workload through the SLO front-end.
        let FleetArgs { replicas, tier, autoscale, trace, faults: cfaults, hedge_fraction } =
            fleet;
        let (fraction, ttft_s) = tier.mix();
        let mut pool = VirtualConfig::new(
            cfg.policy,
            workers,
            cfg.max_active_per_worker,
            cluster_step_model(&model)?,
        );
        pool.max_batch = cfg.max_batch;
        // --fault-plan composes with --replicas: each replica's
        // coordinator is built from the same cfg, faults included.
        pool.faults = cfg.faults.clone();
        let mut cc = ClusterConfig::new(replicas, pool);
        cc.autoscale = autoscale;
        cc.faults = cfaults;
        cc.hedge_fraction = hedge_fraction;
        cc.trace = trace_out.is_some();
        let mut trace_tls: Vec<RequestTimeline> = Vec::new();
        let mut t = Table::new(
            format!(
                "cluster load study: {model} ({backend} backend, {replicas} replicas, \
                 {} trace)",
                trace.name()
            ),
            &[
                "req/s",
                "completed",
                "shed",
                "failed",
                "TTFT p50 ms",
                "TTFT p99 ms",
                "int attain %",
                "peak reps",
                "failover",
                "hedge w/i",
            ],
        );
        for &rate in &rates {
            let cluster = Cluster::threaded(&cc, &model, || {
                let mut c = Coordinator::new(cfg.clone());
                c.add_pool(&model, workers, make_factory());
                c
            })?;
            let wl = ClusterWorkload {
                base: Workload {
                    model: model.clone(),
                    rate,
                    n_requests,
                    prompt_len: LenDist::Uniform(2, 10),
                    output_len: LenDist::LongTail { min: 4, mean_extra: 12.0, cap: 64 },
                    vocab,
                    seed: 7,
                },
                trace,
                interactive_fraction: fraction,
                interactive_deadline_s: ttft_s,
            };
            let r = run_cluster_open_loop(&cluster, &wl)?;
            let s = cluster.metrics.snapshot();
            let attain = if s.tier_interactive_submitted == 0 {
                100.0
            } else {
                100.0 * s.tier_interactive_attained as f64
                    / s.tier_interactive_submitted as f64
            };
            let peak =
                cluster.replica_timeline().iter().map(|&(_, n)| n).max().unwrap_or(0);
            t.row(&[
                format!("{rate:.0}"),
                r.completed.to_string(),
                r.shed.to_string(),
                r.failed.to_string(),
                format!("{:.2}", r.ttft.p50 * 1e3),
                format!("{:.2}", r.ttft.p99 * 1e3),
                format!("{attain:.1}"),
                peak.to_string(),
                s.streams_failed_over.to_string(),
                format!("{}/{}", s.hedges_won, s.hedges_issued),
            ]);
            if trace_out.is_some() {
                // Keep the last rate's fleet-wide timelines for export.
                trace_tls = collect_cluster_timelines(&cluster);
            }
            cluster.shutdown();
        }
        t.note(format!(
            "tier mix: {:.0}% interactive (TTFT budget {ttft_s}s); shed counts \
             front-end admission drops",
            fraction * 100.0
        ));
        t.print();
        if let Some(path) = &trace_out {
            write_trace_out(path, &trace_tls)?;
        }
        return Ok(());
    }

    let mut coord = Coordinator::new(cfg);
    coord.add_pool(&model, workers, make_factory());
    let mut t = Table::new(
        format!("load study: {model} ({backend} backend, {} scheduling)", policy.name()),
        &["req/s", "tokens/s", "TTFT p50 ms", "TTFT p99 ms", "TPOT p95 ms", "latency p99 ms"],
    );
    for rate in rates {
        let wl = Workload {
            model: model.clone(),
            rate,
            n_requests,
            prompt_len: LenDist::Uniform(2, 10),
            output_len: LenDist::LongTail { min: 4, mean_extra: 12.0, cap: 64 },
            vocab,
            seed: 7,
        };
        let r = run_open_loop(&coord, &wl)?;
        t.row(&[
            format!("{rate:.0}"),
            format!("{:.0}", r.tokens_per_s),
            format!("{:.2}", r.ttft.p50 * 1e3),
            format!("{:.2}", r.ttft.p99 * 1e3),
            format!("{:.2}", r.tpot.p95 * 1e3),
            format!("{:.2}", r.request_latency.p99 * 1e3),
        ]);
    }
    t.print();
    if let Some(path) = &trace_out {
        let (tls, _) = coord.tracer.drain();
        write_trace_out(path, &tls)?;
    }
    coord.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(raw: &[&str]) -> Args {
        let v: Vec<String> = raw.iter().map(|s| s.to_string()).collect();
        Args::parse(&v).expect("flag syntax")
    }

    #[test]
    fn cluster_flags_without_replicas_are_refused_not_ignored() {
        for (flag, value) in [
            ("--slo-tier", "mixed:0.05:0.3"),
            ("--autoscale", "min=1,max=4"),
            ("--trace", "uniform"),
            ("--cluster-fault-plan", "crash=0@1"),
            ("--hedge", "0.5"),
        ] {
            let err = cluster_args(&argv(&[flag, value])).unwrap_err();
            assert!(
                err.contains(flag.trim_start_matches('-')) && err.contains("--replicas"),
                "{flag}: {err}"
            );
        }
        assert!(cluster_args(&argv(&[])).expect("no fleet flags").is_none());
    }

    #[test]
    fn malformed_cluster_fault_plan_names_the_bad_field() {
        let cases = [
            ("crash=zz@1", "crash"),
            ("crash=0", "crash"),
            ("partition=0@5..2", "partition"),
            ("partition=0@oops", "partition"),
            ("slow=0x0", "slow"),
            ("probe=nope", "probe"),
            ("explode=1", "explode"),
        ];
        for (spec, field) in cases {
            let err = cluster_args(&argv(&["--replicas", "2", "--cluster-fault-plan", spec]))
                .unwrap_err();
            assert!(err.contains(field), "spec `{spec}`: {err}");
        }
    }

    #[test]
    fn malformed_pool_fault_plan_names_the_bad_field() {
        let cases = [
            ("transient=2", "transient"),
            ("crash=0", "crash"),
            ("slow=1xbad", "slow"),
            ("retries=-1", "retries"),
            ("bogus=1", "bogus"),
        ];
        for (spec, field) in cases {
            let err = fault_arg(&argv(&["--fault-plan", spec])).unwrap_err();
            assert!(err.contains(field), "spec `{spec}`: {err}");
        }
        assert!(!fault_arg(&argv(&[])).expect("inert default").is_active());
    }

    #[test]
    fn malformed_autoscale_and_trace_name_the_bad_field() {
        let err =
            cluster_args(&argv(&["--replicas", "2", "--autoscale", "min=3,max=2"])).unwrap_err();
        assert!(err.contains("max"), "{err}");
        let err =
            cluster_args(&argv(&["--replicas", "2", "--autoscale", "ceiling=9"])).unwrap_err();
        assert!(err.contains("ceiling"), "{err}");
        let err = cluster_args(&argv(&["--replicas", "2", "--trace", "flash:bad"])).unwrap_err();
        assert!(err.contains("flash:bad"), "{err}");
        let err = cluster_args(&argv(&["--replicas", "2", "--trace", "diurnal:60:x"])).unwrap_err();
        assert!(err.contains('x'), "{err}");
    }

    #[test]
    fn trace_flag_confusion_points_at_trace_out() {
        // --trace (arrival-trace shape) is one typo away from
        // --trace-out (Perfetto export); a bad value must name the
        // other flag so the user lands on the right one.
        let err =
            cluster_args(&argv(&["--replicas", "2", "--trace", "spans.json"])).unwrap_err();
        assert!(err.contains("--trace-out"), "{err}");
    }

    #[test]
    fn hedge_fraction_outside_unit_interval_is_refused() {
        for bad in ["1.5", "-0.1"] {
            let err = cluster_args(&argv(&["--replicas", "2", "--hedge", bad])).unwrap_err();
            assert!(err.contains("--hedge") && err.contains(bad), "{bad}: {err}");
        }
    }

    #[test]
    fn well_formed_fleet_flags_parse_and_compose() {
        let fleet = cluster_args(&argv(&[
            "--replicas",
            "3",
            "--cluster-fault-plan",
            "probe=0.05,crash=0@0.5,partition=1@1..2,slow=2x3",
            "--hedge",
            "0.3",
            "--fault-plan",
            "seed=9,transient=0.01,retries=2,backoff=0.0001",
        ]))
        .expect("valid spec")
        .expect("fleet mode");
        assert_eq!(fleet.replicas, 3);
        assert!(fleet.faults.is_active());
        assert_eq!(fleet.faults.crashes.len(), 1);
        assert_eq!(fleet.faults.partitions.len(), 1);
        assert!((fleet.hedge_fraction - 0.3).abs() < 1e-12);
        // The pool-level plan composes: it is parsed independently and
        // applied to each replica identically.
        let pool = fault_arg(&argv(&["--fault-plan", "seed=9,transient=0.01"]))
            .expect("valid pool plan");
        assert!(pool.is_active());
    }
}
