//! Multi-ring cluster scenarios.
//!
//! Drives the strong-scaling sweep behind Fig 7(c) (1–8 LPUs on one
//! model) and the reconfigurable multi-model scenario of Fig 4(b)
//! (e.g. two different models on two independent 4-rings of an 8-device
//! Orion-cloud, with no switching overhead).

use crate::compiler::CompileError;
use crate::config::LpuConfig;
use crate::model::ModelConfig;
use crate::sim::{simulate_generation, GenerationReport};

use super::RingConfig;

/// One row of a strong-scaling sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub devices: usize,
    pub ms_per_token: f64,
    /// Speedup vs the 1-device (or smallest feasible) point.
    pub speedup: f64,
}

/// Strong scaling of one model across 1..=max_devices (powers of two),
/// with or without ESL latency hiding. Models too large for small device
/// counts are skipped (the paper's 66B starts at 2 devices).
pub fn scaling_sweep(
    model: &ModelConfig,
    cfg: &LpuConfig,
    max_devices: usize,
    esl_overlap: bool,
    in_tokens: usize,
    out_tokens: usize,
) -> Result<Vec<ScalingPoint>, CompileError> {
    let mut points: Vec<ScalingPoint> = Vec::new();
    let mut base: Option<(usize, f64)> = None;
    let mut n = 1;
    while n <= max_devices {
        match simulate_generation(model, cfg, n, in_tokens, out_tokens, esl_overlap) {
            Ok(r) => {
                let (bn, bms) = *base.get_or_insert((n, r.ms_per_token));
                // Normalize speedup to a hypothetical single device:
                // speedup(n) = bms/ms * bn (linear extrapolation below
                // the smallest feasible count, as the paper plots).
                points.push(ScalingPoint {
                    devices: n,
                    ms_per_token: r.ms_per_token,
                    speedup: bms / r.ms_per_token * bn as f64,
                });
            }
            Err(CompileError::OutOfMemory { .. }) => {}
            Err(e) => return Err(e),
        }
        n *= 2;
    }
    Ok(points)
}

/// Geometric-mean speedup per device doubling (the paper's headline
/// "1.75× speedup for doubling the number of devices").
pub fn speedup_per_doubling(points: &[ScalingPoint]) -> f64 {
    let mut ratios = Vec::new();
    for w in points.windows(2) {
        if w[1].devices == w[0].devices * 2 {
            ratios.push(w[1].speedup / w[0].speedup);
        }
    }
    if ratios.is_empty() {
        return 1.0;
    }
    let log_sum: f64 = ratios.iter().map(|r| r.ln()).sum();
    (log_sum / ratios.len() as f64).exp()
}

/// Reconfigured multi-model deployment: each ring serves its own model
/// concurrently (Fig 4(b)). Returns one report per ring.
pub fn multi_model_deployment(
    server_devices: usize,
    ring_size: usize,
    models: &[&ModelConfig],
    cfg: &LpuConfig,
    out_tokens: usize,
) -> Result<Vec<(usize, GenerationReport)>, String> {
    let rc = RingConfig::new(server_devices, ring_size)?;
    rc.validate()?;
    if models.len() != rc.n_rings() {
        return Err(format!("{} models for {} rings", models.len(), rc.n_rings()));
    }
    let mut out = Vec::with_capacity(models.len());
    for (ring, model) in models.iter().enumerate() {
        let r = simulate_generation(model, cfg, ring_size, 32, out_tokens, true)
            .map_err(|e| format!("ring {ring} ({}): {e}", model.name))?;
        out.push((ring, r));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;

    #[test]
    fn scaling_improves_with_devices() {
        // Fig 7(c) model: GPT3-20B.
        let m = by_name("gpt3-20b").unwrap();
        let cfg = LpuConfig::asic_3_28tbs();
        let pts = scaling_sweep(&m, &cfg, 8, true, 32, 64).unwrap();
        assert_eq!(pts.len(), 4); // 1,2,4,8
        for w in pts.windows(2) {
            assert!(
                w[1].ms_per_token < w[0].ms_per_token,
                "{} devs {}ms !> {} devs {}ms",
                w[0].devices,
                w[0].ms_per_token,
                w[1].devices,
                w[1].ms_per_token
            );
        }
        let per_doubling = speedup_per_doubling(&pts);
        assert!(per_doubling > 1.5, "per-doubling speedup {per_doubling}");
    }

    #[test]
    fn small_models_stop_scaling() {
        // A 1.3B model saturates: fixed per-token overheads (sampler,
        // host, sync tails) dominate once shards are tiny — the Fig 4(b)
        // motivation for reconfiguring into smaller rings.
        let m = by_name("opt-1.3b").unwrap();
        let cfg = LpuConfig::asic_3_28tbs();
        let pts = scaling_sweep(&m, &cfg, 8, true, 32, 64).unwrap();
        let s8 = pts.last().unwrap();
        assert_eq!(s8.devices, 8);
        assert!(s8.speedup < 6.0, "1.3B should not scale near-linearly to 8 devices");
    }

    #[test]
    fn esl_overlap_scales_better_than_blocking() {
        let m = by_name("gpt3-20b").unwrap();
        let cfg = LpuConfig::asic_3_28tbs();
        let with = scaling_sweep(&m, &cfg, 8, true, 32, 64).unwrap();
        let without = scaling_sweep(&m, &cfg, 8, false, 32, 64).unwrap();
        let s_with = speedup_per_doubling(&with);
        let s_without = speedup_per_doubling(&without);
        assert!(
            s_with > s_without,
            "overlap {s_with:.3} !> blocking {s_without:.3}"
        );
    }

    #[test]
    fn oversized_small_counts_skipped() {
        let m = by_name("opt-66b").unwrap();
        let cfg = LpuConfig::asic_3_28tbs();
        let pts = scaling_sweep(&m, &cfg, 8, true, 32, 32).unwrap();
        // 66B needs >= 2 devices of 96 GB.
        assert_eq!(pts.first().unwrap().devices, 2);
    }

    #[test]
    fn multi_model_two_rings() {
        let m1 = by_name("opt-mini").unwrap();
        let m2 = by_name("opt-tiny").unwrap();
        let cfg = LpuConfig::fpga_u55c();
        let reports =
            multi_model_deployment(8, 4, &[&m1, &m2], &cfg, 32).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].1.n_devices, 4);
    }

    #[test]
    fn multi_model_wrong_count_rejected() {
        let m1 = by_name("opt-tiny").unwrap();
        let cfg = LpuConfig::fpga_u55c();
        assert!(multi_model_deployment(8, 4, &[&m1], &cfg, 8).is_err());
    }
}
