//! Expandable Synchronization Link (ESL).
//!
//! The P2P interconnect of the paper: dual-QSFP full-duplex links in a
//! ring, a custom protocol that overlaps vector–matrix computation with
//! synchronization (the per-instruction overlap lives in
//! [`crate::sim::core`]; this module owns the *network* itself):
//!
//! * [`Packet`]/[`Router`] — packet-header formulation: "the router
//!   determines the number and direction of hops based on the device ID
//!   to formulate a packet header that guarantees the most efficient
//!   communication path" (Fig 4(b));
//! * [`RingConfig`] — the reconfigurable 2/4/8-device ring partitioning:
//!   an 8-device server can run one 8-ring, two independent 4-rings, or
//!   four 2-rings, without rewiring ("each ring is guaranteed not to
//!   intersect with a different ring");
//! * [`LinkModel`] — packetization and cut-through wire timing used by
//!   tests and the cluster driver;
//! * [`cluster`] — multi-ring serving scenarios (different models on
//!   different rings) and the strong-scaling sweep behind Fig 7(c).

pub mod cluster;

use crate::util::json::{obj, Json};

/// Direction around the ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Clockwise,
    CounterClockwise,
}

/// An ESL packet header (the router's on-wire routing decision).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Packet {
    pub src: usize,
    pub dst: usize,
    pub hops: usize,
    pub dir: Direction,
    /// Payload bytes in this packet.
    pub bytes: u32,
    /// Sequence number within the transfer.
    pub seq: u32,
}

/// Maximum payload per packet (the "bitwidth of the P2P interface" chunk
/// the SXE column-tasks are sized to).
pub const PACKET_MTU: u32 = 4096;

/// A reconfigurable ring partitioning of `n_devices` (Fig 4(b)).
#[derive(Clone, Debug, PartialEq)]
pub struct RingConfig {
    pub n_devices: usize,
    /// Ring size (2, 4, or 8 in the paper; any power of two ≤ n here).
    pub ring_size: usize,
}

impl RingConfig {
    pub fn new(n_devices: usize, ring_size: usize) -> Result<RingConfig, String> {
        if !n_devices.is_power_of_two() || !ring_size.is_power_of_two() {
            return Err(format!("devices ({n_devices}) and ring size ({ring_size}) must be powers of two"));
        }
        if ring_size > n_devices {
            return Err(format!("ring size {ring_size} exceeds device count {n_devices}"));
        }
        Ok(RingConfig { n_devices, ring_size })
    }

    /// Number of independent rings.
    pub fn n_rings(&self) -> usize {
        self.n_devices / self.ring_size
    }

    /// Ring index of a device. Contiguous blocks: the physical full ring
    /// is split into arcs, so no two rings share a link.
    pub fn ring_of(&self, device: usize) -> usize {
        assert!(device < self.n_devices);
        device / self.ring_size
    }

    /// Devices in ring `r`, in ring order.
    pub fn members(&self, r: usize) -> Vec<usize> {
        let base = r * self.ring_size;
        (base..base + self.ring_size).collect()
    }

    /// All rings are disjoint and cover every device (paper invariant).
    pub fn validate(&self) -> Result<(), String> {
        let mut seen = vec![false; self.n_devices];
        for r in 0..self.n_rings() {
            for d in self.members(r) {
                if seen[d] {
                    return Err(format!("device {d} in two rings"));
                }
                seen[d] = true;
            }
        }
        if seen.iter().all(|&s| s) {
            Ok(())
        } else {
            Err("uncovered device".into())
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("n_devices", self.n_devices.into()),
            ("ring_size", self.ring_size.into()),
        ])
    }
}

/// The per-device router: computes packet headers.
#[derive(Clone, Debug)]
pub struct Router {
    pub device: usize,
    pub ring: RingConfig,
}

impl Router {
    pub fn new(device: usize, ring: RingConfig) -> Router {
        Router { device, ring }
    }

    /// Route to `dst`: shortest direction around this device's ring.
    /// Errors if `dst` is not in the same ring (rings never intersect).
    pub fn route(&self, dst: usize) -> Result<(usize, Direction), String> {
        let r = self.ring.ring_of(self.device);
        if self.ring.ring_of(dst) != r {
            return Err(format!(
                "device {dst} is in ring {} (this is ring {r}); rings do not intersect",
                self.ring.ring_of(dst)
            ));
        }
        let size = self.ring.ring_size;
        let me = self.device % size;
        let them = dst % size;
        let cw = (them + size - me) % size;
        let ccw = (me + size - them) % size;
        if cw == 0 {
            return Err("route to self".into());
        }
        if cw <= ccw {
            Ok((cw, Direction::Clockwise))
        } else {
            Ok((ccw, Direction::CounterClockwise))
        }
    }

    /// Split a transfer into MTU packets with headers.
    pub fn packetize(&self, dst: usize, bytes: u64) -> Result<Vec<Packet>, String> {
        let (hops, dir) = self.route(dst)?;
        let n = bytes.div_ceil(PACKET_MTU as u64).max(1);
        Ok((0..n)
            .map(|seq| Packet {
                src: self.device,
                dst,
                hops,
                dir,
                bytes: if seq == n - 1 && bytes % PACKET_MTU as u64 != 0 {
                    (bytes % PACKET_MTU as u64) as u32
                } else {
                    PACKET_MTU
                },
                seq: seq as u32,
            })
            .collect())
    }
}

/// Wire-level timing of one ESL link (per direction).
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// Bytes/s per direction (dual QSFP28: 25 GB/s).
    pub bw: f64,
    /// Per-hop router + serdes latency, seconds.
    pub hop_latency: f64,
}

impl LinkModel {
    /// Cut-through transfer time: packets stream back-to-back; each hop
    /// adds latency once (pipelined forwarding, not store-and-forward).
    pub fn transfer_time(&self, bytes: u64, hops: usize) -> f64 {
        bytes as f64 / self.bw + self.hop_latency * hops.max(1) as f64
    }

    /// Store-and-forward time (the ablation: why cut-through matters).
    pub fn store_and_forward_time(&self, bytes: u64, hops: usize) -> f64 {
        (bytes as f64 / self.bw + self.hop_latency) * hops.max(1) as f64
    }

    /// Ring all-reduce wall time without any compute overlap (the
    /// GPU-like blocking baseline): 2(n-1) sequential chunk steps.
    pub fn blocking_allreduce_time(&self, vector_bytes: u64, ring: usize) -> f64 {
        if ring <= 1 {
            return 0.0;
        }
        let chunk = vector_bytes.div_ceil(ring as u64);
        2.0 * (ring as f64 - 1.0) * self.transfer_time(chunk, 1)
    }

    /// Visible all-reduce time under ESL overlap: the transfer body hides
    /// behind compute; one tail chunk per step remains.
    pub fn overlapped_allreduce_tail(&self, vector_bytes: u64, ring: usize) -> f64 {
        if ring <= 1 {
            return 0.0;
        }
        let chunk = (vector_bytes.div_ceil(ring as u64)).min(PACKET_MTU as u64);
        2.0 * (ring as f64 - 1.0) * self.transfer_time(chunk, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::quick;

    #[test]
    fn ring_partitions_valid() {
        for (n, s) in [(8, 8), (8, 4), (8, 2), (4, 2), (2, 2), (4, 4)] {
            let rc = RingConfig::new(n, s).unwrap();
            rc.validate().unwrap();
            assert_eq!(rc.n_rings(), n / s);
        }
    }

    #[test]
    fn bad_ring_configs_rejected() {
        assert!(RingConfig::new(6, 2).is_err());
        assert!(RingConfig::new(8, 3).is_err());
        assert!(RingConfig::new(4, 8).is_err());
    }

    #[test]
    fn rings_never_intersect() {
        let rc = RingConfig::new(8, 4).unwrap();
        let a: Vec<usize> = rc.members(0);
        let b: Vec<usize> = rc.members(1);
        assert_eq!(a, vec![0, 1, 2, 3]);
        assert_eq!(b, vec![4, 5, 6, 7]);
        assert!(a.iter().all(|d| !b.contains(d)));
    }

    #[test]
    fn router_picks_shortest_direction() {
        let rc = RingConfig::new(8, 8).unwrap();
        let r = Router::new(0, rc);
        assert_eq!(r.route(1).unwrap(), (1, Direction::Clockwise));
        assert_eq!(r.route(7).unwrap(), (1, Direction::CounterClockwise));
        assert_eq!(r.route(4).unwrap(), (4, Direction::Clockwise)); // tie -> cw
        assert_eq!(r.route(6).unwrap(), (2, Direction::CounterClockwise));
    }

    #[test]
    fn router_rejects_cross_ring_and_self() {
        let rc = RingConfig::new(8, 4).unwrap();
        let r = Router::new(1, rc);
        assert!(r.route(5).is_err()); // other ring
        assert!(r.route(1).is_err()); // self
        assert!(r.route(2).is_ok());
    }

    #[test]
    fn packetize_covers_bytes() {
        let rc = RingConfig::new(4, 4).unwrap();
        let r = Router::new(0, rc);
        let pkts = r.packetize(2, 10_000).unwrap();
        assert_eq!(pkts.len(), 3);
        let total: u64 = pkts.iter().map(|p| p.bytes as u64).sum();
        assert_eq!(total, 10_000);
        assert_eq!(pkts[0].bytes, PACKET_MTU);
        assert_eq!(pkts[2].bytes, 10_000 - 2 * PACKET_MTU as u64 as u32);
        assert!(pkts.iter().enumerate().all(|(i, p)| p.seq == i as u32));
    }

    #[test]
    fn cut_through_beats_store_and_forward() {
        let l = LinkModel { bw: 25e9, hop_latency: 500e-9 };
        let ct = l.transfer_time(1_000_000, 4);
        let sf = l.store_and_forward_time(1_000_000, 4);
        assert!(ct < sf);
        // 4 hops of 1 MB: SF pays the wire 4x.
        assert!(sf > 3.0 * ct * 0.8);
    }

    #[test]
    fn overlap_tail_much_smaller_than_blocking() {
        let l = LinkModel { bw: 25e9, hop_latency: 500e-9 };
        let d_bytes = 9216 * 2; // opt-66b hidden vector
        for ring in [2usize, 4, 8] {
            let blocking = l.blocking_allreduce_time(d_bytes, ring);
            let tail = l.overlapped_allreduce_tail(d_bytes, ring);
            assert!(tail <= blocking, "ring {ring}");
        }
        // For large vectors the gap is wide.
        let big = 1_000_000u64;
        assert!(l.overlapped_allreduce_tail(big, 8) < 0.2 * l.blocking_allreduce_time(big, 8));
    }

    #[test]
    fn prop_route_hops_bounded_by_half_ring() {
        quick("route-hops-bound", |rng| {
            let size = 1usize << rng.range(1, 4); // 2..8
            let rc = RingConfig::new(8.max(size), size).map_err(|e| e)?;
            let ring_idx = rng.range(0, rc.n_rings());
            let members = rc.members(ring_idx);
            let a = *rng.choose(&members);
            let mut b = *rng.choose(&members);
            if a == b {
                b = members[(members.iter().position(|&m| m == a).unwrap() + 1) % members.len()];
            }
            let r = Router::new(a, rc);
            let (hops, _) = r.route(b)?;
            if hops >= 1 && hops <= size / 2 {
                Ok(())
            } else {
                Err(format!("route {a}->{b} in ring of {size}: {hops} hops"))
            }
        });
    }
}
