//! HyperDex Model and Memory Mapper.
//!
//! "Analyzes the given model architecture and parameters, determining the
//! most optimal memory allocation and alignment of each model parameter
//! for maximum burst and streamlined processing ... divides the
//! multi-head attention weights with head-wise tiles and the feed-forward
//! network weights with column-wise tiles ... memory mapping of the tiled
//! weights that perfectly matches the memory channel bitwidth and the
//! order of operation."
//!
//! The map is per-device (intra-layer / tensor parallelism): attention is
//! partitioned head-wise, FFN column-wise on FC1 and row-wise on FC2, LM
//! head column-wise over the vocabulary. Every region is aligned to the
//! HBM burst size and padded so its column count is a multiple of the
//! MAC-tree count (the tile width streamed per cycle).

use super::CompileError;
use crate::config::LpuConfig;
use crate::model::{Family, ModelConfig};

/// Tiling scheme of a weight region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tiling {
    /// Head-wise: tiles of `head_dim` columns, one attention head each.
    HeadWise { head_dim: usize, heads: usize },
    /// Column-wise: tiles of `cols` columns (= MAC-tree count).
    ColumnWise { cols: usize },
    /// Row vector (norm params, biases, embedding rows).
    Vector,
    /// KV cache lines (seq-major, head-minor; strobe-transposed on write).
    KvCache { head_dim: usize, heads: usize, max_seq: usize },
}

/// One mapped HBM region on a device.
#[derive(Clone, Debug)]
pub struct Region {
    pub name: String,
    /// Byte address in device HBM.
    pub addr: u64,
    /// Size in bytes (padded).
    pub bytes: u64,
    /// Logical rows (k) and columns (n) of the tensor, post-partition.
    pub rows: usize,
    pub cols: usize,
    pub tiling: Tiling,
}

impl Region {
    /// Elements (FP16) in the padded region.
    pub fn elems(&self) -> u64 {
        self.bytes / 2
    }
}

/// The full per-device memory map.
#[derive(Clone, Debug)]
pub struct MemoryMap {
    pub regions: Vec<Region>,
    /// Device HBM capacity.
    pub capacity: u64,
    /// Devices in the tensor-parallel group.
    pub n_devices: usize,
    /// Local head count (heads / n_devices).
    pub heads_local: usize,
    /// Local FFN width (d_ffn / n_devices, padded).
    pub ffn_local: usize,
    /// Local QKV output width (3 * d / n_devices, padded).
    pub qkv_local: usize,
    /// Local vocab shard (vocab / n_devices, padded).
    pub vocab_local: usize,
}

impl MemoryMap {
    pub fn get(&self, name: &str) -> Option<&Region> {
        self.regions.iter().find(|r| r.name == name)
    }

    pub fn total_bytes(&self) -> u64 {
        self.regions.iter().map(|r| r.bytes).sum()
    }

    /// Weight bytes only (excluding KV cache reservations).
    pub fn weight_bytes(&self) -> u64 {
        self.regions.iter().filter(|r| !r.name.contains("cache")).map(|r| r.bytes).sum()
    }

    /// Validate structural invariants: in-bounds, aligned, disjoint.
    pub fn validate(&self, align: u64) -> Result<(), String> {
        let mut sorted: Vec<&Region> = self.regions.iter().collect();
        sorted.sort_by_key(|r| r.addr);
        let mut prev_end = 0u64;
        for r in sorted {
            if r.addr % align != 0 {
                return Err(format!("{}: addr {:#x} not {}-aligned", r.name, r.addr, align));
            }
            if r.addr < prev_end {
                return Err(format!("{}: overlaps previous region (addr {:#x} < {:#x})", r.name, r.addr, prev_end));
            }
            prev_end = r.addr + r.bytes;
            if prev_end > self.capacity {
                return Err(format!("{}: exceeds capacity ({} > {})", r.name, prev_end, self.capacity));
            }
        }
        Ok(())
    }
}

fn pad_to(v: usize, m: usize) -> usize {
    v.div_ceil(m) * m
}

/// Build the per-device memory map for `n_devices`-way tensor parallelism.
pub fn map_model(
    model: &ModelConfig,
    cfg: &LpuConfig,
    n_devices: usize,
) -> Result<MemoryMap, CompileError> {
    let bad = |reason: String| CompileError::BadPartition { devices: n_devices, reason };
    if model.n_heads % n_devices != 0 {
        return Err(bad(format!("{} heads not divisible by {} devices", model.n_heads, n_devices)));
    }
    let d = model.d_model;
    let hd = model.head_dim();
    let heads_local = model.n_heads / n_devices;
    // Column paddings: streamed tile width is the MAC-tree count.
    let tile_w = cfg.mac_trees;
    let qkv_local = pad_to(3 * d / n_devices, tile_w);
    let ffn_local = pad_to(model.d_ffn.div_ceil(n_devices), tile_w);
    let vocab_local = pad_to(model.vocab.div_ceil(n_devices), tile_w);
    let d_local = heads_local * hd;
    let bias = !matches!(model.family, Family::Llama);

    // Burst alignment for region starts.
    let align: u64 = 256;
    let mut regions: Vec<Region> = Vec::with_capacity(model.n_layers * 8 + 6);
    let mut cursor: u64 = 0;
    let mut push = |name: String, rows: usize, cols: usize, tiling: Tiling, extra_elems: usize| {
        let bytes = ((rows * cols + extra_elems) as u64 * 2).div_ceil(align) * align;
        let r = Region { name, addr: cursor, bytes, rows, cols, tiling };
        cursor += bytes;
        regions.push(r);
    };

    // Token embedding: vocab-sharded across the ring (row-parallel
    // lookup; the owning device broadcasts the row — one d-vector, noise
    // next to the weight streams). Positional table is small: replicate.
    push("embed.token".into(), model.vocab.div_ceil(n_devices), d, Tiling::Vector, 0);
    if !matches!(model.family, Family::Llama) {
        // Positional table: row-sharded like the token table.
        push("embed.pos".into(), model.max_seq.div_ceil(n_devices), d, Tiling::Vector, 0);
    }

    for l in 0..model.n_layers {
        let b3 = if bias { qkv_local } else { 0 };
        push(
            format!("layer{l}.ln1"),
            2,
            d,
            Tiling::Vector,
            0,
        );
        push(
            format!("layer{l}.qkv"),
            d,
            qkv_local,
            Tiling::HeadWise { head_dim: hd, heads: heads_local },
            b3,
        );
        push(
            format!("layer{l}.kcache"),
            model.max_seq,
            d_local,
            Tiling::KvCache { head_dim: hd, heads: heads_local, max_seq: model.max_seq },
            0,
        );
        push(
            format!("layer{l}.vcache"),
            model.max_seq,
            d_local,
            Tiling::KvCache { head_dim: hd, heads: heads_local, max_seq: model.max_seq },
            0,
        );
        push(
            format!("layer{l}.attn_out"),
            d_local,
            d,
            Tiling::ColumnWise { cols: tile_w },
            if bias { d } else { 0 },
        );
        push(format!("layer{l}.ln2"), 2, d, Tiling::Vector, 0);
        match model.family {
            Family::Llama => {
                // Fused gate+up (column-parallel), then down (row-parallel).
                push(
                    format!("layer{l}.fc1"),
                    d,
                    2 * ffn_local,
                    Tiling::ColumnWise { cols: tile_w },
                    0,
                );
                push(format!("layer{l}.fc2"), ffn_local, d, Tiling::ColumnWise { cols: tile_w }, 0);
            }
            _ => {
                push(
                    format!("layer{l}.fc1"),
                    d,
                    ffn_local,
                    Tiling::ColumnWise { cols: tile_w },
                    if bias { ffn_local } else { 0 },
                );
                push(
                    format!("layer{l}.fc2"),
                    ffn_local,
                    d,
                    Tiling::ColumnWise { cols: tile_w },
                    if bias { d } else { 0 },
                );
            }
        }
    }

    push("final_ln".into(), 2, d, Tiling::Vector, 0);
    push("lm_head".into(), d, vocab_local, Tiling::ColumnWise { cols: tile_w }, 0);

    let map = MemoryMap {
        regions,
        capacity: cfg.hbm.capacity(),
        n_devices,
        heads_local,
        ffn_local,
        qkv_local,
        vocab_local,
    };
    let need = map.total_bytes();
    if need > map.capacity {
        return Err(CompileError::OutOfMemory {
            need,
            have: map.capacity,
            devices: n_devices,
        });
    }
    map.validate(align).map_err(|e| CompileError::BadPartition {
        devices: n_devices,
        reason: format!("internal map invariant violated: {e}"),
    })?;
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::util::proptest::quick;

    fn map(name: &str, cfg: &LpuConfig, n: usize) -> MemoryMap {
        map_model(&by_name(name).unwrap(), cfg, n).unwrap()
    }

    #[test]
    fn regions_disjoint_and_aligned() {
        let m = map("opt-1.3b", &LpuConfig::asic_3_28tbs(), 1);
        m.validate(256).unwrap();
    }

    #[test]
    fn total_close_to_model_weight_bytes_single_device() {
        let model = by_name("opt-1.3b").unwrap();
        let m = map("opt-1.3b", &LpuConfig::asic_3_28tbs(), 1);
        let w = m.weight_bytes() as f64;
        // The map stores the LM head untied from the token embedding: the
        // embedding is row-major (row gather) while the LM head must be
        // column-tiled for streaming, so both layouts are resident.
        let expect = (model.weight_bytes()
            + model.vocab as u64 * model.d_model as u64 * 2) as f64;
        let rel = (w - expect).abs() / expect;
        assert!(rel < 0.02, "mapped {w:.3e} vs model {expect:.3e} (rel {rel:.4})");
        // KV reservation matches model accounting.
        let kv = (m.total_bytes() - m.weight_bytes()) as f64;
        let expect_kv = model.kv_capacity_bytes(model.max_seq) as f64;
        assert!((kv - expect_kv).abs() / expect_kv < 0.02, "kv {kv:.3e} vs {expect_kv:.3e}");
    }

    #[test]
    fn two_devices_halve_the_shard() {
        let one = map("opt-6.7b", &LpuConfig::asic_3_28tbs(), 1);
        let two = map("opt-6.7b", &LpuConfig::asic_3_28tbs(), 2);
        let ratio = two.weight_bytes() as f64 / one.weight_bytes() as f64;
        // Sharded weights + embeddings halve; the positional table and
        // padding keep it just above 1/2.
        assert!(ratio > 0.5 && ratio < 0.56, "ratio {ratio}");
        assert_eq!(two.heads_local, 16);
    }

    #[test]
    fn opt66b_fits_orion_cloud_eight_devices() {
        // Paper: 66B fits the "128 GB" (= 128 GiB) Orion-cloud.
        let m = map("opt-66b", &LpuConfig::fpga_u55c(), 8);
        assert!(m.total_bytes() <= m.capacity, "{} > {}", m.total_bytes(), m.capacity);
    }

    #[test]
    fn opt66b_fits_two_96gb_devices_not_one() {
        assert!(map_model(&by_name("opt-66b").unwrap(), &LpuConfig::asic_3_28tbs(), 1).is_err());
        let m = map("opt-66b", &LpuConfig::asic_3_28tbs(), 2);
        assert!(m.total_bytes() <= m.capacity);
    }

    #[test]
    fn heads_must_divide() {
        // opt-30b has 56 heads; 56 % 16 != 0.
        let e = map_model(&by_name("opt-30b").unwrap(), &LpuConfig::asic_3_28tbs(), 16);
        assert!(matches!(e, Err(CompileError::BadPartition { .. })));
    }

    #[test]
    fn padding_is_mac_tree_multiple() {
        let cfg = LpuConfig::asic_3_28tbs(); // 32 trees
        let m = map("opt-125m", &cfg, 4);
        assert_eq!(m.ffn_local % cfg.mac_trees, 0);
        assert_eq!(m.vocab_local % cfg.mac_trees, 0);
        assert!(m.vocab_local >= 50272 / 4);
    }

    #[test]
    fn lookup_regions_exist() {
        let m = map("opt-tiny", &LpuConfig::asic_819gbs(), 1);
        for name in ["embed.token", "embed.pos", "layer0.qkv", "layer3.fc2", "lm_head", "final_ln", "layer0.kcache"] {
            assert!(m.get(name).is_some(), "missing region {name}");
        }
        assert!(m.get("layer4.qkv").is_none());
    }

    #[test]
    fn headwise_tiling_recorded() {
        let m = map("opt-1.3b", &LpuConfig::asic_3_28tbs(), 2);
        match m.get("layer0.qkv").unwrap().tiling {
            Tiling::HeadWise { head_dim, heads } => {
                assert_eq!(head_dim, 64);
                assert_eq!(heads, 16);
            }
            t => panic!("expected head-wise tiling, got {t:?}"),
        }
    }

    #[test]
    fn prop_partitions_always_disjoint_and_within_capacity() {
        let models = ["opt-125m", "opt-350m", "opt-1.3b", "opt-tiny", "opt-mini", "llama-7b"];
        quick("mapper-disjoint", |rng| {
            let name = models[rng.range(0, models.len())];
            let n = 1usize << rng.range(0, 4); // 1,2,4,8
            let cfg = if rng.bool(0.5) { LpuConfig::asic_3_28tbs() } else { LpuConfig::fpga_u55c() };
            match map_model(&by_name(name).unwrap(), &cfg, n) {
                Ok(m) => m.validate(256).map_err(|e| format!("{name}/{n}: {e}")),
                Err(CompileError::BadPartition { .. }) | Err(CompileError::OutOfMemory { .. }) => Ok(()),
                Err(e) => Err(format!("{name}/{n}: unexpected {e}")),
            }
        });
    }
}
