//! HyperDex register allocator.
//!
//! "Register allocator of the compiler tracks the lifetime of all
//! variables and automatically allocates and releases the hardware
//! registers at the compiler level." Linear-scan over the virtual-
//! register program: a physical LMU register is allocated at a virtual's
//! definition and released after its last use. Exceeding the 64 physical
//! registers is a compile error (the LPU has no spill path — the
//! instruction generator keeps lifetimes short by construction).

use super::instgen::{VInstr, VProgram};
use crate::isa::{Instr, Program, NUM_VREGS};
use std::collections::HashMap;

/// Patch the template instruction's register fields.
fn patch(op: Instr, r1: Option<u8>, r2: Option<u8>, w: Option<u8>) -> Instr {
    use Instr::*;
    match op {
        ReadEmbedding { addr, len, .. } => ReadEmbedding { addr, dst: w.unwrap(), len },
        ReadHost { addr, len, .. } => ReadHost { addr, dst: w.unwrap(), len },
        WriteHost { addr, len, .. } => WriteHost { src: r1.unwrap(), addr, len },
        MatMul { k, n, accum, to_net, from_lmu, .. } => MatMul {
            src: r1.unwrap(),
            dst: w.unwrap(),
            k,
            n,
            accum,
            to_net,
            from_lmu,
        },
        VecCompute { op, len, .. } => VecCompute {
            op,
            a: r1.unwrap(),
            b: r2.unwrap(),
            dst: w.unwrap(),
            len,
        },
        VecFused { op, len, .. } => VecFused {
            op,
            a: r1.unwrap(),
            b: r2.unwrap(),
            dst: w.unwrap(),
            len,
        },
        Sample { len, .. } => Sample { src: r1.unwrap(), dst: w.unwrap(), len },
        Transmit { len, hops, .. } => Transmit { src: r1.unwrap(), len, hops },
        Receive { len, hops, .. } => Receive { dst: w.unwrap(), len, hops },
        other => other,
    }
}

/// Allocate physical registers. Returns the program and the peak number
/// of simultaneously-live physical registers.
pub fn allocate(v: &VProgram) -> Result<(Program, usize), String> {
    // Last index at which each virtual is referenced.
    let mut last_use: HashMap<u32, usize> = HashMap::new();
    for (i, vi) in v.instrs.iter().enumerate() {
        for r in vi.reads.iter().flatten() {
            last_use.insert(*r, i);
        }
        if let Some(w) = vi.write {
            last_use.insert(w, i);
        }
    }

    let mut free: Vec<u8> = (0..NUM_VREGS).rev().collect();
    let mut assign: HashMap<u32, u8> = HashMap::new();
    let mut peak = 0usize;
    let mut out = Vec::with_capacity(v.instrs.len());

    for (i, vi) in v.instrs.iter().enumerate() {
        let VInstr { op, reads, write, .. } = vi;
        let lookup = |assign: &HashMap<u32, u8>, r: &Option<u32>| -> Result<Option<u8>, String> {
            match r {
                None => Ok(None),
                Some(vr) => assign
                    .get(vr)
                    .copied()
                    .map(Some)
                    .ok_or_else(|| format!("instr {i}: use of undefined virtual v{vr}")),
            }
        };
        let r1 = lookup(&assign, &reads[0])?;
        let r2 = lookup(&assign, &reads[1])?;

        // Free registers whose last use is this instruction's reads
        // *before* allocating the destination, so a dying source's
        // register can be reused by the destination (in-place ops).
        for vr in reads.iter().flatten() {
            if last_use.get(vr) == Some(&i) {
                if let Some(p) = assign.remove(vr) {
                    free.push(p);
                }
            }
        }

        let w = match write {
            None => None,
            Some(vw) => {
                let p = match assign.get(vw) {
                    Some(&p) => p,
                    None => {
                        let p = free
                            .pop()
                            .ok_or_else(|| format!("instr {i}: out of physical registers (64)"))?;
                        assign.insert(*vw, p);
                        p
                    }
                };
                // Dead write (result never read): release immediately after.
                if last_use.get(vw) == Some(&i) {
                    assign.remove(vw);
                    free.push(p);
                }
                Some(p)
            }
        };
        peak = peak.max(NUM_VREGS as usize - free.len());
        out.push(patch(*op, r1, r2, w));
    }
    Ok((Program::new(out), peak))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::VecOp;

    fn vi(op: Instr, reads: [Option<u32>; 2], write: Option<u32>) -> VInstr {
        VInstr { op, reads, write, write_is_accum: false }
    }

    fn vp(instrs: Vec<VInstr>, n: u32) -> VProgram {
        let mut p = VProgram::default();
        p.instrs = instrs;
        // Simulate counter state.
        for _ in 0..n {
            // next_virtual is private; reconstruct by using instgen? Use
            // the fact that n_virtuals only feeds stats — no effect here.
        }
        p
    }

    fn vec_op(a: u32, b: u32, w: u32) -> VInstr {
        vi(
            Instr::VecCompute { op: VecOp::Add, a: 0, b: 0, dst: 0, len: 8 },
            [Some(a), Some(b)],
            Some(w),
        )
    }

    #[test]
    fn simple_chain_allocates_and_reuses() {
        // v0 = read; v1 = f(v0, v0); v2 = f(v1, v1); write v2
        let prog = vp(
            vec![
                vi(Instr::ReadHost { addr: 0, dst: 0, len: 1 }, [None, None], Some(0)),
                vec_op(0, 0, 1),
                vec_op(1, 1, 2),
                vi(Instr::WriteHost { src: 0, addr: 0, len: 1 }, [Some(2), None], None),
                vi(Instr::Halt, [None, None], None),
            ],
            3,
        );
        let (p, peak) = allocate(&prog).unwrap();
        assert_eq!(p.len(), 5);
        // Lifetimes are disjoint-ish: peak must be small.
        assert!(peak <= 2, "peak {peak}");
        // Dying source's register reused by destination.
        if let Instr::VecCompute { a, dst, .. } = p.instrs[1] {
            assert_eq!(a, dst, "in-place reuse expected");
        } else {
            panic!("wrong instr");
        }
    }

    #[test]
    fn use_before_def_rejected() {
        let prog = vp(vec![vec_op(42, 42, 0)], 1);
        let e = allocate(&prog).unwrap_err();
        assert!(e.contains("undefined virtual"), "{e}");
    }

    #[test]
    fn out_of_registers_rejected() {
        // 65 simultaneously-live virtuals: all defined, then all read.
        let mut instrs = Vec::new();
        for i in 0..65u32 {
            instrs.push(vi(Instr::ReadHost { addr: 0, dst: 0, len: 1 }, [None, None], Some(i)));
        }
        for i in 0..65u32 {
            instrs.push(vi(Instr::WriteHost { src: 0, addr: 0, len: 1 }, [Some(i), None], None));
        }
        let e = allocate(&vp(instrs, 65)).unwrap_err();
        assert!(e.contains("out of physical registers"), "{e}");
    }

    #[test]
    fn sixty_four_live_is_fine() {
        let mut instrs = Vec::new();
        for i in 0..64u32 {
            instrs.push(vi(Instr::ReadHost { addr: 0, dst: 0, len: 1 }, [None, None], Some(i)));
        }
        for i in 0..64u32 {
            instrs.push(vi(Instr::WriteHost { src: 0, addr: 0, len: 1 }, [Some(i), None], None));
        }
        let (_, peak) = allocate(&vp(instrs, 64)).unwrap();
        assert_eq!(peak, 64);
    }

    #[test]
    fn dead_write_released_immediately() {
        // v0 defined, never read; then 64 more virtuals must still fit.
        let mut instrs =
            vec![vi(Instr::ReadHost { addr: 0, dst: 0, len: 1 }, [None, None], Some(999))];
        for i in 0..64u32 {
            instrs.push(vi(Instr::ReadHost { addr: 0, dst: 0, len: 1 }, [None, None], Some(i)));
        }
        for i in 0..64u32 {
            instrs.push(vi(Instr::WriteHost { src: 0, addr: 0, len: 1 }, [Some(i), None], None));
        }
        assert!(allocate(&vp(instrs, 65)).is_ok());
    }

    #[test]
    fn mem_only_instrs_untouched() {
        let prog = vp(
            vec![
                vi(Instr::ReadParams { addr: 0x40, len: 99 }, [None, None], None),
                vi(Instr::Halt, [None, None], None),
            ],
            0,
        );
        let (p, peak) = allocate(&prog).unwrap();
        assert_eq!(p.instrs[0], Instr::ReadParams { addr: 0x40, len: 99 });
        assert_eq!(peak, 0);
    }
}
