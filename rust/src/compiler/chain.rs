//! Instruction-chaining verification and statistics.
//!
//! "Instruction chaining strategically divides the operations into a
//! series of dependent instructions that can be executed back-to-back
//! without any control overhead ... separates instructions utilizing
//! independent hardware modules into distinct groups (e.g., MEM, COMP,
//! NET, CTRL) of instruction chains [and] interleaves them so that the
//! execution of each instruction can be overlapped."
//!
//! This pass verifies the invariants that make chained execution safe —
//! primarily the SMA *stream discipline* (every stream-consuming MatMul
//! has exactly one pending `read.params`/`read.kv`, in order, and no
//! stream is left dangling at `halt`) and NET balance — and reports chain
//! statistics (group interleave factor, chain lengths), which the
//! `perf_hotpath` ablation bench consumes.

use crate::isa::{Category, Instr, Program};

/// Chain statistics per category.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChainReport {
    /// Instruction count per category [MEM, COMP, NET, CTRL].
    pub counts: [usize; 4],
    /// Number of maximal single-category runs (chains).
    pub chains: usize,
    /// Longest chain length.
    pub longest_chain: usize,
    /// Interleave factor: chains / categories-present (≥1; higher means
    /// the compiler alternates groups more finely, i.e. more overlap).
    pub interleave: f64,
    /// Peak simultaneously-outstanding SMA streams.
    pub peak_streams: usize,
}

fn cat_idx(c: Category) -> usize {
    match c {
        Category::Mem => 0,
        Category::Comp => 1,
        Category::Net => 2,
        Category::Ctrl => 3,
    }
}

/// Verify chaining/stream invariants; returns statistics.
///
/// Invariants:
/// 1. every non-`from_lmu` MatMul pops exactly one pending stream;
/// 2. no pending stream remains at `halt`;
/// 3. Transmit and Receive counts balance (ring symmetry);
/// 4. the program ends with `halt`.
pub fn verify_chains(p: &Program) -> Result<ChainReport, String> {
    let mut pending_streams: usize = 0;
    let mut peak_streams = 0usize;
    let mut tx = 0usize;
    let mut rx = 0usize;
    let mut counts = [0usize; 4];
    let mut chains = 0usize;
    let mut longest = 0usize;
    let mut run_len = 0usize;
    let mut last_cat: Option<Category> = None;

    if !matches!(p.instrs.last(), Some(Instr::Halt)) {
        return Err("program does not end with halt".into());
    }

    for (i, instr) in p.instrs.iter().enumerate() {
        let cat = instr.category();
        counts[cat_idx(cat)] += 1;
        if last_cat == Some(cat) {
            run_len += 1;
        } else {
            chains += 1;
            run_len = 1;
            last_cat = Some(cat);
        }
        longest = longest.max(run_len);

        match instr {
            Instr::ReadParams { .. } | Instr::ReadKv { .. } => {
                pending_streams += 1;
                peak_streams = peak_streams.max(pending_streams);
            }
            Instr::MatMul { from_lmu: false, .. } => {
                if pending_streams == 0 {
                    return Err(format!(
                        "instr {i}: stream-consuming matmul with no pending SMA stream"
                    ));
                }
                pending_streams -= 1;
            }
            Instr::Transmit { .. } => tx += 1,
            Instr::Receive { .. } => rx += 1,
            _ => {}
        }
    }

    if pending_streams != 0 {
        return Err(format!("{pending_streams} SMA stream(s) never consumed"));
    }
    if tx != rx {
        return Err(format!("unbalanced NET ops: {tx} transmits vs {rx} receives"));
    }

    let present = counts.iter().filter(|&&c| c > 0).count().max(1);
    Ok(ChainReport {
        counts,
        chains,
        longest_chain: longest,
        interleave: chains as f64 / present as f64,
        peak_streams,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    #[test]
    fn accepts_disciplined_program() {
        let p = assemble(
            r#"
            read.params 0x0, len=4096
            matmul v0 -> v1, k=64, n=64
            read.kv 0x100, len=640
            matmul v1 -> v2, k=64, n=10
            halt
        "#,
        )
        .unwrap();
        let r = verify_chains(&p).unwrap();
        assert_eq!(r.counts[0], 2);
        assert_eq!(r.counts[1], 2);
        assert_eq!(r.peak_streams, 1);
        assert!(r.chains >= 4);
    }

    #[test]
    fn rejects_matmul_without_stream() {
        let p = assemble("matmul v0 -> v1, k=64, n=64\nhalt").unwrap();
        let e = verify_chains(&p).unwrap_err();
        assert!(e.contains("no pending SMA stream"), "{e}");
    }

    #[test]
    fn lmu_matmul_needs_no_stream() {
        let p = assemble("matmul v0 -> v1, k=64, n=64, lmu\nhalt").unwrap();
        assert!(verify_chains(&p).is_ok());
    }

    #[test]
    fn rejects_dangling_stream() {
        let p = assemble("read.params 0x0, len=64\nhalt").unwrap();
        let e = verify_chains(&p).unwrap_err();
        assert!(e.contains("never consumed"), "{e}");
    }

    #[test]
    fn rejects_unbalanced_net() {
        let p = assemble("transmit v0, len=8, hops=1\nhalt").unwrap();
        let e = verify_chains(&p).unwrap_err();
        assert!(e.contains("unbalanced NET"), "{e}");
    }

    #[test]
    fn rejects_missing_halt() {
        let p = assemble("scalar.mov s0, s0, 1").unwrap();
        assert!(verify_chains(&p).is_err());
    }

    #[test]
    fn chain_stats_count_runs() {
        // [MEM MEM][COMP COMP][MEM][COMP][CTRL] = 5 chains
        let p = assemble(
            r#"
            read.params 0x0, len=64
            read.params 0x0, len=64
            matmul v0 -> v1, k=64, n=64
            matmul v1 -> v2, k=64, n=64
            read.params 0x0, len=64
            matmul v2 -> v3, k=64, n=64
            halt
        "#,
        )
        .unwrap();
        let r = verify_chains(&p).unwrap();
        assert_eq!(r.chains, 5);
        assert_eq!(r.longest_chain, 2);
        assert_eq!(r.peak_streams, 2);
    }
}
