//! The HyperDex compilation layer.
//!
//! "The compilation layer ... performs memory mapping, instruction
//! generation, and compilation to generate binary program for the LPU
//! hardware." Pipeline:
//!
//! 1. [`mapper`] — analyzes the model and the system setup (device count,
//!    network topology, HBM channel/burst geometry) and lays every
//!    parameter tensor out in HBM: head-wise tiles for attention weights,
//!    column-wise tiles for FFN weights, intra-layer (tensor) model
//!    parallelism across devices, padding to tile boundaries.
//! 2. [`instgen`] — walks the model's decode-step operation list and
//!    emits instruction blocks (`token_embed`, `decoder`, `lmhead`,
//!    `sync`, ...) over *virtual* vector registers.
//! 3. [`regalloc`] — lifetime-based register allocation onto the 64
//!    physical LMU vector registers ("tracks the lifetime of all
//!    variables and automatically allocates and releases the hardware
//!    registers").
//! 4. [`chain`] — instruction-chaining verification & statistics: checks
//!    the MEM/COMP/NET stream discipline that lets chains from distinct
//!    groups execute back-to-back with no control overhead.
//!
//! The output is a [`crate::isa::Program`] binary plus the memory map —
//! exactly what the runtime loads onto a device.

pub mod chain;
pub mod instgen;
pub mod mapper;
pub mod regalloc;

use crate::config::LpuConfig;
use crate::isa::Program;
use crate::model::ModelConfig;

pub use chain::{verify_chains, ChainReport};
pub use instgen::{InstGen, VProgram};
pub use mapper::{MemoryMap, Region, Tiling};

/// Parameter-parallel execution modes (paper §Conclusion future work —
/// implemented here as first-class compiler modes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelMode {
    /// One token of one request per pass (the paper's main mode).
    Single,
    /// Batch mode: `batch` different requests share each weight stream.
    Batch { batch: usize },
    /// Multi-token mode: `tokens` consecutive tokens of one request
    /// (summarization/prefill speedup) share each weight stream.
    MultiToken { tokens: usize },
}

impl ParallelMode {
    /// Number of activation replicas sharing one weight stream.
    pub fn replicas(&self) -> usize {
        match *self {
            ParallelMode::Single => 1,
            ParallelMode::Batch { batch } => batch,
            ParallelMode::MultiToken { tokens } => tokens,
        }
    }
}

/// Compilation options.
#[derive(Clone, Debug)]
pub struct CompileOpts {
    /// Tensor-parallel device count (ESL ring size).
    pub n_devices: usize,
    /// Context length before this decode step (KV entries already cached).
    pub position: usize,
    /// Emit the ESL overlapped dataflow (MatMul `to_net` + eager
    /// transmit). `false` reproduces the blocking, GPU-like sync of
    /// Fig 4(a) top.
    pub esl_overlap: bool,
    /// Parallel mode (Single / Batch / MultiToken).
    pub mode: ParallelMode,
    /// Number of SXE/VXE engine sets (≥2 enables full-rate batch mode).
    pub sxe_sets: usize,
}

impl Default for CompileOpts {
    fn default() -> Self {
        CompileOpts {
            n_devices: 1,
            position: 0,
            esl_overlap: true,
            mode: ParallelMode::Single,
            sxe_sets: 1,
        }
    }
}

/// Compile error.
#[derive(Debug)]
pub enum CompileError {
    BadPartition { devices: usize, reason: String },
    OutOfMemory { need: u64, have: u64, devices: usize },
    RegAlloc(String),
    Encode(crate::isa::IsaError),
    BadOpts(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::BadPartition { devices, reason } => {
                write!(f, "model does not partition over {devices} devices: {reason}")
            }
            CompileError::OutOfMemory { need, have, devices } => write!(
                f,
                "model ({need} B with KV) exceeds capacity of {devices} device(s) ({have} B)"
            ),
            CompileError::RegAlloc(msg) => write!(f, "register allocation failed: {msg}"),
            CompileError::Encode(e) => write!(f, "instruction encoding failed: {e}"),
            CompileError::BadOpts(msg) => write!(f, "invalid options: {msg}"),
        }
    }
}

impl std::error::Error for CompileError {}

impl From<crate::isa::IsaError> for CompileError {
    fn from(e: crate::isa::IsaError) -> CompileError {
        CompileError::Encode(e)
    }
}

/// A fully compiled decode-step program.
#[derive(Clone, Debug)]
pub struct Compiled {
    pub program: Program,
    pub map: MemoryMap,
    /// Compiler statistics (virtual register count, chain report, ...).
    pub stats: CompileStats,
}

#[derive(Clone, Debug, Default)]
pub struct CompileStats {
    pub virtual_regs: usize,
    pub peak_live_regs: usize,
    pub instrs: usize,
    pub chain: ChainReport,
}

/// Compile one decode step for device 0 of an `opts.n_devices` ring
/// (tensor-parallel shards are symmetric, so one device's program is the
/// timing-representative one).
pub fn compile(
    model: &ModelConfig,
    cfg: &LpuConfig,
    opts: &CompileOpts,
) -> Result<Compiled, CompileError> {
    if opts.n_devices == 0 || !opts.n_devices.is_power_of_two() {
        return Err(CompileError::BadOpts(format!(
            "n_devices must be a power of two (ESL ring reconfiguration), got {}",
            opts.n_devices
        )));
    }
    if opts.mode.replicas() == 0 {
        return Err(CompileError::BadOpts("mode with zero replicas".into()));
    }
    if opts.sxe_sets == 0 {
        return Err(CompileError::BadOpts("sxe_sets must be >= 1".into()));
    }
    let map = mapper::map_model(model, cfg, opts.n_devices)?;
    let vprog = instgen::generate(model, cfg, &map, opts);
    let virtual_regs = vprog.n_virtuals();
    let (program, peak_live) =
        regalloc::allocate(&vprog).map_err(CompileError::RegAlloc)?;
    // Validate encodability of every instruction (the binary ABI).
    for i in &program.instrs {
        i.encode()?;
    }
    let chain = chain::verify_chains(&program).map_err(CompileError::BadOpts)?;
    Ok(Compiled {
        stats: CompileStats {
            virtual_regs,
            peak_live_regs: peak_live,
            instrs: program.len(),
            chain,
        },
        program,
        map,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;

    #[test]
    fn compile_opt_tiny_single_device() {
        let m = by_name("opt-tiny").unwrap();
        let c = compile(&m, &LpuConfig::asic_819gbs(), &CompileOpts::default()).unwrap();
        assert!(c.program.len() > 20);
        assert!(c.stats.peak_live_regs <= 64);
        assert!(matches!(c.program.instrs.last(), Some(crate::isa::Instr::Halt)));
    }

    #[test]
    fn compile_rejects_non_power_of_two_devices() {
        let m = by_name("opt-tiny").unwrap();
        let opts = CompileOpts { n_devices: 3, ..Default::default() };
        assert!(matches!(
            compile(&m, &LpuConfig::asic_3_28tbs(), &opts),
            Err(CompileError::BadOpts(_))
        ));
    }

    #[test]
    fn compile_rejects_oversized_model() {
        let m = by_name("opt-66b").unwrap();
        // One 24 GB device cannot hold 132 GB of weights.
        let opts = CompileOpts { n_devices: 1, ..Default::default() };
        assert!(matches!(
            compile(&m, &LpuConfig::asic_819gbs(), &opts),
            Err(CompileError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn compile_66b_on_two_devices() {
        let m = by_name("opt-66b").unwrap();
        let opts = CompileOpts { n_devices: 2, position: 100, ..Default::default() };
        let c = compile(&m, &LpuConfig::asic_3_28tbs(), &opts).unwrap();
        // Must contain NET instructions (tensor-parallel sync).
        let h = c.program.category_histogram();
        assert!(h[2].1 > 0, "expected NET instructions: {h:?}");
    }

    #[test]
    fn batch_mode_emits_replica_matmuls() {
        let m = by_name("opt-tiny").unwrap();
        let single = compile(&m, &LpuConfig::asic_819gbs(), &CompileOpts::default()).unwrap();
        let batched = compile(
            &m,
            &LpuConfig::asic_819gbs(),
            &CompileOpts { mode: ParallelMode::Batch { batch: 4 }, ..Default::default() },
        )
        .unwrap();
        assert!(batched.program.len() > single.program.len() * 2);
    }
}
