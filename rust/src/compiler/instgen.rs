//! HyperDex Instruction Generator.
//!
//! Converts a model architecture into LPU instruction blocks
//! (`input_load`, `token_embed`, `decoder`, `lmhead`, `sync`,
//! `output_store`, `hlt` — the blocks of Fig 5(b)) over *virtual* vector
//! registers, which [`super::regalloc`] later maps onto the 64 physical
//! LMU registers.
//!
//! Stream discipline: every weight/KV MatMul is immediately preceded by
//! the `read.params`/`read.kv` that feeds it, one stream per consuming
//! MatMul (`from_lmu` MatMuls consume no stream). Norm/bias parameters
//! (γ/β) are folded into the adjacent weight stream — the SMA reads them
//! in the same burst train and routes them to the VXE.
//!
//! Parallel modes (paper future work, first-class here): in
//! `Batch`/`MultiToken` mode, `replicas` activation sets share each
//! weight stream. With `sxe_sets = S` engine sets, `ceil(R/S)` timing
//! passes are emitted per weight op (the weight stream is read once);
//! attention and KV traffic remain per-replica since each replica has
//! its own context.

use super::mapper::MemoryMap;
use super::{CompileOpts, ParallelMode};
use crate::config::LpuConfig;
use crate::isa::{FusedOp, Instr, VecOp};
use crate::model::{Family, ModelConfig};

/// A virtual-register instruction.
#[derive(Clone, Copy, Debug)]
pub struct VInstr {
    /// Template with register fields zeroed.
    pub op: Instr,
    /// Virtual registers read (slot order matches the variant's fields).
    pub reads: [Option<u32>; 2],
    /// Virtual register written.
    pub write: Option<u32>,
    /// Write also reads its previous value (MatMul accumulate).
    pub write_is_accum: bool,
}

/// Instruction list over virtual registers.
#[derive(Clone, Debug, Default)]
pub struct VProgram {
    pub instrs: Vec<VInstr>,
    next_virtual: u32,
}

impl VProgram {
    pub fn n_virtuals(&self) -> usize {
        self.next_virtual as usize
    }
}

/// Generator state.
pub struct InstGen<'a> {
    #[allow(dead_code)] // kept for future family-specific emission rules
    model: &'a ModelConfig,
    #[allow(dead_code)] // tile sizes come via the map today
    cfg: &'a LpuConfig,
    map: &'a MemoryMap,
    opts: &'a CompileOpts,
    v: VProgram,
}

impl<'a> InstGen<'a> {
    fn vr(&mut self) -> u32 {
        let r = self.v.next_virtual;
        self.v.next_virtual += 1;
        r
    }

    fn push(&mut self, op: Instr, reads: [Option<u32>; 2], write: Option<u32>, accum: bool) {
        self.v.instrs.push(VInstr { op, reads, write, write_is_accum: accum });
    }

    // ---- emission helpers ----

    fn read_params(&mut self, addr: u64, elems: u64) {
        debug_assert!(elems < u32::MAX as u64, "region too large for one stream: {elems}");
        self.push(Instr::ReadParams { addr, len: elems as u32 }, [None, None], None, false);
    }

    fn read_kv(&mut self, addr: u64, elems: u64) {
        self.push(Instr::ReadKv { addr, len: elems as u32 }, [None, None], None, false);
    }

    fn write_kv(&mut self, addr: u64, elems: u64) {
        self.push(Instr::WriteKv { addr, len: elems as u32 }, [None, None], None, false);
    }

    fn read_embedding(&mut self, addr: u64, elems: u64) -> u32 {
        let dst = self.vr();
        self.push(Instr::ReadEmbedding { addr, dst: 0, len: elems as u32 }, [None, None], Some(dst), false);
        dst
    }

    fn matmul(&mut self, src: u32, k: usize, n: usize, to_net: bool, from_lmu: bool) -> u32 {
        let dst = self.vr();
        self.push(
            Instr::MatMul { src: 0, dst: 0, k: k as u32, n: n as u32, accum: false, to_net, from_lmu },
            [Some(src), None],
            Some(dst),
            false,
        );
        dst
    }

    fn vec(&mut self, op: VecOp, a: u32, b: u32, len: usize) -> u32 {
        let dst = self.vr();
        self.push(
            Instr::VecCompute { op, a: 0, b: 0, dst: 0, len: len as u32 },
            [Some(a), Some(b)],
            Some(dst),
            false,
        );
        dst
    }

    fn fused(&mut self, op: FusedOp, a: u32, b: u32, len: usize) -> u32 {
        let dst = self.vr();
        self.push(
            Instr::VecFused { op, a: 0, b: 0, dst: 0, len: len as u32 },
            [Some(a), Some(b)],
            Some(dst),
            false,
        );
        dst
    }

    fn transmit(&mut self, src: u32, elems: usize, hops: u8) {
        self.push(Instr::Transmit { src: 0, len: elems as u32, hops }, [Some(src), None], None, false);
    }

    fn receive(&mut self, elems: usize, hops: u8) -> u32 {
        let dst = self.vr();
        self.push(Instr::Receive { dst: 0, len: elems as u32, hops }, [None, None], Some(dst), false);
        dst
    }

    /// Synchronize a `d`-element partial-sum vector across the
    /// tensor-parallel group (the `sync` block).
    ///
    /// With `esl_overlap` (Fig 4(a)): the producing MatMul routed its
    /// partial products to the TX buffer as column tasks completed, so
    /// chunks circulate the ring *while* the MatMul computes; the ESL
    /// dataflow arbitrates between chunks received from peers and
    /// written back from the local SXE, accumulating in flight. Emitted
    /// as one transmit/receive pair over `n-1` hops — the visible cost
    /// collapses to the tail chunk's traversal.
    ///
    /// Without overlap (the GPU-like ablation): an explicit blocking
    /// ring all-reduce — 2(n-1) chunk steps, each gated on the previous
    /// step's VXE accumulation.
    fn sync_allreduce(&mut self, mut partial: u32, d: usize) -> u32 {
        let n = self.opts.n_devices;
        if n == 1 {
            return partial;
        }
        if self.opts.esl_overlap {
            let vol = (d * (n - 1) / n).max(1);
            self.transmit(partial, vol, (n - 1) as u8);
            return self.receive(vol, (n - 1) as u8);
        }
        let chunk = d.div_ceil(n);
        // Reduce-scatter.
        for _ in 0..n - 1 {
            self.transmit(partial, chunk, 1);
            let rx = self.receive(chunk, 1);
            partial = self.vec(VecOp::Add, partial, rx, chunk);
        }
        // All-gather.
        for _ in 0..n - 1 {
            self.transmit(partial, chunk, 1);
            let rx = self.receive(chunk, 1);
            partial = self.vec(VecOp::Add, partial, rx, chunk);
        }
        partial
    }

    /// One weight-streamed matmul shared across replicas: stream read
    /// once, `ceil(replicas / sxe_sets)` timing passes. Returns one dst
    /// per replica (replicas within a pass share the pass's register).
    fn shared_matmul(
        &mut self,
        srcs: &[u32],
        addr: u64,
        stream_elems: u64,
        k: usize,
        n: usize,
        to_net: bool,
    ) -> Vec<u32> {
        let replicas = srcs.len();
        let sets = self.opts.sxe_sets;
        let passes = replicas.div_ceil(sets);
        self.read_params(addr, stream_elems);
        let mut dsts = Vec::with_capacity(replicas);
        let mut pass_dsts = Vec::with_capacity(passes);
        for p in 0..passes {
            let src = srcs[p * sets];
            let dst = self.matmul(src, k, n, to_net, p > 0);
            pass_dsts.push(dst);
        }
        for r in 0..replicas {
            dsts.push(pass_dsts[r / sets]);
        }
        dsts
    }
}

/// Generate the decode-step program (device 0's shard of an
/// `opts.n_devices` ring).
pub fn generate(
    model: &ModelConfig,
    cfg: &LpuConfig,
    map: &MemoryMap,
    opts: &CompileOpts,
) -> VProgram {
    let mut g = InstGen { model, cfg, map, opts, v: VProgram::default() };
    let d = model.d_model;
    let hd = model.head_dim();
    let heads_local = map.heads_local;
    let d_local = heads_local * hd;
    let replicas = opts.mode.replicas();
    let llama = matches!(model.family, Family::Llama);
    let net = opts.n_devices > 1 && opts.esl_overlap;

    // Context length for replica r at this step.
    let ctx = |r: usize| -> usize {
        match opts.mode {
            ParallelMode::MultiToken { .. } => opts.position + r + 1,
            _ => opts.position + 1,
        }
    };

    // ---- input_load + token_embed ----
    let mut xs: Vec<u32> = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let tok = {
            let dst = g.vr();
            g.push(Instr::ReadHost { addr: 0, dst: 0, len: 1 }, [None, None], Some(dst), false);
            dst
        };
        let tok_addr = g.map.get("embed.token").expect("embed.token mapped").addr;
        let emb = g.read_embedding(tok_addr, d as u64);
        let x = if llama {
            // RoPE models have no positional table; combine with token reg
            // to keep the data dependency on the host input.
            g.vec(VecOp::Embed, emb, tok, d)
        } else {
            let pos_addr = g.map.get("embed.pos").expect("embed.pos mapped").addr;
            let pos = g.read_embedding(pos_addr, d as u64);
            let e = g.vec(VecOp::Embed, emb, pos, d);
            // Keep the host-token dependency explicit.
            g.vec(VecOp::Add, e, tok, d)
        };
        xs.push(x);
    }

    // ---- decoder layers ----
    for l in 0..model.n_layers {
        let grab = |g: &InstGen, name: String| {
            let r = g.map.get(&name).unwrap();
            (r.addr, r.elems())
        };
        let (qkv_addr, qkv_w_elems) = grab(&g, format!("layer{l}.qkv"));
        let (kc_addr, _) = grab(&g, format!("layer{l}.kcache"));
        let (vc_addr, _) = grab(&g, format!("layer{l}.vcache"));
        let (ao_addr, ao_elems) = grab(&g, format!("layer{l}.attn_out"));
        let (fc1_addr, fc1_elems) = grab(&g, format!("layer{l}.fc1"));
        let (fc2_addr, fc2_elems) = grab(&g, format!("layer{l}.fc2"));
        let (_, ln1_elems) = grab(&g, format!("layer{l}.ln1"));
        let (_, ln2_elems) = grab(&g, format!("layer{l}.ln2"));

        // LN1 (γβ folded into the QKV stream).
        let hs: Vec<u32> = xs
            .iter()
            .map(|&x| {
                if llama {
                    g.vec(VecOp::RmsNorm, x, x, d)
                } else {
                    g.vec(VecOp::LayerNorm, x, x, d)
                }
            })
            .collect();

        // QKV projection, head-partitioned (column-parallel).
        let qkv_elems = qkv_w_elems + ln1_elems;
        let qkvs = g.shared_matmul(&hs, qkv_addr, qkv_elems, d, map.qkv_local, false);

        let mut head_outs: Vec<u32> = Vec::with_capacity(replicas);
        for (r, &qkv) in qkvs.iter().enumerate() {
            let ctx_len = ctx(r);
            let qkv = if llama { g.vec(VecOp::Rope, qkv, qkv, 2 * d_local) } else { qkv };
            // Append this token's K,V (strobe-transposed on write).
            g.write_kv(kc_addr + (ctx_len as u64 - 1) * d_local as u64 * 2, d_local as u64);
            g.write_kv(vc_addr + (ctx_len as u64 - 1) * d_local as u64 * 2, d_local as u64);

            // Per-head attention (Fig 3(b) dataflow).
            let mut head_out = qkv;
            for h in 0..heads_local {
                let k_addr = kc_addr + (h * hd * model.max_seq) as u64 * 2;
                g.read_kv(k_addr, (ctx_len * hd) as u64);
                let score = g.matmul(qkv, hd, ctx_len, false, false);
                let prob = g.fused(FusedOp::ScaleSoftmax, score, score, ctx_len);
                let v_addr = vc_addr + (h * hd * model.max_seq) as u64 * 2;
                g.read_kv(v_addr, (ctx_len * hd) as u64);
                head_out = g.matmul(prob, ctx_len, hd, false, false);
            }

            head_outs.push(head_out);
        }

        // Output projection (row-parallel, weight stream shared across
        // replicas) + sync + residual.
        let partials = g.shared_matmul(&head_outs, ao_addr, ao_elems, d_local, d, net);
        for (r, &partial) in partials.iter().enumerate() {
            let attn = g.sync_allreduce(partial, d);
            xs[r] = g.vec(VecOp::Add, attn, xs[r], d);
        }

        // LN2 + FFN.
        let h2s: Vec<u32> = xs
            .iter()
            .map(|&x| {
                if llama {
                    g.vec(VecOp::RmsNorm, x, x, d)
                } else {
                    g.vec(VecOp::LayerNorm, x, x, d)
                }
            })
            .collect();

        let fc1_cols = if llama { 2 * map.ffn_local } else { map.ffn_local };
        let f1 = g.shared_matmul(&h2s, fc1_addr, fc1_elems + ln2_elems, d, fc1_cols, false);
        let acts: Vec<u32> = f1
            .iter()
            .map(|&v| {
                if llama {
                    g.fused(FusedOp::MulSilu, v, v, map.ffn_local)
                } else {
                    match model.family {
                        Family::Gpt => g.vec(VecOp::Gelu, v, v, map.ffn_local),
                        _ => g.vec(VecOp::Relu, v, v, map.ffn_local),
                    }
                }
            })
            .collect();
        let f2 = g.shared_matmul(&acts, fc2_addr, fc2_elems, map.ffn_local, d, net);
        for (r, &o) in f2.iter().enumerate() {
            let summed = g.sync_allreduce(o, d);
            xs[r] = g.vec(VecOp::Add, summed, xs[r], d);
        }
    }

    // ---- lmhead + sample + output_store ----
    let fln_elems = g.map.get("final_ln").unwrap().elems();
    let (lmh_addr, lmh_elems) = {
        let r = g.map.get("lm_head").unwrap();
        (r.addr, r.elems())
    };
    let finals: Vec<u32> = xs
        .iter()
        .map(|&x| {
            if llama {
                g.vec(VecOp::RmsNorm, x, x, d)
            } else {
                g.vec(VecOp::LayerNorm, x, x, d)
            }
        })
        .collect();
    let logit_shards = g.shared_matmul(&finals, lmh_addr, lmh_elems + fln_elems, d, map.vocab_local, false);
    for &shard in &logit_shards {
        // Gather vocabulary shards to the sampling device: each ring
        // step forwards a shard (transmit) and takes one in (receive).
        let mut logits = shard;
        if opts.n_devices > 1 {
            for _ in 0..opts.n_devices - 1 {
                g.transmit(logits, map.vocab_local, 1);
                let rx = g.receive(map.vocab_local, 1);
                // Concatenation modeled as a cheap vector op touch.
                logits = g.vec(VecOp::Add, logits, rx, 1);
            }
        }
        let token = {
            let dst = g.vr();
            g.push(
                Instr::Sample { src: 0, dst: 0, len: (map.vocab_local * opts.n_devices) as u32 },
                [Some(logits), None],
                Some(dst),
                false,
            );
            dst
        };
        g.push(Instr::WriteHost { src: 0, addr: 0, len: 1 }, [Some(token), None], None, false);
    }
    g.push(Instr::Halt, [None, None], None, false);
    g.v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapper::map_model;
    use crate::model::by_name;

    fn gen(model: &str, n_devices: usize, pos: usize) -> VProgram {
        let m = by_name(model).unwrap();
        let cfg = LpuConfig::asic_3_28tbs();
        let map = map_model(&m, &cfg, n_devices).unwrap();
        let opts = CompileOpts { n_devices, position: pos, ..Default::default() };
        generate(&m, &cfg, &map, &opts)
    }

    fn weight_stream_elems(v: &VProgram) -> u64 {
        v.instrs
            .iter()
            .filter_map(|vi| match vi.op {
                Instr::ReadParams { len, .. } | Instr::ReadEmbedding { len, .. } => Some(len as u64),
                _ => None,
            })
            .sum()
    }

    #[test]
    fn stream_discipline_one_stream_per_matmul() {
        let v = gen("opt-tiny", 1, 5);
        let streams = v
            .instrs
            .iter()
            .filter(|vi| matches!(vi.op, Instr::ReadParams { .. } | Instr::ReadKv { .. }))
            .count();
        let consumers = v
            .instrs
            .iter()
            .filter(|vi| matches!(vi.op, Instr::MatMul { from_lmu: false, .. }))
            .count();
        assert_eq!(streams, consumers);
    }

    #[test]
    fn weight_bytes_match_model_accounting() {
        for name in ["opt-tiny", "opt-125m", "opt-1.3b"] {
            let m = by_name(name).unwrap();
            let v = gen(name, 1, 0);
            let streamed = weight_stream_elems(&v) * 2;
            let expect = m.decode_stream_bytes();
            let rel = (streamed as f64 - expect as f64).abs() / expect as f64;
            assert!(rel < 0.03, "{name}: streamed {streamed} vs {expect} (rel {rel:.4})");
        }
    }

    #[test]
    fn kv_traffic_scales_with_position() {
        let kv = |pos: usize| -> u64 {
            gen("opt-tiny", 1, pos)
                .instrs
                .iter()
                .filter_map(|vi| match vi.op {
                    Instr::ReadKv { len, .. } => Some(len as u64),
                    _ => None,
                })
                .sum()
        };
        let k10 = kv(9);
        let k100 = kv(99);
        assert_eq!(k100 / k10, 10);
    }

    #[test]
    fn multi_device_emits_balanced_net_ops() {
        let v = gen("opt-1.3b", 4, 10);
        let tx = v.instrs.iter().filter(|vi| matches!(vi.op, Instr::Transmit { .. })).count();
        let rx = v.instrs.iter().filter(|vi| matches!(vi.op, Instr::Receive { .. })).count();
        assert_eq!(tx, rx);
        // Overlapped syncs: one tx/rx pair per all-reduce (2/layer)
        // + (n-1) logit gathers.
        assert_eq!(tx, 24 * 2 + 3);
    }

    #[test]
    fn single_device_has_no_net_ops() {
        let v = gen("opt-1.3b", 1, 10);
        assert!(!v.instrs.iter().any(|vi| matches!(vi.op, Instr::Transmit { .. } | Instr::Receive { .. })));
    }

    #[test]
    fn batch_mode_reads_weights_once() {
        let m = by_name("opt-tiny").unwrap();
        let cfg = LpuConfig::asic_819gbs();
        let map = map_model(&m, &cfg, 1).unwrap();
        let single = generate(&m, &cfg, &map, &CompileOpts::default());
        let batch4 = generate(
            &m,
            &cfg,
            &map,
            &CompileOpts { mode: ParallelMode::Batch { batch: 4 }, ..Default::default() },
        );
        // Weight streams identical (embedding rows are per-token and
        // legitimately replicate; compare read.params only).
        let params_only = |v: &VProgram| -> u64 {
            v.instrs
                .iter()
                .filter_map(|vi| match vi.op {
                    Instr::ReadParams { len, .. } => Some(len as u64),
                    _ => None,
                })
                .sum()
        };
        assert_eq!(params_only(&single), params_only(&batch4));
        let kv = |v: &VProgram| {
            v.instrs
                .iter()
                .filter(|vi| matches!(vi.op, Instr::ReadKv { .. }))
                .count()
        };
        assert_eq!(kv(&batch4), 4 * kv(&single));
    }

    #[test]
    fn sxe_sets_reduce_timing_passes() {
        let m = by_name("opt-tiny").unwrap();
        let cfg = LpuConfig::asic_819gbs();
        let map = map_model(&m, &cfg, 1).unwrap();
        let b4s1 = generate(
            &m,
            &cfg,
            &map,
            &CompileOpts { mode: ParallelMode::Batch { batch: 4 }, sxe_sets: 1, ..Default::default() },
        );
        let b4s4 = generate(
            &m,
            &cfg,
            &map,
            &CompileOpts { mode: ParallelMode::Batch { batch: 4 }, sxe_sets: 4, ..Default::default() },
        );
        let mm = |v: &VProgram| v.instrs.iter().filter(|vi| matches!(vi.op, Instr::MatMul { .. })).count();
        assert!(mm(&b4s4) < mm(&b4s1));
    }

    #[test]
    fn rope_emitted_for_llama_only() {
        // llama-7b fits one 96GB device.
        let v = gen("llama-7b", 1, 0);
        assert!(v.instrs.iter().any(|vi| matches!(vi.op, Instr::VecCompute { op: VecOp::Rope, .. })));
        let v2 = gen("opt-tiny", 1, 0);
        assert!(!v2.instrs.iter().any(|vi| matches!(vi.op, Instr::VecCompute { op: VecOp::Rope, .. })));
    }

    #[test]
    fn ends_with_halt_and_host_writeback() {
        let v = gen("opt-tiny", 1, 3);
        assert!(matches!(v.instrs.last().unwrap().op, Instr::Halt));
        assert!(v.instrs.iter().any(|vi| matches!(vi.op, Instr::WriteHost { .. })));
        assert!(v.instrs.iter().any(|vi| matches!(vi.op, Instr::Sample { .. })));
    }
}
