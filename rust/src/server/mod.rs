//! Orion-style serving front end: a threaded TCP server speaking
//! newline-delimited JSON, plus a matching client library.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op":"generate","model":"opt-tiny","prompt":[1,2,3],
//!    "max_new_tokens":8,"temperature":0.7,"top_k":50,"top_p":0.9,
//!    "stream":true,"deadline_s":0.5}
//! ← {"type":"token","request_id":1,"index":0,"token":42}   (if stream)
//! ← {"type":"done","request_id":1,"tokens":[42,...],"reason":"length"}
//! → {"op":"metrics"}
//! ← {"type":"metrics", ...snapshot fields...}
//! → {"op":"trace"}
//! ← {"type":"trace","enabled":true,"timelines":[...],"digest":{...}}
//! → {"op":"models"}
//! ← {"type":"models","models":["opt-tiny"]}
//! ```
//!
//! No tokio in this offline environment: `std::net::TcpListener` with a
//! thread per connection (the LPU serves token streams, not thousands of
//! idle sockets — thread-per-conn is the right tool at this scale).
//!
//! The same protocol fronts either a single [`Coordinator`] pool
//! ([`serve`]) or an SLO-aware replica fleet ([`serve_cluster`]):
//! `deadline_s` marks a request interactive (the value is its TTFT
//! budget), and on the fleet path the cluster front-end may shed it at
//! admission with an error frame mentioning `shed`. The fleet's
//! `metrics` frame carries the per-tier counters and fault rollups
//! (`replica_crashes`, `partitions`, `streams_failed_over`,
//! `hedges_issued`, `hedges_won`) plus `replicas`, `active_replicas`,
//! a `replica_health` boolean array (false = ejected by the fault
//! plan's health state machine), and a `replica_pools` array of
//! per-replica pool gauges.
//!
//! The `trace` op drains the served tracer's flight recorder (the ring
//! of last-N completed request timelines plus a monotonic shed/failure
//! "why" digest — see [`crate::coordinator::Tracer`]). Draining
//! empties the ring; the digest keeps accumulating across drains. On
//! the fleet path the frame adds a `replica_traces` array with each
//! replica coordinator's drain (fleet-level timelines only exist when
//! the pump wrapper is active — fault plan or hedging — so per-request
//! detail usually lives in `replica_traces`).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::{
    Cluster, Coordinator, FinishReason, Request, RequestHandle, SloTier, Submitted,
    TokenEvent,
};
use crate::numerics::SampleParams;
use crate::util::json::{obj, Json};

/// A running server; dropping the handle does not stop it — call
/// [`ServerHandle::stop`].
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and join the acceptor. In-flight connections
    /// finish their current request.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so accept() returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// What the front end serves: a single coordinator pool, or an
/// SLO-aware [`Cluster`] fleet. One protocol, one connection handler —
/// only submission and the metrics frame differ.
#[derive(Clone)]
enum Served {
    Pool(Arc<Coordinator>),
    Fleet(Arc<Cluster>),
}

/// Serve `coordinator` on `addr` (use port 0 for an ephemeral port).
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<ServerHandle> {
    serve_inner(Served::Pool(coordinator), addr)
}

/// Serve a replica fleet on `addr`: same JSON-lines protocol as
/// [`serve`], but requests pass through the cluster front-end (tier
/// classification, deadline-aware admission, autoscaling) before
/// reaching a replica. Shed requests get an error frame mentioning
/// `shed` — no tokens are ever generated for them.
pub fn serve_cluster(cluster: Arc<Cluster>, addr: &str) -> std::io::Result<ServerHandle> {
    serve_inner(Served::Fleet(cluster), addr)
}

fn serve_inner(served: Served, addr: &str) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let accept_thread = std::thread::Builder::new()
        .name("lpu-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let served = served.clone();
                let _ = std::thread::Builder::new()
                    .name("lpu-conn".into())
                    .spawn(move || handle_conn(stream, served));
            }
        })?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread) })
}

fn handle_conn(stream: TcpStream, served: Served) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_err = |writer: &mut TcpStream, msg: String| {
            let j = obj(vec![("type", "error".into()), ("message", msg.into())]);
            let _ = writeln!(writer, "{j}");
        };
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                reply_err(&mut writer, format!("bad json: {e}"));
                continue;
            }
        };
        match req.get("op").as_str() {
            Some("generate") => {
                let r = match &served {
                    Served::Pool(coord) => handle_generate(&req, coord, &mut writer),
                    Served::Fleet(cluster) => {
                        handle_generate_cluster(&req, cluster, &mut writer)
                    }
                };
                if let Err(e) = r {
                    reply_err(&mut writer, e);
                }
            }
            Some("metrics") => {
                let mut j = match &served {
                    Served::Pool(coord) => coord.metrics.snapshot().to_json(),
                    Served::Fleet(cluster) => cluster.metrics.snapshot().to_json(),
                };
                if let Json::Obj(o) = &mut j {
                    o.insert("type", "metrics".into());
                    match &served {
                        Served::Pool(coord) => {
                            // Latency tails are policy-dependent; tag the
                            // frame so sweeps can label per-policy results.
                            o.insert("policy", coord.policy().name().into());
                            // Per-pool prefill/prefix gauges: which model's
                            // prompts are long, chunked, or cache-friendly.
                            o.insert("pools", coord.pools_json());
                            if coord.tracer.enabled() {
                                // Latency attribution rollup over traced
                                // completions (tracing on only).
                                o.insert(
                                    "attribution",
                                    coord.tracer.attribution_summary().to_json(),
                                );
                            }
                        }
                        Served::Fleet(cluster) => {
                            // Fleet shape + per-replica pool gauges: the
                            // tier counters live on the cluster snapshot,
                            // the serving gauges on each replica.
                            o.insert("replicas", cluster.replica_count().into());
                            o.insert("active_replicas", cluster.active_replicas().into());
                            o.insert(
                                "replica_health",
                                Json::Arr(
                                    cluster
                                        .replica_health()
                                        .into_iter()
                                        .map(Json::from)
                                        .collect(),
                                ),
                            );
                            o.insert(
                                "replica_pools",
                                Json::Arr(
                                    cluster
                                        .replicas()
                                        .iter()
                                        .map(|c| c.pools_json())
                                        .collect(),
                                ),
                            );
                            if cluster.tracer.enabled() {
                                o.insert(
                                    "attribution",
                                    cluster.tracer.attribution_summary().to_json(),
                                );
                            }
                        }
                    }
                }
                let _ = writeln!(writer, "{j}");
            }
            Some("trace") => {
                let mut j = match &served {
                    Served::Pool(coord) => coord.tracer.drain_json(),
                    Served::Fleet(cluster) => cluster.tracer.drain_json(),
                };
                if let Json::Obj(o) = &mut j {
                    o.insert("type", "trace".into());
                    if let Served::Fleet(cluster) = &served {
                        o.insert(
                            "replica_traces",
                            Json::Arr(
                                cluster
                                    .replicas()
                                    .iter()
                                    .map(|c| c.tracer.drain_json())
                                    .collect(),
                            ),
                        );
                    }
                }
                let _ = writeln!(writer, "{j}");
            }
            Some("models") => {
                let models: Vec<Json> = match &served {
                    Served::Pool(coord) => coord.models(),
                    Served::Fleet(cluster) => cluster.replicas()[0].models(),
                }
                .into_iter()
                .map(Json::from)
                .collect();
                let j = obj(vec![("type", "models".into()), ("models", models.into())]);
                let _ = writeln!(writer, "{j}");
            }
            Some("ping") => {
                let _ = writeln!(writer, "{}", obj(vec![("type", "pong".into())]));
            }
            other => {
                reply_err(&mut writer, format!("unknown op {other:?} from {peer:?}"));
            }
        }
    }
}

/// Parse a `generate` op into a [`Request`] plus its `stream` flag.
/// Shared verbatim by the pool and fleet paths — one wire grammar.
fn parse_generate(req: &Json) -> Result<(Request, bool), String> {
    let model = req.get("model").as_str().ok_or("missing 'model'")?.to_string();
    let prompt: Vec<i64> = req
        .get("prompt")
        .as_arr()
        .ok_or("missing 'prompt'")?
        .iter()
        .map(|v| v.as_f64().map(|f| f as i64).ok_or("prompt tokens must be numbers"))
        .collect::<Result<_, _>>()?;
    let max_new_tokens = req.get("max_new_tokens").as_usize().unwrap_or(16);
    let stream_tokens = req.get("stream").as_bool().unwrap_or(false);
    let temperature = req.get("temperature").as_f64();
    let params = match temperature {
        None => SampleParams::greedy(),
        Some(t) => SampleParams::sampled(
            t as f32,
            req.get("top_k").as_usize().unwrap_or(0),
            req.get("top_p").as_f64().unwrap_or(1.0) as f32,
        ),
    };
    let request = Request {
        model,
        prompt,
        max_new_tokens,
        params,
        eos_token: req.get("eos_token").as_f64().map(|f| f as i64),
        seed: req.get("seed").as_u64().unwrap_or(0),
        deadline_s: req.get("deadline_s").as_f64(),
    };
    Ok((request, stream_tokens))
}

/// Drain one request's event stream onto the wire (token frames if
/// streaming, then the done frame). Returns the wall-clock TTFT
/// (None if the stream finished without a token event).
fn pump_stream(
    handle: RequestHandle,
    stream_tokens: bool,
    writer: &mut TcpStream,
) -> Result<Option<f64>, String> {
    let submitted = Instant::now();
    let mut ttft = None;
    for ev in handle.events.iter() {
        match ev {
            TokenEvent::Token { request_id, index, token } => {
                if index == 0 {
                    ttft = Some(submitted.elapsed().as_secs_f64());
                }
                if stream_tokens {
                    let j = obj(vec![
                        ("type", "token".into()),
                        ("request_id", request_id.into()),
                        ("index", index.into()),
                        ("token", (token as f64).into()),
                    ]);
                    writeln!(writer, "{j}").map_err(|e| e.to_string())?;
                }
            }
            TokenEvent::Done { request_id, tokens, reason } => {
                let j = obj(vec![
                    ("type", "done".into()),
                    ("request_id", request_id.into()),
                    (
                        "tokens",
                        Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                    ),
                    (
                        "reason",
                        match reason {
                            FinishReason::Length => "length",
                            FinishReason::Eos => "eos",
                        }
                        .into(),
                    ),
                ]);
                writeln!(writer, "{j}").map_err(|e| e.to_string())?;
                return Ok(ttft);
            }
            TokenEvent::Error { message, .. } => return Err(message),
        }
    }
    Err("stream ended unexpectedly".into())
}

fn handle_generate(
    req: &Json,
    coord: &Coordinator,
    writer: &mut TcpStream,
) -> Result<(), String> {
    let (request, stream_tokens) = parse_generate(req)?;
    let handle = coord.submit(request)?;
    pump_stream(handle, stream_tokens, writer).map(|_| ())
}

fn handle_generate_cluster(
    req: &Json,
    cluster: &Cluster,
    writer: &mut TcpStream,
) -> Result<(), String> {
    let (request, stream_tokens) = parse_generate(req)?;
    let deadline = request.deadline_s;
    match cluster.submit(request)? {
        Submitted::Shed { tier } => Err(format!(
            "shed: {} admission over TTFT budget",
            tier.name()
        )),
        Submitted::Handle { tier, handle, .. } => {
            let ttft = pump_stream(handle, stream_tokens, writer)?;
            // An interactive stream attains its SLO when the first
            // token beat the deadline budget; batch always attains.
            let attained = match (tier, deadline, ttft) {
                (SloTier::Interactive, Some(d), Some(t)) => t <= d,
                _ => true,
            };
            cluster.note_done(tier, attained);
            Ok(())
        }
    }
}

/// Minimal blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Result of a generate call.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateResult {
    pub tokens: Vec<i64>,
    pub reason: String,
    /// Tokens observed via streaming events (empty if stream=false).
    pub streamed: Vec<i64>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn roundtrip(&mut self, req: &Json) -> Result<Json, String> {
        writeln!(self.writer, "{req}").map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        Json::parse(&line).map_err(|e| e.to_string())
    }

    pub fn ping(&mut self) -> Result<(), String> {
        let r = self.roundtrip(&obj(vec![("op", "ping".into())]))?;
        if r.get("type").as_str() == Some("pong") { Ok(()) } else { Err(format!("bad pong: {r}")) }
    }

    pub fn models(&mut self) -> Result<Vec<String>, String> {
        let r = self.roundtrip(&obj(vec![("op", "models".into())]))?;
        Ok(r.get("models")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|m| m.as_str().map(String::from))
            .collect())
    }

    pub fn metrics(&mut self) -> Result<Json, String> {
        self.roundtrip(&obj(vec![("op", "metrics".into())]))
    }

    /// Drain the server's flight recorder: the last-N completed request
    /// timelines plus the monotonic shed/failure digest.
    pub fn trace(&mut self) -> Result<Json, String> {
        self.roundtrip(&obj(vec![("op", "trace".into())]))
    }

    pub fn generate(
        &mut self,
        model: &str,
        prompt: &[i64],
        max_new_tokens: usize,
        stream: bool,
    ) -> Result<GenerateResult, String> {
        let req = obj(vec![
            ("op", "generate".into()),
            ("model", model.into()),
            ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("max_new_tokens", max_new_tokens.into()),
            ("stream", stream.into()),
        ]);
        writeln!(self.writer, "{req}").map_err(|e| e.to_string())?;
        let mut streamed = Vec::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
            if line.is_empty() {
                return Err("connection closed".into());
            }
            let j = Json::parse(&line).map_err(|e| e.to_string())?;
            match j.get("type").as_str() {
                Some("token") => {
                    streamed.push(j.get("token").as_f64().unwrap_or(-1.0) as i64);
                }
                Some("done") => {
                    return Ok(GenerateResult {
                        tokens: j
                            .get("tokens")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|t| t.as_f64().map(|f| f as i64))
                            .collect(),
                        reason: j.get("reason").as_str().unwrap_or("?").to_string(),
                        streamed,
                    });
                }
                Some("error") => {
                    return Err(j.get("message").as_str().unwrap_or("unknown").to_string())
                }
                other => return Err(format!("unexpected frame type {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendFactory, CoordinatorConfig, SchedulerPolicy};

    fn test_server() -> (ServerHandle, SocketAddr) {
        let mut coord = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        coord.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 128));
        let h = serve(Arc::new(coord), "127.0.0.1:0").unwrap();
        let addr = h.addr;
        (h, addr)
    }

    #[test]
    fn ping_and_models() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        c.ping().unwrap();
        assert_eq!(c.models().unwrap(), vec!["opt-tiny".to_string()]);
        h.stop();
    }

    #[test]
    fn generate_blocking_and_streaming_agree() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        let blocking = c.generate("opt-tiny", &[1, 2], 6, false).unwrap();
        assert_eq!(blocking.tokens.len(), 6);
        assert!(blocking.streamed.is_empty());
        let streaming = c.generate("opt-tiny", &[1, 2], 6, true).unwrap();
        assert_eq!(streaming.streamed, streaming.tokens);
        // Deterministic greedy backend: same completion both times.
        assert_eq!(blocking.tokens, streaming.tokens);
        h.stop();
    }

    #[test]
    fn concurrent_clients() {
        let (h, addr) = test_server();
        let threads: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    c.generate("opt-tiny", &[i + 1], 5, false).unwrap().tokens.len()
                })
            })
            .collect();
        for t in threads {
            assert_eq!(t.join().unwrap(), 5);
        }
        let mut c = Client::connect(&addr).unwrap();
        let m = c.metrics().unwrap();
        assert_eq!(m.get("completed").as_u64(), Some(6));
        // Policy tag + latency tails ride along for per-policy sweeps.
        assert_eq!(m.get("policy").as_str(), Some("round_robin"));
        assert!(m.get("ttft_p99_s").as_f64().unwrap() >= m.get("ttft_p50_s").as_f64().unwrap());
        assert!(m.get("tpot_p95_s").as_f64().is_some());
        // Per-pool gauges: each single-token prompt ran as one
        // single-pass prefill span in the opt-tiny pool.
        let pool = m.get("pools").get("opt-tiny");
        assert_eq!(pool.get("prefill_spans").as_u64(), Some(6));
        assert_eq!(pool.get("prefill_tokens").as_u64(), Some(6));
        assert_eq!(pool.get("prefix_hit_tokens").as_u64(), Some(0));
        assert_eq!(pool.get("shared_blocks").as_u64(), Some(0));
        assert_eq!(pool.get("cow_splits").as_u64(), Some(0));
        // Routing-balance gauges: everything has drained, so queues are
        // empty and the per-worker frames are present for both workers.
        assert_eq!(pool.get("queue_depth").as_u64(), Some(0));
        let workers = pool.get("workers").as_arr().expect("workers array");
        assert_eq!(workers.len(), 2);
        for w in workers {
            assert_eq!(w.get("queue_depth").as_u64(), Some(0));
            assert!(w.get("active_lanes").as_u64().is_some());
        }
        h.stop();
    }

    #[test]
    fn bad_requests_get_error_frames() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        let e = c.generate("no-such-model", &[1], 3, false).unwrap_err();
        assert!(e.contains("unknown model"), "{e}");
        // Malformed JSON line.
        writeln!(c.writer, "this is not json").unwrap();
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
        h.stop();
    }

    #[test]
    fn unknown_op_rejected() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        let r = c.roundtrip(&obj(vec![("op", "frobnicate".into())])).unwrap();
        assert_eq!(r.get("type").as_str(), Some("error"));
        h.stop();
    }

    use crate::coordinator::{ClusterConfig, StepModel, VirtualConfig};

    /// A 2-replica fleet whose front-end cost model prices every
    /// request at ~1000 virtual seconds: after `capacity` admissions
    /// the projected delay dwarfs any realistic TTFT budget, so shed
    /// decisions are deterministic on the wall clock (the live sim
    /// pools still answer instantly).
    fn test_cluster_server(capacity: usize) -> (ServerHandle, SocketAddr) {
        let step = StepModel {
            weight_stream_s: 1000.0,
            kv_read_s_per_pos: 0.0,
            lane_overhead_s: 0.0,
            sync_s: 0.0,
            host_restore_s_per_token: 0.0,
        };
        let pool = VirtualConfig::new(SchedulerPolicy::RoundRobin, 1, 4, step);
        let cc = ClusterConfig::new(capacity.max(1), pool);
        let cluster = Cluster::threaded(&cc, "opt-tiny", || {
            let mut coord = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                ..CoordinatorConfig::default()
            });
            coord.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 128));
            coord
        })
        .unwrap();
        let h = serve_cluster(Arc::new(cluster), "127.0.0.1:0").unwrap();
        let addr = h.addr;
        (h, addr)
    }

    #[test]
    fn cluster_server_generates_and_reports_fleet_metrics() {
        let (h, addr) = test_cluster_server(2);
        let mut c = Client::connect(&addr).unwrap();
        c.ping().unwrap();
        assert_eq!(c.models().unwrap(), vec!["opt-tiny".to_string()]);
        // Batch request (no deadline): admitted despite the huge
        // priced backlog — batch is never shed.
        let r = c.generate("opt-tiny", &[3, 4], 5, true).unwrap();
        assert_eq!(r.tokens.len(), 5);
        assert_eq!(r.streamed, r.tokens);
        let m = c.metrics().unwrap();
        assert_eq!(m.get("replicas").as_u64(), Some(2));
        assert_eq!(m.get("active_replicas").as_u64(), Some(2));
        let health = m.get("replica_health").as_arr().expect("replica_health");
        assert_eq!(health.len(), 2);
        assert!(health.iter().all(|h| h.as_bool() == Some(true)));
        assert_eq!(m.get("replica_crashes").as_u64(), Some(0));
        assert_eq!(m.get("hedges_issued").as_u64(), Some(0));
        assert_eq!(m.get("tier_batch_submitted").as_u64(), Some(1));
        assert_eq!(m.get("tier_batch_done").as_u64(), Some(1));
        assert_eq!(m.get("tier_interactive_submitted").as_u64(), Some(0));
        let pools = m.get("replica_pools").as_arr().expect("replica_pools");
        assert_eq!(pools.len(), 2);
        assert!(pools[0].get("opt-tiny").get("queue_depth").as_u64().is_some());
        h.stop();
    }

    #[test]
    fn cluster_server_sheds_interactive_over_budget() {
        let (h, addr) = test_cluster_server(1);
        let mut c = Client::connect(&addr).unwrap();
        let send = |c: &mut Client, deadline: f64| {
            let req = obj(vec![
                ("op", "generate".into()),
                ("model", "opt-tiny".into()),
                ("prompt", Json::Arr(vec![Json::Num(1.0)])),
                ("max_new_tokens", 3usize.into()),
                ("deadline_s", deadline.into()),
            ]);
            writeln!(c.writer, "{req}").unwrap();
        };
        // First interactive request: empty horizon, delay 0 <= budget,
        // admitted and served.
        send(&mut c, 5.0);
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        let done = Json::parse(&line).unwrap();
        assert_eq!(done.get("type").as_str(), Some("done"));
        // Second: the single replica's horizon now sits ~1000 priced
        // seconds out; a 5 s budget cannot fit — shed, before any token.
        send(&mut c, 5.0);
        line.clear();
        c.reader.read_line(&mut line).unwrap();
        let err = Json::parse(&line).unwrap();
        assert_eq!(err.get("type").as_str(), Some("error"));
        let msg = err.get("message").as_str().unwrap_or("");
        assert!(msg.contains("shed") && msg.contains("interactive"), "{msg}");
        let m = c.metrics().unwrap();
        assert_eq!(m.get("tier_interactive_submitted").as_u64(), Some(2));
        assert_eq!(m.get("tier_interactive_shed").as_u64(), Some(1));
        assert_eq!(m.get("tier_interactive_done").as_u64(), Some(1));
        assert_eq!(m.get("tier_interactive_attained").as_u64(), Some(1));
        h.stop();
    }
}
