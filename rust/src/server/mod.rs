//! Orion-style serving front end: a threaded TCP server speaking
//! newline-delimited JSON, plus a matching client library.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op":"generate","model":"opt-tiny","prompt":[1,2,3],
//!    "max_new_tokens":8,"temperature":0.7,"top_k":50,"top_p":0.9,
//!    "stream":true}
//! ← {"type":"token","request_id":1,"index":0,"token":42}   (if stream)
//! ← {"type":"done","request_id":1,"tokens":[42,...],"reason":"length"}
//! → {"op":"metrics"}
//! ← {"type":"metrics", ...snapshot fields...}
//! → {"op":"models"}
//! ← {"type":"models","models":["opt-tiny"]}
//! ```
//!
//! No tokio in this offline environment: `std::net::TcpListener` with a
//! thread per connection (the LPU serves token streams, not thousands of
//! idle sockets — thread-per-conn is the right tool at this scale).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::{Coordinator, FinishReason, Request, TokenEvent};
use crate::numerics::SampleParams;
use crate::util::json::{obj, Json};

/// A running server; dropping the handle does not stop it — call
/// [`ServerHandle::stop`].
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and join the acceptor. In-flight connections
    /// finish their current request.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Poke the listener so accept() returns.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve `coordinator` on `addr` (use port 0 for an ephemeral port).
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let accept_thread = std::thread::Builder::new()
        .name("lpu-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let coord = Arc::clone(&coordinator);
                let _ = std::thread::Builder::new()
                    .name("lpu-conn".into())
                    .spawn(move || handle_conn(stream, coord));
            }
        })?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread) })
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_err = |writer: &mut TcpStream, msg: String| {
            let j = obj(vec![("type", "error".into()), ("message", msg.into())]);
            let _ = writeln!(writer, "{j}");
        };
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                reply_err(&mut writer, format!("bad json: {e}"));
                continue;
            }
        };
        match req.get("op").as_str() {
            Some("generate") => {
                if let Err(e) = handle_generate(&req, &coord, &mut writer) {
                    reply_err(&mut writer, e);
                }
            }
            Some("metrics") => {
                let mut j = coord.metrics.snapshot().to_json();
                if let Json::Obj(o) = &mut j {
                    o.insert("type", "metrics".into());
                    // Latency tails are policy-dependent; tag the frame
                    // so sweeps can label per-policy results.
                    o.insert("policy", coord.policy().name().into());
                    // Per-pool prefill/prefix gauges: which model's
                    // prompts are long, chunked, or cache-friendly.
                    o.insert("pools", coord.pools_json());
                }
                let _ = writeln!(writer, "{j}");
            }
            Some("models") => {
                let models: Vec<Json> =
                    coord.models().into_iter().map(Json::from).collect();
                let j = obj(vec![("type", "models".into()), ("models", models.into())]);
                let _ = writeln!(writer, "{j}");
            }
            Some("ping") => {
                let _ = writeln!(writer, "{}", obj(vec![("type", "pong".into())]));
            }
            other => {
                reply_err(&mut writer, format!("unknown op {other:?} from {peer:?}"));
            }
        }
    }
}

fn handle_generate(
    req: &Json,
    coord: &Coordinator,
    writer: &mut TcpStream,
) -> Result<(), String> {
    let model = req.get("model").as_str().ok_or("missing 'model'")?.to_string();
    let prompt: Vec<i64> = req
        .get("prompt")
        .as_arr()
        .ok_or("missing 'prompt'")?
        .iter()
        .map(|v| v.as_f64().map(|f| f as i64).ok_or("prompt tokens must be numbers"))
        .collect::<Result<_, _>>()?;
    let max_new_tokens = req.get("max_new_tokens").as_usize().unwrap_or(16);
    let stream_tokens = req.get("stream").as_bool().unwrap_or(false);
    let temperature = req.get("temperature").as_f64();
    let params = match temperature {
        None => SampleParams::greedy(),
        Some(t) => SampleParams::sampled(
            t as f32,
            req.get("top_k").as_usize().unwrap_or(0),
            req.get("top_p").as_f64().unwrap_or(1.0) as f32,
        ),
    };
    let request = Request {
        model,
        prompt,
        max_new_tokens,
        params,
        eos_token: req.get("eos_token").as_f64().map(|f| f as i64),
        seed: req.get("seed").as_u64().unwrap_or(0),
    };
    let handle = coord.submit(request)?;
    for ev in handle.events.iter() {
        match ev {
            TokenEvent::Token { request_id, index, token } => {
                if stream_tokens {
                    let j = obj(vec![
                        ("type", "token".into()),
                        ("request_id", request_id.into()),
                        ("index", index.into()),
                        ("token", (token as f64).into()),
                    ]);
                    writeln!(writer, "{j}").map_err(|e| e.to_string())?;
                }
            }
            TokenEvent::Done { request_id, tokens, reason } => {
                let j = obj(vec![
                    ("type", "done".into()),
                    ("request_id", request_id.into()),
                    (
                        "tokens",
                        Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                    ),
                    (
                        "reason",
                        match reason {
                            FinishReason::Length => "length",
                            FinishReason::Eos => "eos",
                        }
                        .into(),
                    ),
                ]);
                writeln!(writer, "{j}").map_err(|e| e.to_string())?;
                return Ok(());
            }
            TokenEvent::Error { message, .. } => return Err(message),
        }
    }
    Err("stream ended unexpectedly".into())
}

/// Minimal blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// Result of a generate call.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateResult {
    pub tokens: Vec<i64>,
    pub reason: String,
    /// Tokens observed via streaming events (empty if stream=false).
    pub streamed: Vec<i64>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn roundtrip(&mut self, req: &Json) -> Result<Json, String> {
        writeln!(self.writer, "{req}").map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        Json::parse(&line).map_err(|e| e.to_string())
    }

    pub fn ping(&mut self) -> Result<(), String> {
        let r = self.roundtrip(&obj(vec![("op", "ping".into())]))?;
        if r.get("type").as_str() == Some("pong") { Ok(()) } else { Err(format!("bad pong: {r}")) }
    }

    pub fn models(&mut self) -> Result<Vec<String>, String> {
        let r = self.roundtrip(&obj(vec![("op", "models".into())]))?;
        Ok(r.get("models")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|m| m.as_str().map(String::from))
            .collect())
    }

    pub fn metrics(&mut self) -> Result<Json, String> {
        self.roundtrip(&obj(vec![("op", "metrics".into())]))
    }

    pub fn generate(
        &mut self,
        model: &str,
        prompt: &[i64],
        max_new_tokens: usize,
        stream: bool,
    ) -> Result<GenerateResult, String> {
        let req = obj(vec![
            ("op", "generate".into()),
            ("model", model.into()),
            ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("max_new_tokens", max_new_tokens.into()),
            ("stream", stream.into()),
        ]);
        writeln!(self.writer, "{req}").map_err(|e| e.to_string())?;
        let mut streamed = Vec::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
            if line.is_empty() {
                return Err("connection closed".into());
            }
            let j = Json::parse(&line).map_err(|e| e.to_string())?;
            match j.get("type").as_str() {
                Some("token") => {
                    streamed.push(j.get("token").as_f64().unwrap_or(-1.0) as i64);
                }
                Some("done") => {
                    return Ok(GenerateResult {
                        tokens: j
                            .get("tokens")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|t| t.as_f64().map(|f| f as i64))
                            .collect(),
                        reason: j.get("reason").as_str().unwrap_or("?").to_string(),
                        streamed,
                    });
                }
                Some("error") => {
                    return Err(j.get("message").as_str().unwrap_or("unknown").to_string())
                }
                other => return Err(format!("unexpected frame type {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendFactory, CoordinatorConfig, SchedulerPolicy};

    fn test_server() -> (ServerHandle, SocketAddr) {
        let mut coord = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        coord.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 128));
        let h = serve(Arc::new(coord), "127.0.0.1:0").unwrap();
        let addr = h.addr;
        (h, addr)
    }

    #[test]
    fn ping_and_models() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        c.ping().unwrap();
        assert_eq!(c.models().unwrap(), vec!["opt-tiny".to_string()]);
        h.stop();
    }

    #[test]
    fn generate_blocking_and_streaming_agree() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        let blocking = c.generate("opt-tiny", &[1, 2], 6, false).unwrap();
        assert_eq!(blocking.tokens.len(), 6);
        assert!(blocking.streamed.is_empty());
        let streaming = c.generate("opt-tiny", &[1, 2], 6, true).unwrap();
        assert_eq!(streaming.streamed, streaming.tokens);
        // Deterministic greedy backend: same completion both times.
        assert_eq!(blocking.tokens, streaming.tokens);
        h.stop();
    }

    #[test]
    fn concurrent_clients() {
        let (h, addr) = test_server();
        let threads: Vec<_> = (0..6)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    c.generate("opt-tiny", &[i + 1], 5, false).unwrap().tokens.len()
                })
            })
            .collect();
        for t in threads {
            assert_eq!(t.join().unwrap(), 5);
        }
        let mut c = Client::connect(&addr).unwrap();
        let m = c.metrics().unwrap();
        assert_eq!(m.get("completed").as_u64(), Some(6));
        // Policy tag + latency tails ride along for per-policy sweeps.
        assert_eq!(m.get("policy").as_str(), Some("round_robin"));
        assert!(m.get("ttft_p99_s").as_f64().unwrap() >= m.get("ttft_p50_s").as_f64().unwrap());
        assert!(m.get("tpot_p95_s").as_f64().is_some());
        // Per-pool gauges: each single-token prompt ran as one
        // single-pass prefill span in the opt-tiny pool.
        let pool = m.get("pools").get("opt-tiny");
        assert_eq!(pool.get("prefill_spans").as_u64(), Some(6));
        assert_eq!(pool.get("prefill_tokens").as_u64(), Some(6));
        assert_eq!(pool.get("prefix_hit_tokens").as_u64(), Some(0));
        assert_eq!(pool.get("shared_blocks").as_u64(), Some(0));
        assert_eq!(pool.get("cow_splits").as_u64(), Some(0));
        // Routing-balance gauges: everything has drained, so queues are
        // empty and the per-worker frames are present for both workers.
        assert_eq!(pool.get("queue_depth").as_u64(), Some(0));
        let workers = pool.get("workers").as_arr().expect("workers array");
        assert_eq!(workers.len(), 2);
        for w in workers {
            assert_eq!(w.get("queue_depth").as_u64(), Some(0));
            assert!(w.get("active_lanes").as_u64().is_some());
        }
        h.stop();
    }

    #[test]
    fn bad_requests_get_error_frames() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        let e = c.generate("no-such-model", &[1], 3, false).unwrap_err();
        assert!(e.contains("unknown model"), "{e}");
        // Malformed JSON line.
        writeln!(c.writer, "this is not json").unwrap();
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad json"));
        h.stop();
    }

    #[test]
    fn unknown_op_rejected() {
        let (h, addr) = test_server();
        let mut c = Client::connect(&addr).unwrap();
        let r = c.roundtrip(&obj(vec![("op", "frobnicate".into())])).unwrap();
        assert_eq!(r.get("type").as_str(), Some("error"));
        h.stop();
    }
}
