//! Cluster tier: an SLO-aware replica fleet above the worker pool.
//!
//! The paper's pitch is not one LPU but a *scalable* fleet; the roadmap
//! north star is "millions of users". This module adds the layer that
//! turns N independent pools into one deployment:
//!
//! * **SLO tiers** ([`SloTier`]): a request with a deadline
//!   ([`Request::deadline_s`]) is *interactive* — its deadline doubles
//!   as the TTFT budget the front-end admits against; a request without
//!   one is *batch* — throughput-only, never shed.
//! * **Deadline-aware admission with load shedding**: the front-end
//!   keeps a fluid work horizon per replica (estimated seconds of
//!   accepted-but-unserved work, priced by the same [`StepModel`] terms
//!   the pools charge) and sheds an interactive arrival when every
//!   routable replica's projected queue delay exceeds its TTFT budget.
//!   Shedding happens strictly *before* the first token — an admitted
//!   stream is never dropped mid-flight.
//! * **Step-driven autoscaling** ([`AutoscaleConfig`]): on a fixed
//!   evaluation grid the controller compares per-replica backlog
//!   seconds against up/down thresholds and activates or drains
//!   replicas; a freshly activated replica is only routable after a
//!   configurable warm-up, so scaling is never free.
//! * **Arrival traces** ([`ArrivalTrace`]): diurnal and flash-crowd
//!   intensity modulation over the Poisson base rate, so SLO-attainment
//!   curves can be swept against realistic load shapes
//!   (`benches/cluster_slo.rs` → `BENCH_cluster.json`).
//!
//! Per the standing constraint, the fleet logic runs on BOTH serving
//! paths without forking: the per-arrival decision core ([`FrontEnd`])
//! is one struct, driven on virtual seconds by [`run_virtual_cluster`]
//! (each replica is a full, unmodified
//! [`run_virtual_plan`][super::workload::run_virtual_plan] pool) and on
//! wall seconds by the threaded [`Cluster`] dispatcher (each replica a
//! live [`Coordinator`]). Greedy token streams are a pure function of
//! (model, prompt) in the sim backend, so completed streams are
//! bit-identical per seed regardless of tier, replica count, or
//! placement — asserted by `tests/invariants.rs` through the shared
//! invariant harness.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::backend::StepModel;
use super::metrics::Metrics;
use super::workload::{
    run_virtual_plan, LenDist, VirtualConfig, VirtualReport, Workload,
};
use super::{Coordinator, Request, RequestHandle, TokenEvent};

/// SLO class of a request. Classification is structural: carrying a
/// deadline makes a request interactive (the deadline is its TTFT
/// budget); no deadline means batch (throughput-only, never shed).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SloTier {
    /// TTFT-bounded: admitted only when the projected queue delay fits
    /// the request's deadline budget; shed otherwise.
    Interactive,
    /// Throughput-only: always admitted (modulo pool-level KV
    /// rejection), never shed by the front-end.
    Batch,
}

impl SloTier {
    /// Classify a request by the presence of a deadline.
    pub fn classify(req: &Request) -> SloTier {
        if req.deadline_s.is_some() {
            SloTier::Interactive
        } else {
            SloTier::Batch
        }
    }

    /// Stable lowercase name for JSON/CLI surfaces.
    pub fn name(self) -> &'static str {
        match self {
            SloTier::Interactive => "interactive",
            SloTier::Batch => "batch",
        }
    }
}

/// CLI tier mix (`--slo-tier batch|interactive:<ttft_s>|mixed:<ttft_s>:<fraction>`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SloTierSpec {
    /// Every request is batch tier (no deadlines).
    Batch,
    /// Every request is interactive with this TTFT budget, seconds.
    Interactive {
        /// TTFT budget applied as each request's deadline.
        ttft_s: f64,
    },
    /// A seeded mix: `fraction` of requests are interactive with
    /// `ttft_s` budgets, the rest batch.
    Mixed {
        /// TTFT budget for the interactive share.
        ttft_s: f64,
        /// Interactive fraction in [0, 1].
        fraction: f64,
    },
}

impl SloTierSpec {
    /// Parse the CLI grammar. Misconfiguration is refused, not ignored.
    pub fn parse(s: &str) -> Result<SloTierSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let ttft = |v: &str| -> Result<f64, String> {
            let t: f64 =
                v.parse().map_err(|_| format!("--slo-tier: bad ttft '{v}'"))?;
            if !t.is_finite() || t <= 0.0 {
                return Err(format!("--slo-tier: ttft must be > 0, got '{v}'"));
            }
            Ok(t)
        };
        match parts.as_slice() {
            ["batch"] => Ok(SloTierSpec::Batch),
            ["interactive", t] => Ok(SloTierSpec::Interactive { ttft_s: ttft(t)? }),
            ["mixed", t, f] => {
                let fraction: f64 =
                    f.parse().map_err(|_| format!("--slo-tier: bad fraction '{f}'"))?;
                if !(0.0..=1.0).contains(&fraction) {
                    return Err(format!(
                        "--slo-tier: fraction must be in [0,1], got '{f}'"
                    ));
                }
                Ok(SloTierSpec::Mixed { ttft_s: ttft(t)?, fraction })
            }
            _ => Err(format!(
                "--slo-tier: want batch | interactive:<ttft_s> | \
                 mixed:<ttft_s>:<fraction>, got '{s}'"
            )),
        }
    }

    /// The (interactive fraction, TTFT budget) pair the workload
    /// generator consumes.
    pub fn mix(self) -> (f64, f64) {
        match self {
            SloTierSpec::Batch => (0.0, 0.0),
            SloTierSpec::Interactive { ttft_s } => (1.0, ttft_s),
            SloTierSpec::Mixed { ttft_s, fraction } => (fraction, ttft_s),
        }
    }
}

/// Autoscaling policy (`--autoscale min=..,max=..,interval=..,warmup=..,up=..,down=..`).
///
/// Evaluated on a fixed grid of `interval_s` ticks: the controller's
/// gauge is mean backlog seconds per active replica (how far each fluid
/// work horizon runs ahead of now). Above `up_backlog_s` it activates
/// one more replica — routable only after `warmup_s` — and below
/// `down_backlog_s` it drains the highest-indexed active replica
/// (in-flight work finishes; it just stops receiving).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// Floor on active replicas (>= 1).
    pub min_replicas: usize,
    /// Ceiling on active replicas.
    pub max_replicas: usize,
    /// Controller evaluation period, seconds.
    pub interval_s: f64,
    /// Delay before a newly activated replica accepts traffic, seconds
    /// (weight streaming / model load — scaling is never free).
    pub warmup_s: f64,
    /// Scale up when mean backlog-seconds per active replica exceeds
    /// this.
    pub up_backlog_s: f64,
    /// Scale down when mean backlog-seconds per active replica falls
    /// below this.
    pub down_backlog_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            interval_s: 0.25,
            warmup_s: 0.5,
            up_backlog_s: 0.5,
            down_backlog_s: 0.05,
        }
    }
}

impl AutoscaleConfig {
    /// Parse `key=value` pairs over the default config. Unknown keys
    /// and inconsistent bounds are refused, not ignored.
    pub fn parse(spec: &str) -> Result<AutoscaleConfig, String> {
        let mut cfg = AutoscaleConfig::default();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("--autoscale: want key=value, got '{part}'"))?;
            let f = || -> Result<f64, String> {
                val.parse().map_err(|_| format!("--autoscale: bad value '{val}' for '{key}'"))
            };
            match key.trim() {
                "min" => {
                    cfg.min_replicas = val
                        .parse()
                        .map_err(|_| format!("--autoscale: bad value '{val}' for 'min'"))?
                }
                "max" => {
                    cfg.max_replicas = val
                        .parse()
                        .map_err(|_| format!("--autoscale: bad value '{val}' for 'max'"))?
                }
                "interval" => cfg.interval_s = f()?,
                "warmup" => cfg.warmup_s = f()?,
                "up" => cfg.up_backlog_s = f()?,
                "down" => cfg.down_backlog_s = f()?,
                other => return Err(format!("--autoscale: unknown key '{other}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.min_replicas == 0 {
            return Err("--autoscale: min must be >= 1".into());
        }
        if self.max_replicas < self.min_replicas {
            return Err("--autoscale: max must be >= min".into());
        }
        if !(self.interval_s.is_finite() && self.interval_s > 0.0) {
            return Err("--autoscale: interval must be > 0".into());
        }
        if !(self.warmup_s.is_finite() && self.warmup_s >= 0.0) {
            return Err("--autoscale: warmup must be >= 0".into());
        }
        if !(self.up_backlog_s.is_finite() && self.up_backlog_s >= 0.0)
            || !(self.down_backlog_s.is_finite() && self.down_backlog_s >= 0.0)
        {
            return Err("--autoscale: up/down must be >= 0".into());
        }
        if self.down_backlog_s > self.up_backlog_s {
            return Err("--autoscale: down threshold must not exceed up".into());
        }
        Ok(())
    }
}

/// Cluster deployment configuration: N replicas of one pool config.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Initial replica count (>= 1). With autoscaling this is clamped
    /// into `[min_replicas, max_replicas]`.
    pub replicas: usize,
    /// The per-replica pool: worker count, slots, KV policy, step model
    /// — each replica is one full pool run by the unmodified machinery.
    pub pool: VirtualConfig,
    /// SLO admission: shed interactive arrivals whose projected queue
    /// delay exceeds their TTFT budget. Batch is never shed.
    pub shed: bool,
    /// Optional autoscaling policy (None = fixed fleet).
    pub autoscale: Option<AutoscaleConfig>,
    /// Default deadline applied to requests arriving without one
    /// (`--slo-tier interactive:<ttft_s>` on the server path). None
    /// leaves untagged requests batch tier.
    pub default_deadline_s: Option<f64>,
}

impl ClusterConfig {
    /// A fixed fleet of `replicas` pools with SLO shedding enabled.
    pub fn new(replicas: usize, pool: VirtualConfig) -> ClusterConfig {
        ClusterConfig { replicas, pool, shed: true, autoscale: None, default_deadline_s: None }
    }
}

/// Arrival-intensity shape over the Poisson base rate: the generator
/// divides each exponential gap by `intensity(t)`, so an intensity of 2
/// doubles the instantaneous arrival rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalTrace {
    /// Constant intensity 1 (plain Poisson).
    Uniform,
    /// Sinusoidal day/night swing: `1 + depth * sin(2πt/period)`,
    /// floored at 0.05 so the rate never hits zero.
    Diurnal {
        /// Full day length, seconds (virtual).
        period_s: f64,
        /// Swing amplitude; 1.0 swings between ~0 and 2x.
        depth: f64,
    },
    /// A flash crowd: `magnification`x intensity inside
    /// `[at_s, at_s + dur_s)`, 1 outside.
    FlashCrowd {
        /// Burst start, seconds.
        at_s: f64,
        /// Burst duration, seconds.
        dur_s: f64,
        /// Intensity multiplier during the burst.
        magnification: f64,
    },
}

impl ArrivalTrace {
    /// Instantaneous intensity multiplier at time `t`.
    pub fn intensity(&self, t: f64) -> f64 {
        match *self {
            ArrivalTrace::Uniform => 1.0,
            ArrivalTrace::Diurnal { period_s, depth } => {
                let phase = 2.0 * std::f64::consts::PI * t / period_s.max(1e-9);
                (1.0 + depth * phase.sin()).max(0.05)
            }
            ArrivalTrace::FlashCrowd { at_s, dur_s, magnification } => {
                if t >= at_s && t < at_s + dur_s {
                    magnification.max(0.05)
                } else {
                    1.0
                }
            }
        }
    }

    /// Stable name for JSON/CLI surfaces.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalTrace::Uniform => "uniform",
            ArrivalTrace::Diurnal { .. } => "diurnal",
            ArrivalTrace::FlashCrowd { .. } => "flash_crowd",
        }
    }

    /// Parse `uniform | diurnal:<period_s>:<depth> | flash:<at_s>:<dur_s>:<mag>`.
    pub fn parse(s: &str) -> Result<ArrivalTrace, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let f = |v: &str| -> Result<f64, String> {
            let x: f64 = v.parse().map_err(|_| format!("--trace: bad number '{v}'"))?;
            if !x.is_finite() {
                return Err(format!("--trace: non-finite '{v}'"));
            }
            Ok(x)
        };
        match parts.as_slice() {
            ["uniform"] => Ok(ArrivalTrace::Uniform),
            ["diurnal", p, d] => Ok(ArrivalTrace::Diurnal { period_s: f(p)?, depth: f(d)? }),
            ["flash", at, dur, mag] => Ok(ArrivalTrace::FlashCrowd {
                at_s: f(at)?,
                dur_s: f(dur)?,
                magnification: f(mag)?,
            }),
            _ => Err(format!(
                "--trace: want uniform | diurnal:<period_s>:<depth> | \
                 flash:<at_s>:<dur_s>:<mag>, got '{s}'"
            )),
        }
    }
}

/// A tiered, trace-shaped workload: the base [`Workload`] generator
/// with arrival-intensity modulation and a seeded interactive/batch
/// split. Same seed, same plan, bit for bit.
#[derive(Clone, Debug)]
pub struct ClusterWorkload {
    /// Base rate, lengths, vocab, seed, request count.
    pub base: Workload,
    /// Arrival-intensity shape over the base Poisson rate.
    pub trace: ArrivalTrace,
    /// Fraction of requests tagged interactive (deadline-carrying).
    pub interactive_fraction: f64,
    /// TTFT budget (deadline) each interactive request carries, s.
    pub interactive_deadline_s: f64,
}

impl ClusterWorkload {
    /// Generate the request plan: `(arrival_s, request)` with
    /// non-decreasing arrivals, trace-modulated gaps, and per-request
    /// tier tags.
    pub fn generate(&self) -> Vec<(f64, Request)> {
        let mut rng = Rng::new(self.base.seed);
        let mut at = 0.0f64;
        (0..self.base.n_requests)
            .map(|i| {
                at += rng.exp(self.base.rate) / self.trace.intensity(at).max(1e-9);
                let p_len = self.base.prompt_len.sample(&mut rng);
                let o_len = self.base.output_len.sample(&mut rng).max(1);
                let prompt = (0..p_len.max(1))
                    .map(|_| rng.range(0, self.base.vocab) as i64)
                    .collect();
                let interactive = rng.bool(self.interactive_fraction);
                let req = Request {
                    model: self.base.model.clone(),
                    prompt,
                    max_new_tokens: o_len,
                    params: crate::numerics::SampleParams::greedy(),
                    eos_token: None,
                    seed: self.base.seed ^ i as u64,
                    deadline_s: if interactive {
                        Some(self.interactive_deadline_s)
                    } else {
                        None
                    },
                };
                (at, req)
            })
            .collect()
    }

    /// Check internal consistency (refused, not ignored).
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.interactive_fraction) {
            return Err("cluster workload: interactive fraction must be in [0,1]".into());
        }
        if self.interactive_fraction > 0.0
            && !(self.interactive_deadline_s.is_finite() && self.interactive_deadline_s > 0.0)
        {
            return Err("cluster workload: interactive deadline must be > 0".into());
        }
        Ok(())
    }
}

/// The front-end's verdict on one arrival.
enum Admission {
    Route { replica: usize, tier: SloTier },
    Shed { tier: SloTier },
}

/// The per-arrival decision core shared VERBATIM by both serving paths
/// (the virtual sweep drives it on virtual seconds, the threaded
/// [`Cluster`] on wall seconds): tier classification, fluid work
/// horizons per replica, deadline-aware shedding, and the autoscale
/// controller. Pure arithmetic over arrival times — deterministic.
struct FrontEnd {
    /// Routable flag per replica slot (autoscale flips these).
    active: Vec<bool>,
    /// Earliest time each replica may receive traffic (warm-up).
    available_from: Vec<f64>,
    /// Fluid work horizon per replica: the virtual timestamp at which
    /// its accepted work is projected to drain.
    horizon: Vec<f64>,
    /// `(t, active_count)` at init and at every autoscale action.
    timeline: Vec<(f64, usize)>,
    /// Next controller evaluation is at `last_eval + interval`.
    last_eval: f64,
    shed: bool,
    autoscale: Option<AutoscaleConfig>,
    default_deadline_s: Option<f64>,
    /// Per-replica worker count (horizon advance divides by this).
    workers: f64,
    /// Resolved fused-batch cap for the amortized weight-stream term.
    max_batch: f64,
    step: StepModel,
}

impl FrontEnd {
    fn new(cc: &ClusterConfig) -> Result<FrontEnd, String> {
        if cc.replicas == 0 {
            return Err("cluster config needs >= 1 replica".into());
        }
        if let Some(a) = &cc.autoscale {
            a.validate()?;
        }
        let slots = cc
            .autoscale
            .as_ref()
            .map_or(cc.replicas, |a| a.max_replicas.max(cc.replicas));
        let initial = cc
            .autoscale
            .as_ref()
            .map_or(cc.replicas, |a| cc.replicas.clamp(a.min_replicas, a.max_replicas));
        let max_batch =
            if cc.pool.max_batch == 0 { cc.pool.max_active } else { cc.pool.max_batch };
        Ok(FrontEnd {
            active: (0..slots).map(|i| i < initial).collect(),
            available_from: vec![0.0; slots],
            horizon: vec![0.0; slots],
            timeline: vec![(0.0, initial)],
            last_eval: 0.0,
            shed: cc.shed,
            autoscale: cc.autoscale,
            default_deadline_s: cc.default_deadline_s,
            workers: cc.pool.workers.max(1) as f64,
            max_batch: max_batch.max(1) as f64,
            step: cc.pool.step,
        })
    }

    fn slots(&self) -> usize {
        self.active.len()
    }

    fn active_count(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Estimated service seconds one request adds to a replica (whole-
    /// pool view, so the caller divides by the worker count): a
    /// single-pass prefill plus per-token decode steps with the weight
    /// stream amortized across the fused batch — the same first-order
    /// terms [`StepModel`] charges the pools.
    fn request_cost_s(&self, req: &Request) -> f64 {
        let prompt = req.prompt.len().max(1) as f64;
        let out = req.max_new_tokens.max(1) as f64;
        let prefill = self.step.weight_stream_s
            + prompt * self.step.kv_read_s_per_pos
            + self.step.lane_overhead_s
            + self.step.sync_s;
        let avg_pos = prompt + out * 0.5;
        let per_token = (self.step.weight_stream_s + self.step.sync_s) / self.max_batch
            + avg_pos * self.step.kv_read_s_per_pos
            + self.step.lane_overhead_s;
        prefill + out * per_token
    }

    /// Run the autoscale controller over every whole evaluation tick up
    /// to `t`.
    fn advance(&mut self, t: f64) {
        let Some(a) = self.autoscale else { return };
        while self.last_eval + a.interval_s <= t {
            let te = self.last_eval + a.interval_s;
            self.last_eval = te;
            let n_active = self.active_count();
            let backlog: f64 = (0..self.slots())
                .filter(|&r| self.active[r])
                .map(|r| (self.horizon[r].max(self.available_from[r]) - te).max(0.0))
                .sum::<f64>()
                / n_active.max(1) as f64;
            if backlog > a.up_backlog_s && n_active < a.max_replicas {
                // Lowest inactive slot; a previously drained replica
                // re-activates (its horizon carried over).
                if let Some(r) = (0..self.slots()).find(|&r| !self.active[r]) {
                    self.active[r] = true;
                    self.available_from[r] = te + a.warmup_s;
                    self.horizon[r] = self.horizon[r].max(te);
                    self.timeline.push((te, n_active + 1));
                }
            } else if backlog < a.down_backlog_s && n_active > a.min_replicas {
                // Drain the highest active slot: stops receiving, but
                // already-assigned work finishes.
                if let Some(r) = (0..self.slots()).rev().find(|&r| self.active[r]) {
                    self.active[r] = false;
                    self.timeline.push((te, n_active - 1));
                }
            }
        }
    }

    /// Decide one arrival at time `t`. Applies the default deadline (if
    /// configured and the request carries none), classifies the tier,
    /// picks the least-delayed routable replica, sheds interactive
    /// arrivals whose projected delay blows the budget, and advances
    /// the chosen replica's horizon by the request's estimated cost.
    fn admit(&mut self, t: f64, req: &mut Request) -> Admission {
        self.advance(t);
        if req.deadline_s.is_none() {
            req.deadline_s = self.default_deadline_s;
        }
        let tier = SloTier::classify(req);
        // Least projected delay wins; ties go to the lowest index.
        let mut best: Option<(f64, usize)> = None;
        for r in 0..self.slots() {
            if !self.active[r] {
                continue;
            }
            let ready = self.horizon[r].max(self.available_from[r]).max(t);
            let delay = ready - t;
            if best.map_or(true, |(bd, _)| delay < bd) {
                best = Some((delay, r));
            }
        }
        let (delay, r) = best.expect("front-end keeps >= 1 replica active");
        if self.shed && tier == SloTier::Interactive {
            if let Some(budget) = req.deadline_s {
                if delay > budget {
                    return Admission::Shed { tier };
                }
            }
        }
        let start = self.horizon[r].max(self.available_from[r]).max(t);
        self.horizon[r] = start + self.request_cost_s(req) / self.workers;
        Admission::Route { replica: r, tier }
    }
}

/// One request's cluster-level lifetime (virtual path).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterRecord {
    /// Index in the cluster plan.
    pub request_id: usize,
    /// SLO tier the front-end classified it into.
    pub tier: SloTier,
    /// Replica that served it (None = shed at the front-end).
    pub replica: Option<usize>,
    /// Shed by SLO admission (always before any token).
    pub shed: bool,
    /// Arrival time, seconds.
    pub arrival_s: f64,
    /// First-token emission time (= arrival for shed/rejected).
    pub first_token_s: f64,
    /// Completion time.
    pub done_s: f64,
    /// The generated stream (empty for shed/rejected/failed).
    pub tokens: Vec<i64>,
    /// Emission time per token (same length as `tokens`).
    pub token_times: Vec<f64>,
    /// The TTFT budget it carried (None = batch).
    pub deadline_s: Option<f64>,
}

impl ClusterRecord {
    /// Completed means a non-empty stream reached the client.
    pub fn completed(&self) -> bool {
        !self.tokens.is_empty()
    }

    /// Whether a completed interactive stream met its TTFT budget
    /// (batch and budget-less records count attained when completed).
    pub fn attained(&self) -> bool {
        self.completed()
            && self
                .deadline_s
                .map_or(true, |d| self.first_token_s - self.arrival_s <= d)
    }
}

/// Results of one virtual cluster run. Pure function of
/// (plan, config) — two runs are bit-identical.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Offered request rate, requests/second (base rate).
    pub offered_rate: f64,
    /// Per-request lifetimes, indexed by plan order.
    pub records: Vec<ClusterRecord>,
    /// Per-replica pool reports (None = replica never received work).
    pub replicas: Vec<Option<VirtualReport>>,
    /// `(t, active_replicas)` at init and every autoscale action.
    pub replica_timeline: Vec<(f64, usize)>,
    /// Peak simultaneously active replicas.
    pub peak_replicas: usize,
    /// Interactive arrivals offered.
    pub submitted_interactive: usize,
    /// Batch arrivals offered.
    pub submitted_batch: usize,
    /// Interactive arrivals shed by SLO admission.
    pub shed_interactive: usize,
    /// Batch arrivals shed (the policy never sheds batch; nonzero
    /// flags a front-end bug).
    pub shed_batch: usize,
    /// Interactive requests that completed their stream.
    pub completed_interactive: usize,
    /// Batch requests that completed their stream.
    pub completed_batch: usize,
    /// Interactive completions whose TTFT met the budget.
    pub attained_interactive: usize,
    /// Cluster makespan, seconds (max over replicas and arrivals).
    pub wall_s: f64,
    /// Achieved output tokens/second over the makespan.
    pub tokens_per_s: f64,
    /// KV blocks still held across every replica at drain — must be 0.
    pub end_kv_blocks_in_use: usize,
}

impl ClusterReport {
    /// SLO attainment for a tier, over everything *offered* to that
    /// tier (shed requests count against attainment — that is the
    /// honest fleet-level number). 1.0 when the tier saw no traffic.
    pub fn attainment(&self, tier: SloTier) -> f64 {
        let (num, den) = match tier {
            SloTier::Interactive => {
                (self.attained_interactive, self.submitted_interactive)
            }
            SloTier::Batch => (self.completed_batch, self.submitted_batch),
        };
        if den == 0 {
            1.0
        } else {
            num as f64 / den as f64
        }
    }

    /// Fraction of a tier's arrivals shed at admission.
    pub fn shed_fraction(&self, tier: SloTier) -> f64 {
        let (num, den) = match tier {
            SloTier::Interactive => (self.shed_interactive, self.submitted_interactive),
            SloTier::Batch => (self.shed_batch, self.submitted_batch),
        };
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }
}

/// Replay a tiered workload through the virtual cluster.
pub fn run_virtual_cluster(
    wl: &ClusterWorkload,
    cc: &ClusterConfig,
) -> Result<ClusterReport, String> {
    wl.validate()?;
    run_virtual_cluster_plan(&wl.base.model, wl.base.vocab, wl.base.rate, wl.generate(), cc)
}

/// [`run_virtual_cluster`] over an explicit `(arrival_s, request)`
/// plan. The front-end makes every admission/shed/autoscale decision
/// in arrival order, then each replica's assigned sub-plan runs
/// through the UNMODIFIED single-pool
/// [`run_virtual_plan`][super::workload::run_virtual_plan] (global
/// arrival timestamps preserved, so all replica clocks share one
/// timeline) and the per-pool records are merged back by plan index.
pub fn run_virtual_cluster_plan(
    model: &str,
    vocab: usize,
    offered_rate: f64,
    plan: Vec<(f64, Request)>,
    cc: &ClusterConfig,
) -> Result<ClusterReport, String> {
    if plan.windows(2).any(|w| w[0].0 > w[1].0) {
        return Err("cluster plan arrivals must be non-decreasing".into());
    }
    let mut fe = FrontEnd::new(cc)?;
    let n = plan.len();
    let mut plan_end = 0.0f64;
    let mut tiers: Vec<(SloTier, Option<f64>)> = Vec::with_capacity(n);
    let mut records: Vec<Option<ClusterRecord>> = (0..n).map(|_| None).collect();
    let mut sub: Vec<Vec<(f64, Request)>> = (0..fe.slots()).map(|_| Vec::new()).collect();
    let mut assigned: Vec<Vec<usize>> = (0..fe.slots()).map(|_| Vec::new()).collect();
    for (rid, (t, mut req)) in plan.into_iter().enumerate() {
        plan_end = plan_end.max(t);
        match fe.admit(t, &mut req) {
            Admission::Shed { tier } => {
                records[rid] = Some(ClusterRecord {
                    request_id: rid,
                    tier,
                    replica: None,
                    shed: true,
                    arrival_s: t,
                    first_token_s: t,
                    done_s: t,
                    tokens: Vec::new(),
                    token_times: Vec::new(),
                    deadline_s: req.deadline_s,
                });
                tiers.push((tier, req.deadline_s));
            }
            Admission::Route { replica, tier } => {
                tiers.push((tier, req.deadline_s));
                assigned[replica].push(rid);
                sub[replica].push((t, req));
            }
        }
    }

    let mut replicas: Vec<Option<VirtualReport>> = Vec::with_capacity(fe.slots());
    for (r, subplan) in sub.into_iter().enumerate() {
        if subplan.is_empty() {
            replicas.push(None);
            continue;
        }
        let vr = run_virtual_plan(model, vocab, offered_rate, subplan, &cc.pool)?;
        for (local, rec) in vr.records.iter().enumerate() {
            let rid = assigned[r][local];
            let (tier, deadline_s) = tiers[rid];
            records[rid] = Some(ClusterRecord {
                request_id: rid,
                tier,
                replica: Some(r),
                shed: false,
                arrival_s: rec.arrival_s,
                first_token_s: rec.first_token_s,
                done_s: rec.done_s,
                tokens: rec.tokens.clone(),
                token_times: rec.token_times.clone(),
                deadline_s,
            });
        }
        replicas.push(Some(vr));
    }

    let records: Vec<ClusterRecord> =
        records.into_iter().map(|r| r.expect("every arrival recorded")).collect();
    let wall_s = replicas
        .iter()
        .flatten()
        .map(|vr| vr.wall_s)
        .fold(plan_end, f64::max);
    let total_tokens: usize = records.iter().map(|r| r.tokens.len()).sum();
    let count =
        |f: &dyn Fn(&ClusterRecord) -> bool| records.iter().filter(|r| f(r)).count();
    let peak_replicas = fe.timeline.iter().map(|&(_, n)| n).max().unwrap_or(0);
    Ok(ClusterReport {
        offered_rate,
        submitted_interactive: count(&|r| r.tier == SloTier::Interactive),
        submitted_batch: count(&|r| r.tier == SloTier::Batch),
        shed_interactive: count(&|r| r.tier == SloTier::Interactive && r.shed),
        shed_batch: count(&|r| r.tier == SloTier::Batch && r.shed),
        completed_interactive: count(&|r| r.tier == SloTier::Interactive && r.completed()),
        completed_batch: count(&|r| r.tier == SloTier::Batch && r.completed()),
        attained_interactive: count(&|r| r.tier == SloTier::Interactive && r.attained()),
        wall_s,
        tokens_per_s: if wall_s > 0.0 { total_tokens as f64 / wall_s } else { 0.0 },
        end_kv_blocks_in_use: replicas
            .iter()
            .flatten()
            .map(|vr| vr.end_kv_blocks_in_use)
            .sum(),
        replica_timeline: fe.timeline.clone(),
        peak_replicas,
        replicas,
        records,
    })
}

/// Outcome of a threaded cluster submission.
pub enum Submitted {
    /// Routed to a replica; stream via the handle.
    Handle {
        /// Replica index that received the request.
        replica: usize,
        /// The tier the front-end classified it into.
        tier: SloTier,
        /// Streaming handle from the replica's coordinator.
        handle: RequestHandle,
    },
    /// Shed at admission — no tokens were (or will be) generated.
    Shed {
        /// The tier of the shed arrival (always interactive under the
        /// shipped policy).
        tier: SloTier,
    },
}

/// The threaded cluster dispatcher: live [`Coordinator`] replicas
/// behind the SAME [`FrontEnd`] decision core the virtual sweep runs,
/// driven on wall seconds (or on caller-supplied timestamps via
/// [`Cluster::submit_at`], which makes front-end decisions
/// reproducible across paths).
pub struct Cluster {
    model: String,
    replicas: Vec<Coordinator>,
    fe: Mutex<FrontEnd>,
    epoch: Instant,
    /// Fleet-level metrics: per-tier submitted/shed/done/attained
    /// counters (pool-level serving metrics live on each replica).
    pub metrics: Arc<Metrics>,
}

impl Cluster {
    /// Build a fleet: one [`Coordinator`] per replica slot from the
    /// caller's factory (which must register `model`'s pool). With
    /// autoscaling, all `max_replicas` coordinators exist up front —
    /// activation is a routing decision; warm-up is charged by the
    /// front-end.
    pub fn threaded(
        cc: &ClusterConfig,
        model: &str,
        mut build: impl FnMut() -> Coordinator,
    ) -> Result<Cluster, String> {
        let fe = FrontEnd::new(cc)?;
        let replicas: Vec<Coordinator> = (0..fe.slots()).map(|_| build()).collect();
        for c in &replicas {
            if !c.models().contains(&model.to_string()) {
                return Err(format!("replica factory did not register model '{model}'"));
            }
        }
        Ok(Cluster {
            model: model.to_string(),
            replicas,
            fe: Mutex::new(fe),
            epoch: Instant::now(),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// The model this fleet serves.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Total replica slots (active or not).
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Currently routable replicas.
    pub fn active_replicas(&self) -> usize {
        self.fe.lock().unwrap().active_count()
    }

    /// `(t, active_count)` autoscale history (seconds since the fleet
    /// epoch).
    pub fn replica_timeline(&self) -> Vec<(f64, usize)> {
        self.fe.lock().unwrap().timeline.clone()
    }

    /// The live replica coordinators (for per-replica gauges).
    pub fn replicas(&self) -> &[Coordinator] {
        &self.replicas
    }

    /// Submit with an explicit front-end timestamp (seconds on the
    /// caller's clock; must be non-decreasing across calls for the
    /// fluid horizons to mean anything). [`run_cluster_open_loop`]
    /// passes the *planned* arrival time, which makes shed/route/
    /// autoscale decisions bit-identical to the virtual path's.
    pub fn submit_at(&self, at_s: f64, request: Request) -> Result<Submitted, String> {
        let mut request = request;
        let decision = self.fe.lock().unwrap().admit(at_s, &mut request);
        match decision {
            Admission::Shed { tier } => {
                self.metrics.on_tier_submit(tier);
                self.metrics.on_tier_shed(tier);
                Ok(Submitted::Shed { tier })
            }
            Admission::Route { replica, tier } => {
                self.metrics.on_tier_submit(tier);
                let handle = self.replicas[replica].submit(request)?;
                Ok(Submitted::Handle { replica, tier, handle })
            }
        }
    }

    /// Submit on the fleet's wall clock (the server path).
    pub fn submit(&self, request: Request) -> Result<Submitted, String> {
        self.submit_at(self.epoch.elapsed().as_secs_f64(), request)
    }

    /// Record a completed stream's tier outcome (`attained` = its TTFT
    /// met the deadline budget; pass true for batch).
    pub fn note_done(&self, tier: SloTier, attained: bool) {
        self.metrics.on_tier_done(tier, attained);
    }

    /// Shut every replica down (in-flight requests finish).
    pub fn shutdown(self) {
        for c in self.replicas {
            c.shutdown();
        }
    }
}

/// Results of one threaded cluster load run.
#[derive(Clone, Debug)]
pub struct ClusterLoadReport {
    /// Offered base rate, requests/second.
    pub offered_rate: f64,
    /// Requests whose stream completed.
    pub completed: usize,
    /// Requests shed by SLO admission.
    pub shed: usize,
    /// Requests that ended in a visible error (pool-level shed or
    /// failure).
    pub failed: usize,
    /// Wall time of the run, seconds.
    pub wall_s: f64,
    /// Generated tokens per request in plan order (empty = shed or
    /// failed) — the cross-path stream-identity surface.
    pub token_streams: Vec<Vec<i64>>,
    /// Wall-clock TTFT over completed requests, seconds.
    pub ttft: Summary,
}

/// Run a tiered workload against a live threaded [`Cluster`],
/// honoring planned arrival times on the wall clock while feeding the
/// front-end the *planned* timestamps (so admission decisions match
/// the virtual path bit for bit). Mirrors
/// [`run_open_loop`][super::workload::run_open_loop].
pub fn run_cluster_open_loop(
    cluster: &Cluster,
    wl: &ClusterWorkload,
) -> Result<ClusterLoadReport, String> {
    wl.validate()?;
    type PerReq = Result<(f64, Vec<i64>), String>;
    fn collect(submitted: Instant, handle: RequestHandle) -> PerReq {
        let mut first: Option<f64> = None;
        for ev in handle.events.iter() {
            match ev {
                TokenEvent::Token { index, .. } => {
                    if index == 0 {
                        first = Some(submitted.elapsed().as_secs_f64());
                    }
                }
                TokenEvent::Done { tokens, .. } => {
                    let ttft =
                        first.unwrap_or_else(|| submitted.elapsed().as_secs_f64());
                    return Ok((ttft, tokens));
                }
                TokenEvent::Error { message, .. } => return Err(message),
            }
        }
        Err("stream closed without completion".into())
    }

    let plan = wl.generate();
    let n = plan.len();
    let t0 = Instant::now();
    let mut shed = 0usize;
    let mut collectors: Vec<(usize, SloTier, Option<f64>, std::thread::JoinHandle<PerReq>)> =
        Vec::new();
    for (rid, (at_s, req)) in plan.into_iter().enumerate() {
        if let Some(sleep) =
            std::time::Duration::from_secs_f64(at_s).checked_sub(t0.elapsed())
        {
            std::thread::sleep(sleep);
        }
        let deadline = req.deadline_s;
        let submitted = Instant::now();
        match cluster.submit_at(at_s, req)? {
            Submitted::Shed { .. } => shed += 1,
            Submitted::Handle { tier, handle, .. } => {
                collectors.push((
                    rid,
                    tier,
                    deadline,
                    std::thread::Builder::new()
                        .name("lpu-cluster-collect".into())
                        .spawn(move || collect(submitted, handle))
                        .map_err(|e| e.to_string())?,
                ));
            }
        }
    }
    let mut streams: Vec<Vec<i64>> = vec![Vec::new(); n];
    let mut ttfts = Vec::new();
    let mut completed = 0usize;
    let mut failed = 0usize;
    for (rid, tier, deadline, c) in collectors {
        match c.join().map_err(|_| "collector panicked")? {
            Ok((ttft, tokens)) => {
                cluster.note_done(tier, deadline.map_or(true, |d| ttft <= d));
                streams[rid] = tokens;
                ttfts.push(ttft);
                completed += 1;
            }
            Err(_) => failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(ClusterLoadReport {
        offered_rate: wl.base.rate,
        completed,
        shed,
        failed,
        wall_s,
        token_streams: streams,
        ttft: if ttfts.is_empty() { Summary::of(&[0.0]) } else { Summary::of(&ttfts) },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LpuConfig;
    use crate::coordinator::{BackendFactory, CoordinatorConfig, SchedulerPolicy};
    use crate::model::by_name;

    fn step_model() -> StepModel {
        StepModel::from_config(&by_name("opt-1.3b").unwrap(), &LpuConfig::asic_819gbs(), 1)
    }

    fn cwl(rate: f64, n: usize, frac: f64, deadline: f64, trace: ArrivalTrace) -> ClusterWorkload {
        ClusterWorkload {
            base: Workload {
                model: "opt-tiny".into(),
                rate,
                n_requests: n,
                prompt_len: LenDist::Uniform(1, 6),
                output_len: LenDist::Fixed(5),
                vocab: 512,
                seed: 77,
            },
            trace,
            interactive_fraction: frac,
            interactive_deadline_s: deadline,
        }
    }

    fn pool(workers: usize, max_active: usize) -> VirtualConfig {
        VirtualConfig::new(SchedulerPolicy::RoundRobin, workers, max_active, step_model())
    }

    #[test]
    fn tier_classification_follows_deadline() {
        let mut r = Request::greedy("m", vec![1], 4);
        assert_eq!(SloTier::classify(&r), SloTier::Batch);
        r.deadline_s = Some(0.5);
        assert_eq!(SloTier::classify(&r), SloTier::Interactive);
        assert_eq!(SloTier::Interactive.name(), "interactive");
        assert_eq!(SloTier::Batch.name(), "batch");
    }

    #[test]
    fn slo_tier_spec_grammar() {
        assert_eq!(SloTierSpec::parse("batch").unwrap(), SloTierSpec::Batch);
        assert_eq!(
            SloTierSpec::parse("interactive:0.5").unwrap(),
            SloTierSpec::Interactive { ttft_s: 0.5 }
        );
        assert_eq!(
            SloTierSpec::parse("mixed:0.5:0.25").unwrap(),
            SloTierSpec::Mixed { ttft_s: 0.5, fraction: 0.25 }
        );
        assert!(SloTierSpec::parse("interactive").is_err());
        assert!(SloTierSpec::parse("interactive:-1").is_err());
        assert!(SloTierSpec::parse("mixed:0.5:1.5").is_err());
        assert!(SloTierSpec::parse("gold").is_err());
        assert_eq!(SloTierSpec::Mixed { ttft_s: 0.5, fraction: 0.25 }.mix(), (0.25, 0.5));
    }

    #[test]
    fn autoscale_spec_grammar() {
        let a = AutoscaleConfig::parse("min=2,max=6,interval=0.1,warmup=1.5,up=0.8,down=0.1")
            .unwrap();
        assert_eq!((a.min_replicas, a.max_replicas), (2, 6));
        assert_eq!((a.interval_s, a.warmup_s), (0.1, 1.5));
        assert_eq!((a.up_backlog_s, a.down_backlog_s), (0.8, 0.1));
        // Partial specs inherit defaults.
        let d = AutoscaleConfig::parse("max=8").unwrap();
        assert_eq!(d.max_replicas, 8);
        assert_eq!(d.min_replicas, AutoscaleConfig::default().min_replicas);
        // Misconfiguration is refused, not ignored.
        assert!(AutoscaleConfig::parse("min=0").is_err());
        assert!(AutoscaleConfig::parse("min=4,max=2").is_err());
        assert!(AutoscaleConfig::parse("interval=0").is_err());
        assert!(AutoscaleConfig::parse("up=0.1,down=0.5").is_err());
        assert!(AutoscaleConfig::parse("turbo=9").is_err());
        assert!(AutoscaleConfig::parse("warmup=abc").is_err());
    }

    #[test]
    fn arrival_traces_shape_intensity() {
        assert_eq!(ArrivalTrace::Uniform.intensity(123.0), 1.0);
        let d = ArrivalTrace::Diurnal { period_s: 4.0, depth: 1.0 };
        assert!((d.intensity(1.0) - 2.0).abs() < 1e-9, "peak at quarter period");
        assert!(d.intensity(3.0) <= 0.06, "trough floored above zero");
        let f = ArrivalTrace::FlashCrowd { at_s: 1.0, dur_s: 2.0, magnification: 8.0 };
        assert_eq!(f.intensity(0.5), 1.0);
        assert_eq!(f.intensity(1.5), 8.0);
        assert_eq!(f.intensity(3.5), 1.0);
        assert_eq!(ArrivalTrace::parse("uniform").unwrap(), ArrivalTrace::Uniform);
        assert_eq!(
            ArrivalTrace::parse("diurnal:60:0.9").unwrap(),
            ArrivalTrace::Diurnal { period_s: 60.0, depth: 0.9 }
        );
        assert_eq!(
            ArrivalTrace::parse("flash:5:2:10").unwrap(),
            ArrivalTrace::FlashCrowd { at_s: 5.0, dur_s: 2.0, magnification: 10.0 }
        );
        assert!(ArrivalTrace::parse("bursty").is_err());
        assert!(ArrivalTrace::parse("diurnal:60").is_err());
    }

    #[test]
    fn cluster_workload_generator_is_deterministic_and_tiered() {
        let wl = cwl(200.0, 400, 0.5, 0.5, ArrivalTrace::Uniform);
        let a = wl.generate();
        let b = wl.generate();
        assert_eq!(a.len(), 400);
        for ((ta, ra), (tb, rb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.deadline_s, rb.deadline_s);
        }
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
        let interactive = a.iter().filter(|(_, r)| r.deadline_s.is_some()).count();
        assert!(
            (120..=280).contains(&interactive),
            "tier split ~50%, got {interactive}/400"
        );
    }

    #[test]
    fn flash_crowd_compresses_gaps_inside_burst() {
        let base = cwl(100.0, 600, 0.0, 0.0, ArrivalTrace::Uniform).generate();
        let flash = cwl(
            100.0,
            600,
            0.0,
            0.0,
            ArrivalTrace::FlashCrowd { at_s: 1.0, dur_s: 2.0, magnification: 10.0 },
        )
        .generate();
        // Identical seed: the burst squeezes more arrivals into [1, 3).
        let in_window = |plan: &[(f64, Request)]| {
            plan.iter().filter(|(t, _)| (1.0..3.0).contains(t)).count()
        };
        assert!(
            in_window(&flash) > in_window(&base) * 3,
            "flash {} !>> base {}",
            in_window(&flash),
            in_window(&base)
        );
    }

    #[test]
    fn single_replica_no_shed_cluster_matches_plain_pool_run() {
        // The degenerate cluster IS the pool: same records, wrapped.
        let wl = cwl(2000.0, 60, 0.5, 30.0, ArrivalTrace::Uniform);
        let vc = pool(2, 4);
        let mut cc = ClusterConfig::new(1, vc.clone());
        cc.shed = false;
        let cr = run_virtual_cluster(&wl, &cc).unwrap();
        let plan = wl.generate();
        let vr = run_virtual_plan("opt-tiny", 512, 2000.0, plan, &vc).unwrap();
        assert_eq!(cr.records.len(), vr.records.len());
        for (c, v) in cr.records.iter().zip(&vr.records) {
            assert_eq!(c.tokens, v.tokens);
            assert_eq!(c.first_token_s, v.first_token_s);
            assert_eq!(c.done_s, v.done_s);
            assert_eq!(c.replica, Some(0));
            assert!(!c.shed);
        }
        assert_eq!(cr.shed_interactive + cr.shed_batch, 0);
        assert_eq!(cr.peak_replicas, 1);
        assert_eq!(cr.end_kv_blocks_in_use, 0);
    }

    #[test]
    fn cluster_runs_are_bit_identical() {
        let wl = cwl(3000.0, 120, 0.6, 0.05, ArrivalTrace::Diurnal { period_s: 0.2, depth: 0.8 });
        let mut cc = ClusterConfig::new(2, pool(1, 4));
        cc.autoscale = Some(AutoscaleConfig {
            max_replicas: 3,
            interval_s: 0.01,
            ..AutoscaleConfig::default()
        });
        let a = run_virtual_cluster(&wl, &cc).unwrap();
        let b = run_virtual_cluster(&wl, &cc).unwrap();
        assert_eq!(a.records, b.records);
        assert_eq!(a.replica_timeline, b.replica_timeline);
        assert_eq!(a.wall_s, b.wall_s);
    }

    #[test]
    fn shed_happens_only_before_first_token() {
        // Overload a tiny fleet with tight budgets: sheds must occur,
        // and every shed record is empty — no mid-stream drops.
        let wl = cwl(20_000.0, 200, 1.0, 0.01, ArrivalTrace::Uniform);
        let cc = ClusterConfig::new(1, pool(1, 2));
        let r = run_virtual_cluster(&wl, &cc).unwrap();
        assert!(r.shed_interactive > 0, "overload must shed");
        for rec in &r.records {
            if rec.shed {
                assert!(rec.tokens.is_empty() && rec.token_times.is_empty());
                assert_eq!(rec.replica, None);
                assert_eq!(rec.first_token_s, rec.arrival_s);
            }
        }
        assert_eq!(r.shed_batch, 0, "batch is never shed");
    }

    #[test]
    fn shedding_protects_admitted_interactive_ttft() {
        // At heavy overload, SLO admission keeps the *admitted*
        // interactive requests inside their budget; without shedding
        // the queue grows without bound and attainment collapses.
        let wl = cwl(5_000.0, 300, 1.0, 0.05, ArrivalTrace::Uniform);
        let mut shed_on = ClusterConfig::new(1, pool(1, 4));
        shed_on.shed = true;
        let mut shed_off = shed_on.clone();
        shed_off.shed = false;
        let on = run_virtual_cluster(&wl, &shed_on).unwrap();
        let off = run_virtual_cluster(&wl, &shed_off).unwrap();
        assert!(on.shed_interactive > 0);
        assert!(
            on.attainment(SloTier::Interactive) > off.attainment(SloTier::Interactive),
            "shed attainment {} !> no-shed {}",
            on.attainment(SloTier::Interactive),
            off.attainment(SloTier::Interactive)
        );
        // Completed streams agree request-for-request with the no-shed
        // run (greedy purity: placement never changes tokens).
        for (a, b) in on.records.iter().zip(&off.records) {
            if a.completed() && b.completed() {
                assert_eq!(a.tokens, b.tokens);
            }
        }
    }

    #[test]
    fn autoscaler_rides_a_flash_crowd_and_drains_after() {
        let wl = cwl(
            800.0,
            400,
            0.0,
            0.0,
            ArrivalTrace::FlashCrowd { at_s: 0.5, dur_s: 1.0, magnification: 12.0 },
        );
        let mut cc = ClusterConfig::new(1, pool(1, 4));
        cc.autoscale = Some(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            interval_s: 0.05,
            warmup_s: 0.1,
            up_backlog_s: 0.2,
            down_backlog_s: 0.02,
        });
        let r = run_virtual_cluster(&wl, &cc).unwrap();
        assert!(r.peak_replicas > 1, "burst must trigger scale-up");
        assert!(
            r.replica_timeline.last().unwrap().1 < r.peak_replicas,
            "post-burst drain must scale back down: {:?}",
            r.replica_timeline
        );
        // Scale-up is never free: a warmed replica's first request
        // cannot arrive before its activation + warmup.
        for (rid, rec) in r.records.iter().enumerate() {
            if let Some(rep) = rec.replica {
                if rep > 0 {
                    let activated = r
                        .replica_timeline
                        .iter()
                        .find(|&&(_, n)| n > rep)
                        .map(|&(t, _)| t)
                        .unwrap_or(0.0);
                    assert!(
                        rec.arrival_s >= activated,
                        "request {rid} routed to replica {rep} before activation"
                    );
                }
            }
        }
        assert_eq!(r.end_kv_blocks_in_use, 0);
    }

    #[test]
    fn more_replicas_cut_makespan_under_backlog() {
        let wl = cwl(50_000.0, 160, 0.0, 0.0, ArrivalTrace::Uniform);
        let one = ClusterConfig::new(1, pool(1, 4));
        let four = ClusterConfig::new(4, pool(1, 4));
        let r1 = run_virtual_cluster(&wl, &one).unwrap();
        let r4 = run_virtual_cluster(&wl, &four).unwrap();
        assert!(
            r4.wall_s < r1.wall_s * 0.5,
            "4 replicas {} !< 0.5 * 1 replica {}",
            r4.wall_s,
            r1.wall_s
        );
        // Streams identical regardless of replica count.
        for (a, b) in r1.records.iter().zip(&r4.records) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn threaded_cluster_front_end_matches_virtual_decisions() {
        // Feed the threaded dispatcher the planned timestamps: the
        // shared FrontEnd must shed/route exactly like the virtual run.
        let wl = cwl(20_000.0, 40, 1.0, 0.01, ArrivalTrace::Uniform);
        let cc = ClusterConfig::new(1, pool(1, 2));
        let virt = run_virtual_cluster(&wl, &cc).unwrap();
        let cluster = Cluster::threaded(&cc, "opt-tiny", || {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 2,
                policy: SchedulerPolicy::RoundRobin,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            c
        })
        .unwrap();
        for (rid, (at_s, req)) in wl.generate().into_iter().enumerate() {
            match cluster.submit_at(at_s, req).unwrap() {
                Submitted::Shed { .. } => {
                    assert!(virt.records[rid].shed, "request {rid} shed only on threaded")
                }
                Submitted::Handle { replica, .. } => {
                    assert!(!virt.records[rid].shed, "request {rid} shed only on virtual");
                    assert_eq!(Some(replica), virt.records[rid].replica);
                }
            }
        }
        let s = cluster.metrics.snapshot();
        assert_eq!(s.tier_interactive_submitted, 40);
        assert_eq!(s.tier_interactive_shed as usize, virt.shed_interactive);
        cluster.shutdown();
    }

    #[test]
    fn threaded_factory_must_register_model() {
        let cc = ClusterConfig::new(1, pool(1, 2));
        let err = Cluster::threaded(&cc, "opt-tiny", || {
            Coordinator::new(CoordinatorConfig::default())
        })
        .map(|c| c.shutdown())
        .unwrap_err();
        assert!(err.contains("did not register"), "{err}");
    }
}
