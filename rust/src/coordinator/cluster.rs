//! Cluster tier: an SLO-aware replica fleet above the worker pool.
//!
//! The paper's pitch is not one LPU but a *scalable* fleet; the roadmap
//! north star is "millions of users". This module adds the layer that
//! turns N independent pools into one deployment:
//!
//! * **SLO tiers** ([`SloTier`]): a request with a deadline
//!   ([`Request::deadline_s`]) is *interactive* — its deadline doubles
//!   as the TTFT budget the front-end admits against; a request without
//!   one is *batch* — throughput-only, never shed.
//! * **Deadline-aware admission with load shedding**: the front-end
//!   keeps a fluid work horizon per replica (estimated seconds of
//!   accepted-but-unserved work, priced by the same [`StepModel`] terms
//!   the pools charge) and sheds an interactive arrival when every
//!   routable replica's projected queue delay exceeds its TTFT budget.
//!   Shedding happens strictly *before* the first token — an admitted
//!   stream is never dropped mid-flight.
//! * **Step-driven autoscaling** ([`AutoscaleConfig`]): on a fixed
//!   evaluation grid the controller compares per-replica backlog
//!   seconds against up/down thresholds and activates or drains
//!   replicas; a freshly activated replica is only routable after a
//!   configurable warm-up, so scaling is never free.
//! * **Arrival traces** ([`ArrivalTrace`]): diurnal and flash-crowd
//!   intensity modulation over the Poisson base rate, so SLO-attainment
//!   curves can be swept against realistic load shapes
//!   (`benches/cluster_slo.rs` → `BENCH_cluster.json`).
//!
//! * **Replica fault domains** ([`ClusterFaultPlan`]): deterministic
//!   crash / partition / slow injection one tier above the pool-level
//!   [`FaultPlan`][super::faults::FaultPlan]. The front-end is an
//!   active health manager (healthy → probation → ejected, with
//!   probe-based reinstatement after a partition heals), reprices
//!   degraded replicas once a probe interval has passed, and the
//!   dispatcher fails orphaned in-flight streams over to a healthy
//!   replica: the delivered token prefix plus a reconstructed sampler
//!   become a resume state, re-admitted via the pool's restore path.
//!   Delivery is exactly-once — a resumed or hedged duplicate can
//!   never duplicate or reorder tokens — and, by greedy purity,
//!   completed streams are bit-identical to the fault-free run.
//! * **Hedged interactive requests**: when `hedge_fraction > 0`, an
//!   interactive arrival whose projected queue delay exceeds that
//!   fraction of its deadline is duplicated on the runner-up replica;
//!   the first usable stream wins and the loser is cancelled (its KV
//!   released by the normal client-disconnect path).
//!
//! Per the standing constraint, the fleet logic runs on BOTH serving
//! paths without forking: the per-arrival decision core ([`FrontEnd`])
//! is one struct, driven on virtual seconds by [`run_virtual_cluster`]
//! (each replica is a full, unmodified
//! [`run_virtual_plan_jobs`][super::workload::run_virtual_plan_jobs]
//! pool) and on wall seconds by the threaded [`Cluster`] dispatcher
//! (each replica a live [`Coordinator`]). Greedy token streams are a
//! pure function of (model, prompt) in the sim backend, so completed
//! streams are bit-identical per seed regardless of tier, replica
//! count, placement, failover, or hedging — asserted by
//! `tests/invariants.rs` through the shared invariant harness.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::numerics::Sampler;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::backend::StepModel;
use super::faults::{ClusterFaultPlan, FleetFault, ReplicaHealth};
use super::lane::ResumeState;
use super::metrics::Metrics;
use super::trace::{
    AttributionSummary, RequestTimeline, SpanEvent, TraceEvent, Tracer, DEFAULT_TRACE_RING,
};
use super::workload::{
    run_virtual_plan, run_virtual_plan_jobs, LenDist, OrphanJob, PlanJob, PlanResume,
    PoolInterrupt, VirtualConfig, VirtualReport, Workload,
};
use super::{Coordinator, Request, RequestHandle, TokenEvent};

/// SLO class of a request. Classification is structural: carrying a
/// deadline makes a request interactive (the deadline is its TTFT
/// budget); no deadline means batch (throughput-only, never shed).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SloTier {
    /// TTFT-bounded: admitted only when the projected queue delay fits
    /// the request's deadline budget; shed otherwise.
    Interactive,
    /// Throughput-only: always admitted (modulo pool-level KV
    /// rejection), never shed by the front-end.
    Batch,
}

impl SloTier {
    /// Classify a request by the presence of a deadline.
    pub fn classify(req: &Request) -> SloTier {
        if req.deadline_s.is_some() {
            SloTier::Interactive
        } else {
            SloTier::Batch
        }
    }

    /// Stable lowercase name for JSON/CLI surfaces.
    pub fn name(self) -> &'static str {
        match self {
            SloTier::Interactive => "interactive",
            SloTier::Batch => "batch",
        }
    }
}

/// CLI tier mix (`--slo-tier batch|interactive:<ttft_s>|mixed:<ttft_s>:<fraction>`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SloTierSpec {
    /// Every request is batch tier (no deadlines).
    Batch,
    /// Every request is interactive with this TTFT budget, seconds.
    Interactive {
        /// TTFT budget applied as each request's deadline.
        ttft_s: f64,
    },
    /// A seeded mix: `fraction` of requests are interactive with
    /// `ttft_s` budgets, the rest batch.
    Mixed {
        /// TTFT budget for the interactive share.
        ttft_s: f64,
        /// Interactive fraction in [0, 1].
        fraction: f64,
    },
}

impl SloTierSpec {
    /// Parse the CLI grammar. Misconfiguration is refused, not ignored.
    pub fn parse(s: &str) -> Result<SloTierSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let ttft = |v: &str| -> Result<f64, String> {
            let t: f64 =
                v.parse().map_err(|_| format!("--slo-tier: bad ttft '{v}'"))?;
            if !t.is_finite() || t <= 0.0 {
                return Err(format!("--slo-tier: ttft must be > 0, got '{v}'"));
            }
            Ok(t)
        };
        match parts.as_slice() {
            ["batch"] => Ok(SloTierSpec::Batch),
            ["interactive", t] => Ok(SloTierSpec::Interactive { ttft_s: ttft(t)? }),
            ["mixed", t, f] => {
                let fraction: f64 =
                    f.parse().map_err(|_| format!("--slo-tier: bad fraction '{f}'"))?;
                if !(0.0..=1.0).contains(&fraction) {
                    return Err(format!(
                        "--slo-tier: fraction must be in [0,1], got '{f}'"
                    ));
                }
                Ok(SloTierSpec::Mixed { ttft_s: ttft(t)?, fraction })
            }
            _ => Err(format!(
                "--slo-tier: want batch | interactive:<ttft_s> | \
                 mixed:<ttft_s>:<fraction>, got '{s}'"
            )),
        }
    }

    /// The (interactive fraction, TTFT budget) pair the workload
    /// generator consumes.
    pub fn mix(self) -> (f64, f64) {
        match self {
            SloTierSpec::Batch => (0.0, 0.0),
            SloTierSpec::Interactive { ttft_s } => (1.0, ttft_s),
            SloTierSpec::Mixed { ttft_s, fraction } => (fraction, ttft_s),
        }
    }
}

/// Autoscaling policy (`--autoscale min=..,max=..,interval=..,warmup=..,up=..,down=..`).
///
/// Evaluated on a fixed grid of `interval_s` ticks: the controller's
/// gauge is mean backlog seconds per active replica (how far each fluid
/// work horizon runs ahead of now). Above `up_backlog_s` it activates
/// one more replica — routable only after `warmup_s` — and below
/// `down_backlog_s` it drains the highest-indexed active replica
/// (in-flight work finishes; it just stops receiving).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// Floor on active replicas (>= 1).
    pub min_replicas: usize,
    /// Ceiling on active replicas.
    pub max_replicas: usize,
    /// Controller evaluation period, seconds.
    pub interval_s: f64,
    /// Delay before a newly activated replica accepts traffic, seconds
    /// (weight streaming / model load — scaling is never free).
    pub warmup_s: f64,
    /// Scale up when mean backlog-seconds per active replica exceeds
    /// this.
    pub up_backlog_s: f64,
    /// Scale down when mean backlog-seconds per active replica falls
    /// below this.
    pub down_backlog_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            interval_s: 0.25,
            warmup_s: 0.5,
            up_backlog_s: 0.5,
            down_backlog_s: 0.05,
        }
    }
}

impl AutoscaleConfig {
    /// Parse `key=value` pairs over the default config. Unknown keys
    /// and inconsistent bounds are refused, not ignored.
    pub fn parse(spec: &str) -> Result<AutoscaleConfig, String> {
        let mut cfg = AutoscaleConfig::default();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("--autoscale: want key=value, got '{part}'"))?;
            let f = || -> Result<f64, String> {
                val.parse().map_err(|_| format!("--autoscale: bad value '{val}' for '{key}'"))
            };
            match key.trim() {
                "min" => {
                    cfg.min_replicas = val
                        .parse()
                        .map_err(|_| format!("--autoscale: bad value '{val}' for 'min'"))?
                }
                "max" => {
                    cfg.max_replicas = val
                        .parse()
                        .map_err(|_| format!("--autoscale: bad value '{val}' for 'max'"))?
                }
                "interval" => cfg.interval_s = f()?,
                "warmup" => cfg.warmup_s = f()?,
                "up" => cfg.up_backlog_s = f()?,
                "down" => cfg.down_backlog_s = f()?,
                other => return Err(format!("--autoscale: unknown key '{other}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.min_replicas == 0 {
            return Err("--autoscale: min must be >= 1".into());
        }
        if self.max_replicas < self.min_replicas {
            return Err("--autoscale: max must be >= min".into());
        }
        if !(self.interval_s.is_finite() && self.interval_s > 0.0) {
            return Err("--autoscale: interval must be > 0".into());
        }
        if !(self.warmup_s.is_finite() && self.warmup_s >= 0.0) {
            return Err("--autoscale: warmup must be >= 0".into());
        }
        if !(self.up_backlog_s.is_finite() && self.up_backlog_s >= 0.0)
            || !(self.down_backlog_s.is_finite() && self.down_backlog_s >= 0.0)
        {
            return Err("--autoscale: up/down must be >= 0".into());
        }
        if self.down_backlog_s > self.up_backlog_s {
            return Err("--autoscale: down threshold must not exceed up".into());
        }
        Ok(())
    }
}

/// Cluster deployment configuration: N replicas of one pool config.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Initial replica count (>= 1). With autoscaling this is clamped
    /// into `[min_replicas, max_replicas]`.
    pub replicas: usize,
    /// The per-replica pool: worker count, slots, KV policy, step model
    /// — each replica is one full pool run by the unmodified machinery.
    pub pool: VirtualConfig,
    /// SLO admission: shed interactive arrivals whose projected queue
    /// delay exceeds their TTFT budget. Batch is never shed.
    pub shed: bool,
    /// Optional autoscaling policy (None = fixed fleet).
    pub autoscale: Option<AutoscaleConfig>,
    /// Default deadline applied to requests arriving without one
    /// (`--slo-tier interactive:<ttft_s>` on the server path). None
    /// leaves untagged requests batch tier.
    pub default_deadline_s: Option<f64>,
    /// Replica-level fault plan (inert by default): deterministic
    /// crash / partition / slow injection driven identically on both
    /// serving paths. See [`ClusterFaultPlan`].
    pub faults: ClusterFaultPlan,
    /// Deadline-fraction hedging for the interactive tier: when > 0,
    /// an admitted interactive arrival whose projected queue delay
    /// exceeds `hedge_fraction * deadline` is duplicated on the
    /// runner-up routable replica; the first usable stream wins and
    /// the loser is cancelled. 0 disables hedging.
    pub hedge_fraction: f64,
    /// Request-lifecycle tracing (off by default, strictly
    /// observational): every replica pool records [`RequestTimeline`]s
    /// and the fleet stitches them — with SLO-shed, failover, and hedge
    /// events — into [`ClusterReport::timelines`] plus per-tier
    /// attribution summaries.
    pub trace: bool,
}

impl ClusterConfig {
    /// A fixed fleet of `replicas` pools with SLO shedding enabled.
    pub fn new(replicas: usize, pool: VirtualConfig) -> ClusterConfig {
        ClusterConfig {
            replicas,
            pool,
            shed: true,
            autoscale: None,
            default_deadline_s: None,
            faults: ClusterFaultPlan::default(),
            hedge_fraction: 0.0,
            trace: false,
        }
    }
}

/// Arrival-intensity shape over the Poisson base rate: the generator
/// divides each exponential gap by `intensity(t)`, so an intensity of 2
/// doubles the instantaneous arrival rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalTrace {
    /// Constant intensity 1 (plain Poisson).
    Uniform,
    /// Sinusoidal day/night swing: `1 + depth * sin(2πt/period)`,
    /// floored at 0.05 so the rate never hits zero.
    Diurnal {
        /// Full day length, seconds (virtual).
        period_s: f64,
        /// Swing amplitude; 1.0 swings between ~0 and 2x.
        depth: f64,
    },
    /// A flash crowd: `magnification`x intensity inside
    /// `[at_s, at_s + dur_s)`, 1 outside.
    FlashCrowd {
        /// Burst start, seconds.
        at_s: f64,
        /// Burst duration, seconds.
        dur_s: f64,
        /// Intensity multiplier during the burst.
        magnification: f64,
    },
}

impl ArrivalTrace {
    /// Instantaneous intensity multiplier at time `t`.
    pub fn intensity(&self, t: f64) -> f64 {
        match *self {
            ArrivalTrace::Uniform => 1.0,
            ArrivalTrace::Diurnal { period_s, depth } => {
                let phase = 2.0 * std::f64::consts::PI * t / period_s.max(1e-9);
                (1.0 + depth * phase.sin()).max(0.05)
            }
            ArrivalTrace::FlashCrowd { at_s, dur_s, magnification } => {
                if t >= at_s && t < at_s + dur_s {
                    magnification.max(0.05)
                } else {
                    1.0
                }
            }
        }
    }

    /// Stable name for JSON/CLI surfaces.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalTrace::Uniform => "uniform",
            ArrivalTrace::Diurnal { .. } => "diurnal",
            ArrivalTrace::FlashCrowd { .. } => "flash_crowd",
        }
    }

    /// Parse `uniform | diurnal:<period_s>:<depth> | flash:<at_s>:<dur_s>:<mag>`.
    ///
    /// Naming hazard: `--trace` is the *arrival-trace shape* flag; the
    /// Perfetto lifecycle exporter is `--trace-out FILE`. Every error
    /// here points at the other flag so a mixed-up invocation
    /// self-diagnoses.
    pub fn parse(s: &str) -> Result<ArrivalTrace, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let f = |v: &str| -> Result<f64, String> {
            let x: f64 = v.parse().map_err(|_| {
                format!(
                    "--trace: bad number '{v}' (--trace is the arrival-trace \
                     shape; for Perfetto span export use --trace-out FILE)"
                )
            })?;
            if !x.is_finite() {
                return Err(format!("--trace: non-finite '{v}'"));
            }
            Ok(x)
        };
        match parts.as_slice() {
            ["uniform"] => Ok(ArrivalTrace::Uniform),
            ["diurnal", p, d] => Ok(ArrivalTrace::Diurnal { period_s: f(p)?, depth: f(d)? }),
            ["flash", at, dur, mag] => Ok(ArrivalTrace::FlashCrowd {
                at_s: f(at)?,
                dur_s: f(dur)?,
                magnification: f(mag)?,
            }),
            _ => Err(format!(
                "--trace: want uniform | diurnal:<period_s>:<depth> | \
                 flash:<at_s>:<dur_s>:<mag>, got '{s}' (--trace shapes arrival \
                 intensity; for Perfetto span export use --trace-out FILE)"
            )),
        }
    }
}

/// A tiered, trace-shaped workload: the base [`Workload`] generator
/// with arrival-intensity modulation and a seeded interactive/batch
/// split. Same seed, same plan, bit for bit.
#[derive(Clone, Debug)]
pub struct ClusterWorkload {
    /// Base rate, lengths, vocab, seed, request count.
    pub base: Workload,
    /// Arrival-intensity shape over the base Poisson rate.
    pub trace: ArrivalTrace,
    /// Fraction of requests tagged interactive (deadline-carrying).
    pub interactive_fraction: f64,
    /// TTFT budget (deadline) each interactive request carries, s.
    pub interactive_deadline_s: f64,
}

impl ClusterWorkload {
    /// Generate the request plan: `(arrival_s, request)` with
    /// non-decreasing arrivals, trace-modulated gaps, and per-request
    /// tier tags.
    pub fn generate(&self) -> Vec<(f64, Request)> {
        let mut rng = Rng::new(self.base.seed);
        let mut at = 0.0f64;
        (0..self.base.n_requests)
            .map(|i| {
                at += rng.exp(self.base.rate) / self.trace.intensity(at).max(1e-9);
                let p_len = self.base.prompt_len.sample(&mut rng);
                let o_len = self.base.output_len.sample(&mut rng).max(1);
                let prompt = (0..p_len.max(1))
                    .map(|_| rng.range(0, self.base.vocab) as i64)
                    .collect();
                let interactive = rng.bool(self.interactive_fraction);
                let req = Request {
                    model: self.base.model.clone(),
                    prompt,
                    max_new_tokens: o_len,
                    params: crate::numerics::SampleParams::greedy(),
                    eos_token: None,
                    seed: self.base.seed ^ i as u64,
                    deadline_s: if interactive {
                        Some(self.interactive_deadline_s)
                    } else {
                        None
                    },
                };
                (at, req)
            })
            .collect()
    }

    /// Check internal consistency (refused, not ignored).
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.interactive_fraction) {
            return Err("cluster workload: interactive fraction must be in [0,1]".into());
        }
        if self.interactive_fraction > 0.0
            && !(self.interactive_deadline_s.is_finite() && self.interactive_deadline_s > 0.0)
        {
            return Err("cluster workload: interactive deadline must be > 0".into());
        }
        Ok(())
    }
}

/// The front-end's verdict on one arrival.
enum Admission {
    Route { replica: usize, tier: SloTier, hedge: Option<usize> },
    Shed { tier: SloTier },
}

/// The per-arrival decision core shared VERBATIM by both serving paths
/// (the virtual sweep drives it on virtual seconds, the threaded
/// [`Cluster`] on wall seconds): tier classification, fluid work
/// horizons per replica, deadline-aware shedding, and the autoscale
/// controller. Pure arithmetic over arrival times — deterministic.
struct FrontEnd {
    /// Routable flag per replica slot (autoscale flips these).
    active: Vec<bool>,
    /// Earliest time each replica may receive traffic (warm-up).
    available_from: Vec<f64>,
    /// Fluid work horizon per replica: the virtual timestamp at which
    /// its accepted work is projected to drain.
    horizon: Vec<f64>,
    /// `(t, active_count)` at init and at every autoscale action.
    timeline: Vec<(f64, usize)>,
    /// Next controller evaluation is at `last_eval + interval`.
    last_eval: f64,
    shed: bool,
    autoscale: Option<AutoscaleConfig>,
    default_deadline_s: Option<f64>,
    /// Per-replica worker count (horizon advance divides by this).
    workers: f64,
    /// Resolved fused-batch cap for the amortized weight-stream term.
    max_batch: f64,
    step: StepModel,
    /// Replica-level fault plan (inert by default); the health state
    /// machine and advertised slow factors all derive from it.
    faults: ClusterFaultPlan,
    /// Interactive hedge trigger as a fraction of the deadline (0 off).
    hedge_fraction: f64,
    /// Ejection latch per replica: on the ejected → non-ejected edge
    /// (reinstatement after a partition heal) the stale horizon is
    /// restarted so the comeback replica is not instantly swamped.
    was_ejected: Vec<bool>,
}

impl FrontEnd {
    fn new(cc: &ClusterConfig) -> Result<FrontEnd, String> {
        if cc.replicas == 0 {
            return Err("cluster config needs >= 1 replica".into());
        }
        if let Some(a) = &cc.autoscale {
            a.validate()?;
        }
        let slots = cc
            .autoscale
            .as_ref()
            .map_or(cc.replicas, |a| a.max_replicas.max(cc.replicas));
        let initial = cc
            .autoscale
            .as_ref()
            .map_or(cc.replicas, |a| cc.replicas.clamp(a.min_replicas, a.max_replicas));
        let max_batch =
            if cc.pool.max_batch == 0 { cc.pool.max_active } else { cc.pool.max_batch };
        cc.faults.validate(slots).map_err(|e| e.to_string())?;
        if !(0.0..=1.0).contains(&cc.hedge_fraction) {
            return Err(format!(
                "cluster config: hedge fraction must be in [0, 1], got {}",
                cc.hedge_fraction
            ));
        }
        Ok(FrontEnd {
            active: (0..slots).map(|i| i < initial).collect(),
            available_from: vec![0.0; slots],
            horizon: vec![0.0; slots],
            timeline: vec![(0.0, initial)],
            last_eval: 0.0,
            shed: cc.shed,
            autoscale: cc.autoscale,
            default_deadline_s: cc.default_deadline_s,
            workers: cc.pool.workers.max(1) as f64,
            max_batch: max_batch.max(1) as f64,
            step: cc.pool.step,
            faults: cc.faults.clone(),
            hedge_fraction: cc.hedge_fraction,
            was_ejected: vec![false; slots],
        })
    }

    fn slots(&self) -> usize {
        self.active.len()
    }

    fn active_count(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Estimated service seconds one request adds to a replica (whole-
    /// pool view, so the caller divides by the worker count): a
    /// single-pass prefill plus per-token decode steps with the weight
    /// stream amortized across the fused batch — the same first-order
    /// terms [`StepModel`] charges the pools.
    fn request_cost_s(&self, req: &Request) -> f64 {
        let prompt = req.prompt.len().max(1) as f64;
        let out = req.max_new_tokens.max(1) as f64;
        let prefill = self.step.weight_stream_s
            + prompt * self.step.kv_read_s_per_pos
            + self.step.lane_overhead_s
            + self.step.sync_s;
        let avg_pos = prompt + out * 0.5;
        let per_token = (self.step.weight_stream_s + self.step.sync_s) / self.max_batch
            + avg_pos * self.step.kv_read_s_per_pos
            + self.step.lane_overhead_s;
        prefill + out * per_token
    }

    /// Run the autoscale controller over every whole evaluation tick up
    /// to `t`. Ejected replicas do not count as active capacity: their
    /// backlog is invisible to the controller and a substitute slot is
    /// activated through the normal warm-up path.
    fn advance(&mut self, t: f64) {
        let Some(a) = self.autoscale else { return };
        while self.last_eval + a.interval_s <= t {
            let te = self.last_eval + a.interval_s;
            self.last_eval = te;
            let counted = |fe: &FrontEnd, r: usize| {
                fe.active[r] && fe.faults.health_at(r, te) != ReplicaHealth::Ejected
            };
            let n_active = (0..self.slots()).filter(|&r| counted(self, r)).count();
            let backlog: f64 = (0..self.slots())
                .filter(|&r| counted(self, r))
                .map(|r| (self.horizon[r].max(self.available_from[r]) - te).max(0.0))
                .sum::<f64>()
                / n_active.max(1) as f64;
            if backlog > a.up_backlog_s && n_active < a.max_replicas {
                // Lowest inactive non-ejected slot; a previously
                // drained replica re-activates (horizon carried over).
                if let Some(r) = (0..self.slots()).find(|&r| {
                    !self.active[r]
                        && self.faults.health_at(r, te) != ReplicaHealth::Ejected
                }) {
                    self.active[r] = true;
                    self.available_from[r] = te + a.warmup_s;
                    self.horizon[r] = self.horizon[r].max(te);
                    self.timeline.push((te, n_active + 1));
                }
            } else if backlog < a.down_backlog_s && n_active > a.min_replicas {
                // Drain the highest counted slot: stops receiving, but
                // already-assigned work finishes. Ejected slots are
                // skipped — their flag stays up so reinstatement after
                // a heal restores them without a scale-up action.
                if let Some(r) = (0..self.slots()).rev().find(|&r| counted(self, r)) {
                    self.active[r] = false;
                    self.timeline.push((te, n_active - 1));
                }
            }
        }
    }

    /// Refresh the per-replica ejection latch at time `t`: on the
    /// ejected → non-ejected edge (probation after a partition heal)
    /// the replica's stale horizon is restarted at `t`, so the work it
    /// could not serve while cut off is not counted against it and it
    /// is not instantly swamped on reinstatement.
    fn note_health(&mut self, t: f64) {
        if !self.faults.is_active() {
            return;
        }
        for r in 0..self.slots() {
            let ejected = self.faults.health_at(r, t) == ReplicaHealth::Ejected;
            if self.was_ejected[r] && !ejected {
                self.horizon[r] = self.horizon[r].max(t);
            }
            self.was_ejected[r] = ejected;
        }
    }

    /// The least-delayed replica the plan lets us route to at `t`
    /// (active, routable, not `skip` — the hedge scan excludes the
    /// primary). Ties go to the lowest index.
    fn best_replica(&self, t: f64, skip: Option<usize>) -> Option<(f64, usize)> {
        let mut best: Option<(f64, usize)> = None;
        for r in 0..self.slots() {
            if !self.active[r] || Some(r) == skip || !self.faults.routable(r, t) {
                continue;
            }
            let ready = self.horizon[r].max(self.available_from[r]).max(t);
            let delay = ready - t;
            if best.map_or(true, |(bd, _)| delay < bd) {
                best = Some((delay, r));
            }
        }
        best
    }

    /// Decide one arrival at time `t`. Applies the default deadline (if
    /// configured and the request carries none), classifies the tier,
    /// picks the least-delayed *routable* replica (health-aware under a
    /// fault plan), sheds interactive arrivals whose projected delay
    /// blows the budget, selects a hedge replica when the projected
    /// delay crosses the hedge fraction of the deadline, and advances
    /// the chosen horizons by the request's estimated cost (inflated by
    /// the advertised slow factor of a detected-degraded replica).
    fn admit(&mut self, t: f64, req: &mut Request) -> Admission {
        self.advance(t);
        self.note_health(t);
        if req.deadline_s.is_none() {
            req.deadline_s = self.default_deadline_s;
        }
        let tier = SloTier::classify(req);
        let choice = self.best_replica(t, None).or_else(|| {
            // Every routable replica is gone (mass partition): rather
            // than drop the arrival, park it on the least-delayed
            // active replica that is at least not known dead — it
            // stalls until a heal instead of being lost outright.
            let mut best: Option<(f64, usize)> = None;
            for r in 0..self.slots() {
                if !self.active[r]
                    || self.faults.crash_at(r).map_or(false, |tc| t >= tc)
                {
                    continue;
                }
                let ready = self.horizon[r].max(self.available_from[r]).max(t);
                let delay = ready - t;
                if best.map_or(true, |(bd, _)| delay < bd) {
                    best = Some((delay, r));
                }
            }
            best
        });
        let Some((delay, r)) = choice else {
            // The whole active fleet is dead. Shedding is the only
            // honest verdict left (a batch shed here flags the
            // operator's plan, not a front-end bug).
            return Admission::Shed { tier };
        };
        if self.shed && tier == SloTier::Interactive {
            if let Some(budget) = req.deadline_s {
                if delay > budget {
                    return Admission::Shed { tier };
                }
            }
        }
        // Hedge before charging the primary so the runner-up scan sees
        // pre-admission horizons on both.
        let mut hedge = None;
        if tier == SloTier::Interactive && self.hedge_fraction > 0.0 {
            if let Some(budget) = req.deadline_s {
                if delay > self.hedge_fraction * budget {
                    if let Some((_, h)) = self.best_replica(t, Some(r)) {
                        hedge = Some(h);
                    }
                }
            }
        }
        let cost = self.request_cost_s(req) / self.workers;
        let start = self.horizon[r].max(self.available_from[r]).max(t);
        self.horizon[r] = start + cost * self.faults.advertised_slow_factor(r, t);
        if let Some(h) = hedge {
            // The duplicate is real work: the runner-up's horizon is
            // charged too, so hedges price themselves out under load.
            let hs = self.horizon[h].max(self.available_from[h]).max(t);
            self.horizon[h] = hs + cost * self.faults.advertised_slow_factor(h, t);
        }
        Admission::Route { replica: r, tier, hedge }
    }
}

/// One request's cluster-level lifetime (virtual path).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterRecord {
    /// Index in the cluster plan.
    pub request_id: usize,
    /// SLO tier the front-end classified it into.
    pub tier: SloTier,
    /// Replica that served it (None = shed at the front-end).
    pub replica: Option<usize>,
    /// Shed by SLO admission (always before any token).
    pub shed: bool,
    /// Arrival time, seconds.
    pub arrival_s: f64,
    /// First-token emission time (= arrival for shed/rejected).
    pub first_token_s: f64,
    /// Completion time.
    pub done_s: f64,
    /// The generated stream (empty for shed/rejected/failed).
    pub tokens: Vec<i64>,
    /// Emission time per token (same length as `tokens`).
    pub token_times: Vec<f64>,
    /// The TTFT budget it carried (None = batch).
    pub deadline_s: Option<f64>,
    /// Finished on a different replica than first assigned: its stream
    /// was salvaged and resumed after a crash or partition ejection.
    pub failed_over: bool,
    /// Was duplicated by deadline-fraction hedging (set whichever copy
    /// won the race).
    pub hedged: bool,
}

impl ClusterRecord {
    /// Completed means a non-empty stream reached the client.
    pub fn completed(&self) -> bool {
        !self.tokens.is_empty()
    }

    /// Whether a completed interactive stream met its TTFT budget
    /// (batch and budget-less records count attained when completed).
    pub fn attained(&self) -> bool {
        self.completed()
            && self
                .deadline_s
                .map_or(true, |d| self.first_token_s - self.arrival_s <= d)
    }
}

/// Results of one virtual cluster run. Pure function of
/// (plan, config) — two runs are bit-identical.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Offered request rate, requests/second (base rate).
    pub offered_rate: f64,
    /// Per-request lifetimes, indexed by plan order.
    pub records: Vec<ClusterRecord>,
    /// Per-replica pool reports (None = replica never received work).
    pub replicas: Vec<Option<VirtualReport>>,
    /// `(t, active_replicas)` at init and every autoscale action.
    pub replica_timeline: Vec<(f64, usize)>,
    /// Peak simultaneously active replicas.
    pub peak_replicas: usize,
    /// Request-lifecycle timelines, one per arrival in plan order
    /// (empty unless [`ClusterConfig::trace`]): the winner replica's
    /// pool timeline rebased to the cluster request id, stitched with
    /// fleet-level routing/failover/hedge events; admission sheds get
    /// a minimal `Submitted → Shed{slo_admission}` pair.
    pub timelines: Vec<RequestTimeline>,
    /// Interactive-tier latency attribution rollup (None unless
    /// tracing is on).
    pub attribution_interactive: Option<AttributionSummary>,
    /// Batch-tier latency attribution rollup (None unless tracing is
    /// on).
    pub attribution_batch: Option<AttributionSummary>,
    /// Interactive arrivals offered.
    pub submitted_interactive: usize,
    /// Batch arrivals offered.
    pub submitted_batch: usize,
    /// Interactive arrivals shed by SLO admission.
    pub shed_interactive: usize,
    /// Batch arrivals shed (the policy never sheds batch; nonzero
    /// flags a front-end bug).
    pub shed_batch: usize,
    /// Interactive requests that completed their stream.
    pub completed_interactive: usize,
    /// Batch requests that completed their stream.
    pub completed_batch: usize,
    /// Interactive completions whose TTFT met the budget.
    pub attained_interactive: usize,
    /// Cluster makespan, seconds (max over replicas and arrivals).
    pub wall_s: f64,
    /// Achieved output tokens/second over the makespan.
    pub tokens_per_s: f64,
    /// KV blocks still held across every replica at drain — must be 0.
    pub end_kv_blocks_in_use: usize,
    /// Replica crash points the fault plan injected.
    pub replica_crashes: usize,
    /// Partition windows the fault plan injected.
    pub partitions: usize,
    /// In-flight streams re-dispatched onto another replica.
    pub streams_failed_over: usize,
    /// Interactive arrivals duplicated by deadline-fraction hedging.
    pub hedges_issued: usize,
    /// Hedge duplicates that beat their primary to the first token.
    pub hedges_won: usize,
}

impl ClusterReport {
    /// SLO attainment for a tier, over everything *offered* to that
    /// tier (shed requests count against attainment — that is the
    /// honest fleet-level number). 1.0 when the tier saw no traffic.
    pub fn attainment(&self, tier: SloTier) -> f64 {
        let (num, den) = match tier {
            SloTier::Interactive => {
                (self.attained_interactive, self.submitted_interactive)
            }
            SloTier::Batch => (self.completed_batch, self.submitted_batch),
        };
        if den == 0 {
            1.0
        } else {
            num as f64 / den as f64
        }
    }

    /// Fraction of a tier's arrivals shed at admission.
    pub fn shed_fraction(&self, tier: SloTier) -> f64 {
        let (num, den) = match tier {
            SloTier::Interactive => (self.shed_interactive, self.submitted_interactive),
            SloTier::Batch => (self.shed_batch, self.submitted_batch),
        };
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }
}

/// Replay a tiered workload through the virtual cluster.
pub fn run_virtual_cluster(
    wl: &ClusterWorkload,
    cc: &ClusterConfig,
) -> Result<ClusterReport, String> {
    wl.validate()?;
    run_virtual_cluster_plan(&wl.base.model, wl.base.vocab, wl.base.rate, wl.generate(), cc)
}

/// One hop of one request's lifetime on one replica: the bookkeeping
/// entry parallel to a [`PlanJob`] in a replica's job list. The serial
/// identifies the hop globally; a request's *canonical* hop (the one
/// whose record represents it) moves on every failover re-dispatch,
/// while superseded hops stay in place so earlier fault analyses remain
/// valid (the job lists are append-only per replica).
struct Hop {
    /// Cluster plan index of the request this hop serves.
    rid: usize,
    /// Globally unique, monotonically increasing hop id.
    serial: u64,
    /// True for a hedge duplicate (never re-dispatched: the primary
    /// chain owns delivery).
    hedge: bool,
}

/// Insert a job into a replica's time-sorted job list, keeping the hop
/// ledger parallel.
fn insert_job(jobs: &mut Vec<PlanJob>, hops: &mut Vec<Hop>, job: PlanJob, hop: Hop) {
    let pos = jobs.partition_point(|j| j.at_s <= job.at_s);
    jobs.insert(pos, job);
    hops.insert(pos, hop);
}

/// [`run_virtual_cluster`] over an explicit `(arrival_s, request)`
/// plan. The front-end makes every admission/shed/hedge/autoscale
/// decision in arrival order, then each replica's assigned jobs run
/// through the single-pool
/// [`run_virtual_plan_jobs`][super::workload::run_virtual_plan_jobs]
/// (global arrival timestamps preserved, so all replica clocks share
/// one timeline) and the per-pool records are merged back by hop.
///
/// Under a [`ClusterFaultPlan`] the run becomes a deterministic
/// multi-round salvage loop: fleet fault edges (crash instants and
/// partition-detection ejections) are processed strictly in time
/// order; at each edge the source replica's pool is (re)simulated, the
/// streams it can no longer finish are identified, and each is
/// re-dispatched to a healthy replica as a resume job carrying the
/// token prefix a client had already seen plus a reconstructed sampler
/// (exact for greedy streams — decode ignores the RNG). Because a
/// re-dispatch only ever inserts work at or after the edge time and the
/// pool simulation is causal, earlier analyses are never invalidated;
/// the whole run is a pure function of (plan, config) and two runs are
/// bit-identical.
pub fn run_virtual_cluster_plan(
    model: &str,
    vocab: usize,
    offered_rate: f64,
    plan: Vec<(f64, Request)>,
    cc: &ClusterConfig,
) -> Result<ClusterReport, String> {
    if plan.windows(2).any(|w| w[0].0 > w[1].0) {
        return Err("cluster plan arrivals must be non-decreasing".into());
    }
    let mut fe = FrontEnd::new(cc)?;
    let slots = fe.slots();
    let n = plan.len();
    let mut plan_end = 0.0f64;
    let mut tiers: Vec<(SloTier, Option<f64>)> = Vec::with_capacity(n);
    let mut records: Vec<Option<ClusterRecord>> = (0..n).map(|_| None).collect();

    // Append-only per-replica job lists with a parallel hop ledger.
    let mut jobs: Vec<Vec<PlanJob>> = (0..slots).map(|_| Vec::new()).collect();
    let mut hops: Vec<Vec<Hop>> = (0..slots).map(|_| Vec::new()).collect();
    let mut next_serial = 0u64;
    // Canonical (final) hop serial per request; u64::MAX = shed.
    let mut canonical: Vec<u64> = vec![u64::MAX; n];
    let mut hedge_serial: Vec<Option<u64>> = vec![None; n];
    let mut failed_over: Vec<bool> = vec![false; n];
    let mut hedges_issued = 0usize;
    let mut streams_failed_over = 0usize;

    for (rid, (t, mut req)) in plan.into_iter().enumerate() {
        plan_end = plan_end.max(t);
        match fe.admit(t, &mut req) {
            Admission::Shed { tier } => {
                records[rid] = Some(ClusterRecord {
                    request_id: rid,
                    tier,
                    replica: None,
                    shed: true,
                    arrival_s: t,
                    first_token_s: t,
                    done_s: t,
                    tokens: Vec::new(),
                    token_times: Vec::new(),
                    deadline_s: req.deadline_s,
                    failed_over: false,
                    hedged: false,
                });
                tiers.push((tier, req.deadline_s));
            }
            Admission::Route { replica, tier, hedge } => {
                tiers.push((tier, req.deadline_s));
                if let Some(h) = hedge {
                    hedges_issued += 1;
                    let s = next_serial;
                    next_serial += 1;
                    hedge_serial[rid] = Some(s);
                    insert_job(
                        &mut jobs[h],
                        &mut hops[h],
                        PlanJob::fresh(t, req.clone()),
                        Hop { rid, serial: s, hedge: true },
                    );
                }
                let s = next_serial;
                next_serial += 1;
                canonical[rid] = s;
                insert_job(
                    &mut jobs[replica],
                    &mut hops[replica],
                    PlanJob::fresh(t, req),
                    Hop { rid, serial: s, hedge: false },
                );
            }
        }
    }

    // Per-replica pool physics: a slow replica's step model is scaled
    // by its factor; crash and partition windows become the pool's
    // interrupt schedule (overlapping windows merged — a freeze shifts
    // busy work by the window length, so overlap would double-charge).
    let mut pools: Vec<VirtualConfig> = Vec::with_capacity(slots);
    let mut interrupts: Vec<PoolInterrupt> = Vec::with_capacity(slots);
    for r in 0..slots {
        let mut p = cc.pool.clone();
        p.trace |= cc.trace;
        let f = cc.faults.slow_factor(r);
        if f > 1.0 {
            p.step.weight_stream_s *= f;
            p.step.kv_read_s_per_pos *= f;
            p.step.lane_overhead_s *= f;
            p.step.sync_s *= f;
            p.step.host_restore_s_per_token *= f;
        }
        let mut it = PoolInterrupt::default();
        it.halt_at = cc.faults.crash_at(r);
        for (from, until) in cc.faults.partitions_of(r) {
            match it.freezes.last_mut() {
                Some(last) if from <= last.1 => last.1 = last.1.max(until),
                _ => it.freezes.push((from, until)),
            }
        }
        pools.push(p);
        interrupts.push(it);
    }

    fn refresh(
        r: usize,
        model: &str,
        vocab: usize,
        offered_rate: f64,
        jobs: &[Vec<PlanJob>],
        pools: &[VirtualConfig],
        interrupts: &[PoolInterrupt],
        dirty: &mut [bool],
        runs: &mut [Option<(VirtualReport, Vec<OrphanJob>)>],
    ) -> Result<(), String> {
        if !dirty[r] {
            return Ok(());
        }
        dirty[r] = false;
        runs[r] = if jobs[r].is_empty() {
            None
        } else {
            Some(run_virtual_plan_jobs(
                model,
                vocab,
                offered_rate,
                jobs[r].clone(),
                &pools[r],
                &interrupts[r],
            )?)
        };
        Ok(())
    }

    let mut dirty = vec![true; slots];
    let mut runs: Vec<Option<(VirtualReport, Vec<OrphanJob>)>> =
        (0..slots).map(|_| None).collect();
    // Fleet-level failover edges, recorded for timeline stitching:
    // (rid, event time, crashed source, salvage target).
    let mut fleet_failovers: Vec<(usize, f64, usize, usize)> = Vec::new();
    for (te, fault) in cc.faults.fault_events() {
        let src = match fault {
            FleetFault::Crash { replica } | FleetFault::Eject { replica } => replica,
        };
        refresh(
            src, model, vocab, offered_rate, &jobs, &pools, &interrupts, &mut dirty,
            &mut runs,
        )?;
        // Collect the streams that must leave the source at this edge.
        // A hop that was already superseded by an earlier edge is
        // stale — a stream is only ever re-dispatched from its
        // canonical home. Hedge duplicates are never re-homed: the
        // primary chain owns delivery, the duplicate just loses.
        let mut moves: Vec<(usize, PlanJob)> = Vec::new();
        match fault {
            FleetFault::Crash { .. } => {
                if let Some((_, orphans)) = &runs[src] {
                    for o in orphans {
                        let hop = &hops[src][o.rid];
                        if hop.hedge || canonical[hop.rid] != hop.serial {
                            continue;
                        }
                        moves.push((
                            hop.rid,
                            PlanJob {
                                at_s: te.max(o.arrival_s),
                                arrival_s: o.arrival_s,
                                request: o.request.clone(),
                                resume: o.resume.clone(),
                            },
                        ));
                    }
                }
            }
            FleetFault::Eject { .. } => {
                // Ejection happens one probe interval after partition
                // onset; tokens emitted before the cut are what the
                // client actually received.
                let cut = te - cc.faults.probe_interval_s;
                if let Some((rep, _)) = &runs[src] {
                    for (local, rec) in rep.records.iter().enumerate() {
                        let hop = &hops[src][local];
                        let job = &jobs[src][local];
                        if hop.hedge
                            || canonical[hop.rid] != hop.serial
                            || job.at_s >= te
                            || rec.done_s <= cut
                        {
                            continue;
                        }
                        let delivered =
                            rec.token_times.iter().take_while(|&&tt| tt < cut).count();
                        let resume = if delivered == 0 {
                            None
                        } else {
                            Some(PlanResume {
                                state: ResumeState {
                                    generated: rec.tokens[..delivered].to_vec(),
                                    // Greedy decode ignores the RNG, so
                                    // a fresh sampler continues the
                                    // stream bit-identically (the real
                                    // sampler is stranded behind the
                                    // partition).
                                    sampler: Sampler::new(job.request.seed),
                                },
                                first_token_s: Some(rec.first_token_s),
                                token_times: rec.token_times[..delivered].to_vec(),
                            })
                        };
                        moves.push((
                            hop.rid,
                            PlanJob {
                                at_s: te,
                                arrival_s: job.arrival_s,
                                request: job.request.clone(),
                                resume,
                            },
                        ));
                    }
                }
            }
        }
        if moves.is_empty() {
            continue;
        }
        // Spread the orphans round-robin over the routable survivors;
        // if every survivor is ejected too, fall back to any replica
        // not known dead (work parks there until its heal).
        let healthy: Vec<usize> =
            (0..slots).filter(|&r| r != src && cc.faults.routable(r, te)).collect();
        let fallback: Vec<usize> = (0..slots)
            .filter(|&r| {
                r != src && cc.faults.crash_at(r).map_or(true, |tc| te < tc)
            })
            .collect();
        let targets = if healthy.is_empty() { fallback } else { healthy };
        if targets.is_empty() {
            // Nowhere to go: the streams are lost; their canonical
            // records stay as the halted pool's failed placeholders.
            continue;
        }
        for (k, (rid, job)) in moves.into_iter().enumerate() {
            let tr = targets[k % targets.len()];
            let s = next_serial;
            next_serial += 1;
            canonical[rid] = s;
            failed_over[rid] = true;
            streams_failed_over += 1;
            if cc.trace {
                fleet_failovers.push((rid, job.at_s, src, tr));
            }
            insert_job(&mut jobs[tr], &mut hops[tr], job, Hop { rid, serial: s, hedge: false });
            dirty[tr] = true;
        }
    }
    for r in 0..slots {
        refresh(
            r, model, vocab, offered_rate, &jobs, &pools, &interrupts, &mut dirty,
            &mut runs,
        )?;
    }

    // Merge: each routed request's record comes from its canonical hop;
    // a hedge duplicate wins only when it completed and either beat the
    // primary to the first token or the primary failed outright.
    let mut primary: Vec<Option<(usize, usize)>> = vec![None; n];
    let mut hedge_rec: Vec<Option<(usize, usize)>> = vec![None; n];
    for r in 0..slots {
        for (local, hop) in hops[r].iter().enumerate() {
            if hop.hedge {
                if hedge_serial[hop.rid] == Some(hop.serial) {
                    hedge_rec[hop.rid] = Some((r, local));
                }
            } else if canonical[hop.rid] == hop.serial {
                primary[hop.rid] = Some((r, local));
            }
        }
    }
    let mut hedges_won = 0usize;
    // Winner hop per routed rid (replica, local index), kept for trace
    // timeline stitching after the merge.
    let mut winner_hop: Vec<Option<(usize, usize)>> = vec![None; n];
    for rid in 0..n {
        if records[rid].is_some() {
            continue; // shed at admission
        }
        let (pr, plocal) = primary[rid].expect("every routed arrival keeps a canonical hop");
        let prec = &runs[pr].as_ref().expect("canonical hop was simulated").0.records[plocal];
        let mut winner = (pr, plocal, prec);
        if let Some((hr, hlocal)) = hedge_rec[rid] {
            let hrec = &runs[hr].as_ref().expect("hedge hop was simulated").0.records[hlocal];
            let h_done = !hrec.tokens.is_empty();
            let p_done = !prec.tokens.is_empty();
            if h_done && (!p_done || hrec.first_token_s < prec.first_token_s) {
                winner = (hr, hlocal, hrec);
                hedges_won += 1;
            }
        }
        let (wr, wlocal, rec) = winner;
        winner_hop[rid] = Some((wr, wlocal));
        let (tier, deadline_s) = tiers[rid];
        records[rid] = Some(ClusterRecord {
            request_id: rid,
            tier,
            replica: Some(wr),
            shed: false,
            arrival_s: rec.arrival_s,
            first_token_s: rec.first_token_s,
            done_s: rec.done_s,
            tokens: rec.tokens.clone(),
            token_times: rec.token_times.clone(),
            deadline_s,
            failed_over: failed_over[rid],
            hedged: hedge_serial[rid].is_some(),
        });
    }
    // Trace stitching: every arrival gets a cluster-level timeline.
    // Routed requests clone their winner hop's pool timeline (rebased
    // to the cluster rid) and splice in the fleet's own decisions —
    // replica routing, crash/eject failovers, hedge wins — by
    // timestamp; admission sheds get a minimal Submitted→Shed pair.
    let mut timelines: Vec<RequestTimeline> = Vec::new();
    let mut att_interactive = AttributionSummary::new();
    let mut att_batch = AttributionSummary::new();
    if cc.trace {
        for rid in 0..n {
            let rec = records[rid].as_ref().expect("every arrival recorded");
            let deadline_s = rec.deadline_s.unwrap_or(f64::INFINITY);
            let mut tl = RequestTimeline::new(rid as u64);
            match winner_hop[rid] {
                None => {
                    tl.push(rec.arrival_s, SpanEvent::Submitted { deadline_s });
                    tl.push(rec.arrival_s, SpanEvent::Shed { reason: "slo_admission".into() });
                }
                Some((wr, wlocal)) => {
                    let pool_tls =
                        &runs[wr].as_ref().expect("winner hop was simulated").0.timelines;
                    match pool_tls.iter().find(|t| t.request_id == wlocal as u64) {
                        Some(pt) => {
                            tl.events = pt.events.clone();
                            // The fleet routed before the pool saw the
                            // job: a replica-level Routed right after
                            // the pool's Submitted.
                            let t0 = tl.events[0].t_s;
                            let ev = SpanEvent::Routed { worker: wr };
                            tl.events.insert(1, TraceEvent { t_s: t0, ev });
                        }
                        None => {
                            // The stream was lost on a halted pool (no
                            // terminal pool timeline survives).
                            tl.push(rec.arrival_s, SpanEvent::Submitted { deadline_s });
                            tl.push(rec.arrival_s, SpanEvent::Routed { worker: wr });
                            tl.push(
                                rec.done_s.max(rec.arrival_s),
                                SpanEvent::Failed { cause: "lost_in_failover".into() },
                            );
                        }
                    }
                    for &(frid, t_ev, from, to) in &fleet_failovers {
                        if frid == rid {
                            insert_fleet_event(
                                &mut tl,
                                t_ev,
                                SpanEvent::Failover { from, to },
                            );
                        }
                    }
                    if rec.hedged && rec.completed() {
                        insert_fleet_event(
                            &mut tl,
                            rec.first_token_s,
                            SpanEvent::Hedged { winner: wr },
                        );
                    }
                }
            }
            tl.seal();
            if let Some(a) = &tl.attribution {
                match rec.tier {
                    SloTier::Interactive => att_interactive.add(a),
                    SloTier::Batch => att_batch.add(a),
                }
            }
            timelines.push(tl);
        }
    }

    let replicas: Vec<Option<VirtualReport>> =
        runs.into_iter().map(|r| r.map(|(rep, _)| rep)).collect();

    let records: Vec<ClusterRecord> =
        records.into_iter().map(|r| r.expect("every arrival recorded")).collect();
    let wall_s = replicas
        .iter()
        .flatten()
        .map(|vr| vr.wall_s)
        .fold(plan_end, f64::max);
    let total_tokens: usize = records.iter().map(|r| r.tokens.len()).sum();
    let count =
        |f: &dyn Fn(&ClusterRecord) -> bool| records.iter().filter(|r| f(r)).count();
    let peak_replicas = fe.timeline.iter().map(|&(_, n)| n).max().unwrap_or(0);
    Ok(ClusterReport {
        offered_rate,
        submitted_interactive: count(&|r| r.tier == SloTier::Interactive),
        submitted_batch: count(&|r| r.tier == SloTier::Batch),
        shed_interactive: count(&|r| r.tier == SloTier::Interactive && r.shed),
        shed_batch: count(&|r| r.tier == SloTier::Batch && r.shed),
        completed_interactive: count(&|r| r.tier == SloTier::Interactive && r.completed()),
        completed_batch: count(&|r| r.tier == SloTier::Batch && r.completed()),
        attained_interactive: count(&|r| r.tier == SloTier::Interactive && r.attained()),
        wall_s,
        tokens_per_s: if wall_s > 0.0 { total_tokens as f64 / wall_s } else { 0.0 },
        end_kv_blocks_in_use: replicas
            .iter()
            .flatten()
            .map(|vr| vr.end_kv_blocks_in_use)
            .sum(),
        replica_crashes: cc.faults.crashes.len(),
        partitions: cc.faults.partitions.len(),
        streams_failed_over,
        hedges_issued,
        hedges_won,
        replica_timeline: fe.timeline.clone(),
        peak_replicas,
        timelines,
        attribution_interactive: cc.trace.then_some(att_interactive),
        attribution_batch: cc.trace.then_some(att_batch),
        replicas,
        records,
    })
}

/// Splice a fleet-level event into a pool timeline by timestamp: after
/// every existing event at the same or earlier time (so the leading
/// `Submitted` stays first), and always before the terminal event.
fn insert_fleet_event(tl: &mut RequestTimeline, t_s: f64, ev: SpanEvent) {
    let cut = tl.events.len().saturating_sub(1);
    let pos = tl.events[..cut]
        .partition_point(|e| e.t_s <= t_s)
        .clamp(1.min(cut), cut);
    tl.events.insert(pos, TraceEvent { t_s, ev });
}

/// Outcome of a threaded cluster submission.
pub enum Submitted {
    /// Routed to a replica; stream via the handle.
    Handle {
        /// Replica index that received the request.
        replica: usize,
        /// The tier the front-end classified it into.
        tier: SloTier,
        /// Streaming handle from the replica's coordinator.
        handle: RequestHandle,
    },
    /// Shed at admission — no tokens were (or will be) generated.
    Shed {
        /// The tier of the shed arrival (always interactive under the
        /// shipped policy).
        tier: SloTier,
    },
}

/// The threaded cluster dispatcher: live [`Coordinator`] replicas
/// behind the SAME [`FrontEnd`] decision core the virtual sweep runs,
/// driven on wall seconds (or on caller-supplied timestamps via
/// [`Cluster::submit_at`], which makes front-end decisions
/// reproducible across paths).
pub struct Cluster {
    model: String,
    replicas: Vec<Coordinator>,
    fe: Mutex<FrontEnd>,
    epoch: Instant,
    /// The replica-level fault plan (inert by default). Fault edges
    /// fire on *planned* timestamps fed through [`Cluster::submit_at`],
    /// never wall time, so a rerun replays the same recovery.
    faults: ClusterFaultPlan,
    hedge_fraction: f64,
    chaos: Mutex<ChaosState>,
    /// Live wrapped streams by pump id, for fault-time failover.
    streams: Arc<Mutex<HashMap<u64, Arc<StreamShared>>>>,
    next_stream: AtomicU64,
    /// Fleet-level metrics: per-tier submitted/shed/done/attained
    /// counters plus fault rollups (pool-level serving metrics live on
    /// each replica).
    pub metrics: Arc<Metrics>,
    /// Fleet-level flight recorder (enabled by [`ClusterConfig::trace`]):
    /// SLO sheds always get a timeline; full stream lifecycles are
    /// recorded when the pump wrapper is active (fault plan or hedging).
    /// The unwrapped fast path hands out raw replica handles, so its
    /// per-request detail lives on each replica coordinator's tracer.
    pub tracer: Arc<Tracer>,
    /// Fleet-assigned trace ids (replica-local request ids can collide
    /// across replicas).
    trace_ids: AtomicU64,
}

/// Dispatcher-side fault bookkeeping (the threaded analog of the
/// virtual salvage loop's event cursor).
struct ChaosState {
    /// Fleet fault edges, sorted by time (from
    /// [`ClusterFaultPlan::fault_events`]).
    events: Vec<(f64, FleetFault)>,
    /// Next unprocessed edge.
    next: usize,
    /// Round-robin cursor for failover target choice.
    rr: usize,
    /// Latest planned timestamp seen (drives health gauges).
    now_s: f64,
}

/// State shared between the dispatcher and one stream's pump thread:
/// enough to fail the stream over (what was delivered, how to
/// resubmit) and to hand the pump its replacement upstream.
struct StreamShared {
    request: Request,
    /// Replica currently serving the stream.
    replica: Mutex<usize>,
    /// Tokens already forwarded to the client — the dedupe horizon for
    /// exactly-once delivery and the resume prefix for failover.
    delivered: Mutex<Vec<i64>>,
    /// Replacement upstream installed by failover; the pump swaps to
    /// it and drops the old handle (the abandoned replica sees the
    /// client disconnect and releases the lane's KV).
    switch: Mutex<Option<RequestHandle>>,
    done: AtomicBool,
    /// Fleet tracer hookup: `(tracer, fleet trace id, fleet epoch)`.
    /// None when tracing is off.
    trace: Option<(Arc<Tracer>, u64, Instant)>,
}

impl StreamShared {
    /// Record a fleet-level trace event for this stream, stamped on
    /// the fleet's wall clock (no-op without a tracer hookup).
    fn trace_ev(&self, ev: SpanEvent) {
        if let Some((tracer, fid, epoch)) = &self.trace {
            tracer.record(*fid, epoch.elapsed().as_secs_f64(), ev);
        }
    }
}

impl Cluster {
    /// Build a fleet: one [`Coordinator`] per replica slot from the
    /// caller's factory (which must register `model`'s pool). With
    /// autoscaling, all `max_replicas` coordinators exist up front —
    /// activation is a routing decision; warm-up is charged by the
    /// front-end.
    pub fn threaded(
        cc: &ClusterConfig,
        model: &str,
        mut build: impl FnMut() -> Coordinator,
    ) -> Result<Cluster, String> {
        let fe = FrontEnd::new(cc)?;
        let replicas: Vec<Coordinator> = (0..fe.slots()).map(|_| build()).collect();
        for c in &replicas {
            if !c.models().contains(&model.to_string()) {
                return Err(format!("replica factory did not register model '{model}'"));
            }
        }
        Ok(Cluster {
            model: model.to_string(),
            replicas,
            fe: Mutex::new(fe),
            epoch: Instant::now(),
            faults: cc.faults.clone(),
            hedge_fraction: cc.hedge_fraction,
            chaos: Mutex::new(ChaosState {
                events: cc.faults.fault_events(),
                next: 0,
                rr: 0,
                now_s: 0.0,
            }),
            streams: Arc::new(Mutex::new(HashMap::new())),
            next_stream: AtomicU64::new(0),
            metrics: Arc::new(Metrics::new()),
            tracer: Arc::new(Tracer::new(cc.trace, DEFAULT_TRACE_RING)),
            trace_ids: AtomicU64::new(0),
        })
    }

    /// The model this fleet serves.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Total replica slots (active or not).
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Currently routable replicas.
    pub fn active_replicas(&self) -> usize {
        self.fe.lock().unwrap().active_count()
    }

    /// `(t, active_count)` autoscale history (seconds since the fleet
    /// epoch).
    pub fn replica_timeline(&self) -> Vec<(f64, usize)> {
        self.fe.lock().unwrap().timeline.clone()
    }

    /// The live replica coordinators (for per-replica gauges).
    pub fn replicas(&self) -> &[Coordinator] {
        &self.replicas
    }

    /// Submit with an explicit front-end timestamp (seconds on the
    /// caller's clock; must be non-decreasing across calls for the
    /// fluid horizons to mean anything). [`run_cluster_open_loop`]
    /// passes the *planned* arrival time, which makes shed/route/
    /// autoscale decisions bit-identical to the virtual path's.
    pub fn submit_at(&self, at_s: f64, request: Request) -> Result<Submitted, String> {
        self.process_fault_events(at_s);
        let mut request = request;
        let decision = self.fe.lock().unwrap().admit(at_s, &mut request);
        match decision {
            Admission::Shed { tier } => {
                self.metrics.on_tier_submit(tier);
                self.metrics.on_tier_shed(tier);
                if self.tracer.enabled() {
                    let fid = self.trace_ids.fetch_add(1, Ordering::Relaxed);
                    let now = self.epoch.elapsed().as_secs_f64();
                    let deadline_s = request.deadline_s.unwrap_or(f64::INFINITY);
                    self.tracer.record(fid, now, SpanEvent::Submitted { deadline_s });
                    self.tracer.record(
                        fid,
                        now,
                        SpanEvent::Shed { reason: "slo_admission".into() },
                    );
                }
                Ok(Submitted::Shed { tier })
            }
            Admission::Route { replica, tier, hedge } => {
                self.metrics.on_tier_submit(tier);
                if !self.wraps_streams() {
                    // No fault plan, no hedging: the raw replica handle
                    // is the stream — zero added machinery (fleet-level
                    // tracing rides on the pump wrapper; per-request
                    // detail lives on the replica's own tracer).
                    let handle = self.replicas[replica].submit(request)?;
                    return Ok(Submitted::Handle { replica, tier, handle });
                }
                let trace_hook = if self.tracer.enabled() {
                    let fid = self.trace_ids.fetch_add(1, Ordering::Relaxed);
                    let now = self.epoch.elapsed().as_secs_f64();
                    let deadline_s = request.deadline_s.unwrap_or(f64::INFINITY);
                    self.tracer.record(fid, now, SpanEvent::Submitted { deadline_s });
                    self.tracer.record(fid, now, SpanEvent::Routed { worker: replica });
                    Some((Arc::clone(&self.tracer), fid, self.epoch))
                } else {
                    None
                };
                let primary = self.replicas[replica].submit(request.clone())?;
                let hedged = match hedge {
                    Some(h) => {
                        self.metrics.on_hedge_issued();
                        Some((h, self.replicas[h].submit(request.clone())?))
                    }
                    None => None,
                };
                let handle = self.pump(replica, request, primary, hedged, trace_hook)?;
                Ok(Submitted::Handle { replica, tier, handle })
            }
        }
    }

    /// Whether streams need the pump/failover wrapper (any active fault
    /// plan or hedging). Without either, submission hands out the raw
    /// replica handle — bit-for-bit the pre-chaos behavior.
    fn wraps_streams(&self) -> bool {
        self.faults.is_active() || self.hedge_fraction > 0.0
    }

    /// Per-replica health verdict at the latest planned timestamp the
    /// dispatcher has seen (true = not ejected). Wall-independent: the
    /// clock only advances through [`Cluster::submit_at`].
    pub fn replica_health(&self) -> Vec<bool> {
        let now = self.chaos.lock().unwrap().now_s;
        (0..self.replicas.len())
            .map(|r| self.faults.health_at(r, now) != ReplicaHealth::Ejected)
            .collect()
    }

    /// Fire every fleet fault edge whose planned time has passed: bump
    /// the rollup counters and fail over each live stream attached to
    /// the faulted replica. Failover snapshots the delivered prefix,
    /// resubmits on a routable survivor via the pool's resume path
    /// (greedy purity makes a fresh sampler exact), and installs the
    /// replacement upstream for the stream's pump to swap in.
    fn process_fault_events(&self, at_s: f64) {
        if !self.faults.is_active() {
            return;
        }
        loop {
            let (te, fault) = {
                let mut chaos = self.chaos.lock().unwrap();
                chaos.now_s = chaos.now_s.max(at_s);
                if chaos.next >= chaos.events.len() || chaos.events[chaos.next].0 > at_s {
                    return;
                }
                let e = chaos.events[chaos.next];
                chaos.next += 1;
                e
            };
            let src = match fault {
                FleetFault::Crash { replica } => {
                    self.metrics.on_replica_crash();
                    replica
                }
                FleetFault::Eject { replica } => {
                    self.metrics.on_partition();
                    replica
                }
            };
            let victims: Vec<Arc<StreamShared>> = {
                let streams = self.streams.lock().unwrap();
                streams
                    .values()
                    .filter(|s| {
                        *s.replica.lock().unwrap() == src && !s.done.load(Ordering::Relaxed)
                    })
                    .cloned()
                    .collect()
            };
            let targets: Vec<usize> = (0..self.replicas.len())
                .filter(|&r| r != src && self.faults.routable(r, te))
                .collect();
            if targets.is_empty() {
                continue;
            }
            for sh in victims {
                let tr = {
                    let mut chaos = self.chaos.lock().unwrap();
                    let k = chaos.rr;
                    chaos.rr += 1;
                    targets[k % targets.len()]
                };
                let delivered = sh.delivered.lock().unwrap().clone();
                let resumed = if delivered.is_empty() {
                    self.replicas[tr].submit(sh.request.clone())
                } else {
                    self.replicas[tr].submit_resumed(
                        sh.request.clone(),
                        ResumeState {
                            generated: delivered,
                            sampler: Sampler::new(sh.request.seed),
                        },
                    )
                };
                if let Ok(h) = resumed {
                    *sh.replica.lock().unwrap() = tr;
                    *sh.switch.lock().unwrap() = Some(h);
                    self.metrics.on_stream_failed_over();
                    sh.trace_ev(SpanEvent::Failover { from: src, to: tr });
                }
            }
        }
    }

    /// Wrap a routed stream in a pump thread that owns the upstream
    /// handle(s) and forwards events to the client with exactly-once
    /// delivery across failover swaps and hedge races.
    fn pump(
        &self,
        replica: usize,
        request: Request,
        primary: RequestHandle,
        hedge: Option<(usize, RequestHandle)>,
        trace: Option<(Arc<Tracer>, u64, Instant)>,
    ) -> Result<RequestHandle, String> {
        let (tx, rx) = std::sync::mpsc::channel();
        let request_id = primary.request_id;
        let shared = Arc::new(StreamShared {
            request,
            replica: Mutex::new(replica),
            delivered: Mutex::new(Vec::new()),
            switch: Mutex::new(None),
            done: AtomicBool::new(false),
            trace,
        });
        let sid = self.next_stream.fetch_add(1, Ordering::Relaxed);
        self.streams.lock().unwrap().insert(sid, Arc::clone(&shared));
        let registry = Arc::clone(&self.streams);
        let metrics = Arc::clone(&self.metrics);
        std::thread::Builder::new()
            .name("lpu-cluster-pump".into())
            .spawn(move || {
                pump_stream(&shared, primary, hedge, tx, &metrics);
                shared.done.store(true, Ordering::Relaxed);
                registry.lock().unwrap().remove(&sid);
            })
            .map_err(|e| e.to_string())?;
        Ok(RequestHandle { request_id, events: rx })
    }

    /// Submit on the fleet's wall clock (the server path).
    pub fn submit(&self, request: Request) -> Result<Submitted, String> {
        self.submit_at(self.epoch.elapsed().as_secs_f64(), request)
    }

    /// Record a completed stream's tier outcome (`attained` = its TTFT
    /// met the deadline budget; pass true for batch).
    pub fn note_done(&self, tier: SloTier, attained: bool) {
        self.metrics.on_tier_done(tier, attained);
    }

    /// Shut every replica down (in-flight requests finish).
    pub fn shutdown(self) {
        for c in self.replicas {
            c.shutdown();
        }
    }
}

/// Forward one wrapped stream to the client. Exactly-once delivery:
/// only the token whose index equals the delivered count is forwarded,
/// so a failover resume (which replays the prefix) or a hedge duplicate
/// can never duplicate or reorder tokens — and by greedy purity a
/// skipped duplicate is value-identical to the token already sent.
fn pump_stream(
    shared: &Arc<StreamShared>,
    mut upstream: RequestHandle,
    mut hedge: Option<(usize, RequestHandle)>,
    client: Sender<TokenEvent>,
    metrics: &Metrics,
) {
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let poll = std::time::Duration::from_millis(2);
    loop {
        // A failover installed a replacement upstream: swap to it. The
        // old handle drops here — the abandoned replica sees the client
        // disconnect and releases the lane's KV.
        if let Some(next) = shared.switch.lock().unwrap().take() {
            upstream = next;
        }
        // Race the hedge until either side produces a usable event.
        if hedge.is_some() {
            let polled = hedge.as_ref().map(|(_, h)| h.events.try_recv());
            match polled {
                Some(Ok(ev @ (TokenEvent::Token { .. } | TokenEvent::Done { .. }))) => {
                    // The duplicate won: it becomes the stream and the
                    // primary is cancelled by dropping its handle.
                    metrics.on_hedge_won();
                    let (hr, h) = hedge.take().expect("hedge present");
                    *shared.replica.lock().unwrap() = hr;
                    shared.trace_ev(SpanEvent::Hedged { winner: hr });
                    upstream = h;
                    if !deliver(shared, &client, ev) {
                        return;
                    }
                    continue;
                }
                Some(Ok(TokenEvent::Error { .. }) | Err(TryRecvError::Disconnected)) => {
                    hedge = None;
                }
                Some(Err(TryRecvError::Empty)) | None => {}
            }
        }
        match upstream.events.recv_timeout(poll) {
            Ok(TokenEvent::Error { request_id, message }) => {
                if shared.switch.lock().unwrap().is_some() {
                    continue; // failover in flight: swap next iteration
                }
                if let Some((hr, h)) = hedge.take() {
                    // The primary collapsed before the race settled —
                    // promote the hedge.
                    *shared.replica.lock().unwrap() = hr;
                    shared.trace_ev(SpanEvent::Hedged { winner: hr });
                    upstream = h;
                    continue;
                }
                shared.trace_ev(SpanEvent::Failed { cause: message.clone() });
                let _ = client.send(TokenEvent::Error { request_id, message });
                return;
            }
            Ok(ev) => {
                // First usable event on the primary: the hedge lost;
                // dropping its handle cancels it and releases its KV.
                hedge = None;
                if !deliver(shared, &client, ev) {
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                if shared.switch.lock().unwrap().is_some() {
                    continue;
                }
                if let Some((hr, h)) = hedge.take() {
                    *shared.replica.lock().unwrap() = hr;
                    shared.trace_ev(SpanEvent::Hedged { winner: hr });
                    upstream = h;
                    continue;
                }
                shared.trace_ev(SpanEvent::Failed {
                    cause: "replica stream closed mid-flight".into(),
                });
                let _ = client.send(TokenEvent::Error {
                    request_id: upstream.request_id,
                    message: "replica stream closed mid-flight".into(),
                });
                return;
            }
        }
    }
}

/// The pump's forwarding core: dedupe tokens by delivered count,
/// re-emit `Done`/`Error` verbatim. Returns false once the stream is
/// finished.
fn deliver(shared: &StreamShared, client: &Sender<TokenEvent>, ev: TokenEvent) -> bool {
    match ev {
        TokenEvent::Token { request_id, index, token } => {
            let mut d = shared.delivered.lock().unwrap();
            if index == d.len() {
                d.push(token);
                shared.trace_ev(SpanEvent::DecodeStep);
                let _ = client.send(TokenEvent::Token { request_id, index, token });
            }
            true
        }
        done @ TokenEvent::Done { .. } => {
            shared.trace_ev(SpanEvent::Finished);
            let _ = client.send(done);
            false
        }
        TokenEvent::Error { request_id, message } => {
            shared.trace_ev(SpanEvent::Failed { cause: message.clone() });
            let _ = client.send(TokenEvent::Error { request_id, message });
            false
        }
    }
}

/// Results of one threaded cluster load run.
#[derive(Clone, Debug)]
pub struct ClusterLoadReport {
    /// Offered base rate, requests/second.
    pub offered_rate: f64,
    /// Requests whose stream completed.
    pub completed: usize,
    /// Requests shed by SLO admission.
    pub shed: usize,
    /// Requests that ended in a visible error (pool-level shed or
    /// failure).
    pub failed: usize,
    /// Wall time of the run, seconds.
    pub wall_s: f64,
    /// Generated tokens per request in plan order (empty = shed or
    /// failed) — the cross-path stream-identity surface.
    pub token_streams: Vec<Vec<i64>>,
    /// Wall-clock TTFT over completed requests, seconds.
    pub ttft: Summary,
}

/// Run a tiered workload against a live threaded [`Cluster`],
/// honoring planned arrival times on the wall clock while feeding the
/// front-end the *planned* timestamps (so admission decisions match
/// the virtual path bit for bit). Mirrors
/// [`run_open_loop`][super::workload::run_open_loop].
pub fn run_cluster_open_loop(
    cluster: &Cluster,
    wl: &ClusterWorkload,
) -> Result<ClusterLoadReport, String> {
    wl.validate()?;
    type PerReq = Result<(f64, Vec<i64>), String>;
    fn collect(submitted: Instant, handle: RequestHandle) -> PerReq {
        let mut first: Option<f64> = None;
        for ev in handle.events.iter() {
            match ev {
                TokenEvent::Token { index, .. } => {
                    if index == 0 {
                        first = Some(submitted.elapsed().as_secs_f64());
                    }
                }
                TokenEvent::Done { tokens, .. } => {
                    let ttft =
                        first.unwrap_or_else(|| submitted.elapsed().as_secs_f64());
                    return Ok((ttft, tokens));
                }
                TokenEvent::Error { message, .. } => return Err(message),
            }
        }
        Err("stream closed without completion".into())
    }

    let plan = wl.generate();
    let n = plan.len();
    let t0 = Instant::now();
    let mut shed = 0usize;
    let mut collectors: Vec<(usize, SloTier, Option<f64>, std::thread::JoinHandle<PerReq>)> =
        Vec::new();
    for (rid, (at_s, req)) in plan.into_iter().enumerate() {
        if let Some(sleep) =
            std::time::Duration::from_secs_f64(at_s).checked_sub(t0.elapsed())
        {
            std::thread::sleep(sleep);
        }
        let deadline = req.deadline_s;
        let submitted = Instant::now();
        match cluster.submit_at(at_s, req)? {
            Submitted::Shed { .. } => shed += 1,
            Submitted::Handle { tier, handle, .. } => {
                collectors.push((
                    rid,
                    tier,
                    deadline,
                    std::thread::Builder::new()
                        .name("lpu-cluster-collect".into())
                        .spawn(move || collect(submitted, handle))
                        .map_err(|e| e.to_string())?,
                ));
            }
        }
    }
    let mut streams: Vec<Vec<i64>> = vec![Vec::new(); n];
    let mut ttfts = Vec::new();
    let mut completed = 0usize;
    let mut failed = 0usize;
    for (rid, tier, deadline, c) in collectors {
        match c.join().map_err(|_| "collector panicked")? {
            Ok((ttft, tokens)) => {
                cluster.note_done(tier, deadline.map_or(true, |d| ttft <= d));
                streams[rid] = tokens;
                ttfts.push(ttft);
                completed += 1;
            }
            Err(_) => failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(ClusterLoadReport {
        offered_rate: wl.base.rate,
        completed,
        shed,
        failed,
        wall_s,
        token_streams: streams,
        ttft: if ttfts.is_empty() { Summary::of(&[0.0]) } else { Summary::of(&ttfts) },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LpuConfig;
    use crate::coordinator::{BackendFactory, CoordinatorConfig, SchedulerPolicy};
    use crate::model::by_name;

    fn step_model() -> StepModel {
        StepModel::from_config(&by_name("opt-1.3b").unwrap(), &LpuConfig::asic_819gbs(), 1)
    }

    fn cwl(rate: f64, n: usize, frac: f64, deadline: f64, trace: ArrivalTrace) -> ClusterWorkload {
        ClusterWorkload {
            base: Workload {
                model: "opt-tiny".into(),
                rate,
                n_requests: n,
                prompt_len: LenDist::Uniform(1, 6),
                output_len: LenDist::Fixed(5),
                vocab: 512,
                seed: 77,
            },
            trace,
            interactive_fraction: frac,
            interactive_deadline_s: deadline,
        }
    }

    fn pool(workers: usize, max_active: usize) -> VirtualConfig {
        VirtualConfig::new(SchedulerPolicy::RoundRobin, workers, max_active, step_model())
    }

    #[test]
    fn tier_classification_follows_deadline() {
        let mut r = Request::greedy("m", vec![1], 4);
        assert_eq!(SloTier::classify(&r), SloTier::Batch);
        r.deadline_s = Some(0.5);
        assert_eq!(SloTier::classify(&r), SloTier::Interactive);
        assert_eq!(SloTier::Interactive.name(), "interactive");
        assert_eq!(SloTier::Batch.name(), "batch");
    }

    #[test]
    fn slo_tier_spec_grammar() {
        assert_eq!(SloTierSpec::parse("batch").unwrap(), SloTierSpec::Batch);
        assert_eq!(
            SloTierSpec::parse("interactive:0.5").unwrap(),
            SloTierSpec::Interactive { ttft_s: 0.5 }
        );
        assert_eq!(
            SloTierSpec::parse("mixed:0.5:0.25").unwrap(),
            SloTierSpec::Mixed { ttft_s: 0.5, fraction: 0.25 }
        );
        assert!(SloTierSpec::parse("interactive").is_err());
        assert!(SloTierSpec::parse("interactive:-1").is_err());
        assert!(SloTierSpec::parse("mixed:0.5:1.5").is_err());
        assert!(SloTierSpec::parse("gold").is_err());
        assert_eq!(SloTierSpec::Mixed { ttft_s: 0.5, fraction: 0.25 }.mix(), (0.25, 0.5));
    }

    #[test]
    fn autoscale_spec_grammar() {
        let a = AutoscaleConfig::parse("min=2,max=6,interval=0.1,warmup=1.5,up=0.8,down=0.1")
            .unwrap();
        assert_eq!((a.min_replicas, a.max_replicas), (2, 6));
        assert_eq!((a.interval_s, a.warmup_s), (0.1, 1.5));
        assert_eq!((a.up_backlog_s, a.down_backlog_s), (0.8, 0.1));
        // Partial specs inherit defaults.
        let d = AutoscaleConfig::parse("max=8").unwrap();
        assert_eq!(d.max_replicas, 8);
        assert_eq!(d.min_replicas, AutoscaleConfig::default().min_replicas);
        // Misconfiguration is refused, not ignored.
        assert!(AutoscaleConfig::parse("min=0").is_err());
        assert!(AutoscaleConfig::parse("min=4,max=2").is_err());
        assert!(AutoscaleConfig::parse("interval=0").is_err());
        assert!(AutoscaleConfig::parse("up=0.1,down=0.5").is_err());
        assert!(AutoscaleConfig::parse("turbo=9").is_err());
        assert!(AutoscaleConfig::parse("warmup=abc").is_err());
    }

    #[test]
    fn arrival_traces_shape_intensity() {
        assert_eq!(ArrivalTrace::Uniform.intensity(123.0), 1.0);
        let d = ArrivalTrace::Diurnal { period_s: 4.0, depth: 1.0 };
        assert!((d.intensity(1.0) - 2.0).abs() < 1e-9, "peak at quarter period");
        assert!(d.intensity(3.0) <= 0.06, "trough floored above zero");
        let f = ArrivalTrace::FlashCrowd { at_s: 1.0, dur_s: 2.0, magnification: 8.0 };
        assert_eq!(f.intensity(0.5), 1.0);
        assert_eq!(f.intensity(1.5), 8.0);
        assert_eq!(f.intensity(3.5), 1.0);
        assert_eq!(ArrivalTrace::parse("uniform").unwrap(), ArrivalTrace::Uniform);
        assert_eq!(
            ArrivalTrace::parse("diurnal:60:0.9").unwrap(),
            ArrivalTrace::Diurnal { period_s: 60.0, depth: 0.9 }
        );
        assert_eq!(
            ArrivalTrace::parse("flash:5:2:10").unwrap(),
            ArrivalTrace::FlashCrowd { at_s: 5.0, dur_s: 2.0, magnification: 10.0 }
        );
        assert!(ArrivalTrace::parse("bursty").is_err());
        assert!(ArrivalTrace::parse("diurnal:60").is_err());
    }

    #[test]
    fn cluster_workload_generator_is_deterministic_and_tiered() {
        let wl = cwl(200.0, 400, 0.5, 0.5, ArrivalTrace::Uniform);
        let a = wl.generate();
        let b = wl.generate();
        assert_eq!(a.len(), 400);
        for ((ta, ra), (tb, rb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(ra.prompt, rb.prompt);
            assert_eq!(ra.deadline_s, rb.deadline_s);
        }
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
        let interactive = a.iter().filter(|(_, r)| r.deadline_s.is_some()).count();
        assert!(
            (120..=280).contains(&interactive),
            "tier split ~50%, got {interactive}/400"
        );
    }

    #[test]
    fn flash_crowd_compresses_gaps_inside_burst() {
        let base = cwl(100.0, 600, 0.0, 0.0, ArrivalTrace::Uniform).generate();
        let flash = cwl(
            100.0,
            600,
            0.0,
            0.0,
            ArrivalTrace::FlashCrowd { at_s: 1.0, dur_s: 2.0, magnification: 10.0 },
        )
        .generate();
        // Identical seed: the burst squeezes more arrivals into [1, 3).
        let in_window = |plan: &[(f64, Request)]| {
            plan.iter().filter(|(t, _)| (1.0..3.0).contains(t)).count()
        };
        assert!(
            in_window(&flash) > in_window(&base) * 3,
            "flash {} !>> base {}",
            in_window(&flash),
            in_window(&base)
        );
    }

    #[test]
    fn single_replica_no_shed_cluster_matches_plain_pool_run() {
        // The degenerate cluster IS the pool: same records, wrapped.
        let wl = cwl(2000.0, 60, 0.5, 30.0, ArrivalTrace::Uniform);
        let vc = pool(2, 4);
        let mut cc = ClusterConfig::new(1, vc.clone());
        cc.shed = false;
        let cr = run_virtual_cluster(&wl, &cc).unwrap();
        let plan = wl.generate();
        let vr = run_virtual_plan("opt-tiny", 512, 2000.0, plan, &vc).unwrap();
        assert_eq!(cr.records.len(), vr.records.len());
        for (c, v) in cr.records.iter().zip(&vr.records) {
            assert_eq!(c.tokens, v.tokens);
            assert_eq!(c.first_token_s, v.first_token_s);
            assert_eq!(c.done_s, v.done_s);
            assert_eq!(c.replica, Some(0));
            assert!(!c.shed);
        }
        assert_eq!(cr.shed_interactive + cr.shed_batch, 0);
        assert_eq!(cr.peak_replicas, 1);
        assert_eq!(cr.end_kv_blocks_in_use, 0);
    }

    #[test]
    fn cluster_runs_are_bit_identical() {
        let wl = cwl(3000.0, 120, 0.6, 0.05, ArrivalTrace::Diurnal { period_s: 0.2, depth: 0.8 });
        let mut cc = ClusterConfig::new(2, pool(1, 4));
        cc.autoscale = Some(AutoscaleConfig {
            max_replicas: 3,
            interval_s: 0.01,
            ..AutoscaleConfig::default()
        });
        let a = run_virtual_cluster(&wl, &cc).unwrap();
        let b = run_virtual_cluster(&wl, &cc).unwrap();
        assert_eq!(a.records, b.records);
        assert_eq!(a.replica_timeline, b.replica_timeline);
        assert_eq!(a.wall_s, b.wall_s);
    }

    #[test]
    fn shed_happens_only_before_first_token() {
        // Overload a tiny fleet with tight budgets: sheds must occur,
        // and every shed record is empty — no mid-stream drops.
        let wl = cwl(20_000.0, 200, 1.0, 0.01, ArrivalTrace::Uniform);
        let cc = ClusterConfig::new(1, pool(1, 2));
        let r = run_virtual_cluster(&wl, &cc).unwrap();
        assert!(r.shed_interactive > 0, "overload must shed");
        for rec in &r.records {
            if rec.shed {
                assert!(rec.tokens.is_empty() && rec.token_times.is_empty());
                assert_eq!(rec.replica, None);
                assert_eq!(rec.first_token_s, rec.arrival_s);
            }
        }
        assert_eq!(r.shed_batch, 0, "batch is never shed");
    }

    #[test]
    fn shedding_protects_admitted_interactive_ttft() {
        // At heavy overload, SLO admission keeps the *admitted*
        // interactive requests inside their budget; without shedding
        // the queue grows without bound and attainment collapses.
        let wl = cwl(5_000.0, 300, 1.0, 0.05, ArrivalTrace::Uniform);
        let mut shed_on = ClusterConfig::new(1, pool(1, 4));
        shed_on.shed = true;
        let mut shed_off = shed_on.clone();
        shed_off.shed = false;
        let on = run_virtual_cluster(&wl, &shed_on).unwrap();
        let off = run_virtual_cluster(&wl, &shed_off).unwrap();
        assert!(on.shed_interactive > 0);
        assert!(
            on.attainment(SloTier::Interactive) > off.attainment(SloTier::Interactive),
            "shed attainment {} !> no-shed {}",
            on.attainment(SloTier::Interactive),
            off.attainment(SloTier::Interactive)
        );
        // Completed streams agree request-for-request with the no-shed
        // run (greedy purity: placement never changes tokens).
        for (a, b) in on.records.iter().zip(&off.records) {
            if a.completed() && b.completed() {
                assert_eq!(a.tokens, b.tokens);
            }
        }
    }

    #[test]
    fn autoscaler_rides_a_flash_crowd_and_drains_after() {
        let wl = cwl(
            800.0,
            400,
            0.0,
            0.0,
            ArrivalTrace::FlashCrowd { at_s: 0.5, dur_s: 1.0, magnification: 12.0 },
        );
        let mut cc = ClusterConfig::new(1, pool(1, 4));
        cc.autoscale = Some(AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 4,
            interval_s: 0.05,
            warmup_s: 0.1,
            up_backlog_s: 0.2,
            down_backlog_s: 0.02,
        });
        let r = run_virtual_cluster(&wl, &cc).unwrap();
        assert!(r.peak_replicas > 1, "burst must trigger scale-up");
        assert!(
            r.replica_timeline.last().unwrap().1 < r.peak_replicas,
            "post-burst drain must scale back down: {:?}",
            r.replica_timeline
        );
        // Scale-up is never free: a warmed replica's first request
        // cannot arrive before its activation + warmup.
        for (rid, rec) in r.records.iter().enumerate() {
            if let Some(rep) = rec.replica {
                if rep > 0 {
                    let activated = r
                        .replica_timeline
                        .iter()
                        .find(|&&(_, n)| n > rep)
                        .map(|&(t, _)| t)
                        .unwrap_or(0.0);
                    assert!(
                        rec.arrival_s >= activated,
                        "request {rid} routed to replica {rep} before activation"
                    );
                }
            }
        }
        assert_eq!(r.end_kv_blocks_in_use, 0);
    }

    #[test]
    fn more_replicas_cut_makespan_under_backlog() {
        let wl = cwl(50_000.0, 160, 0.0, 0.0, ArrivalTrace::Uniform);
        let one = ClusterConfig::new(1, pool(1, 4));
        let four = ClusterConfig::new(4, pool(1, 4));
        let r1 = run_virtual_cluster(&wl, &one).unwrap();
        let r4 = run_virtual_cluster(&wl, &four).unwrap();
        assert!(
            r4.wall_s < r1.wall_s * 0.5,
            "4 replicas {} !< 0.5 * 1 replica {}",
            r4.wall_s,
            r1.wall_s
        );
        // Streams identical regardless of replica count.
        for (a, b) in r1.records.iter().zip(&r4.records) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn threaded_cluster_front_end_matches_virtual_decisions() {
        // Feed the threaded dispatcher the planned timestamps: the
        // shared FrontEnd must shed/route exactly like the virtual run.
        let wl = cwl(20_000.0, 40, 1.0, 0.01, ArrivalTrace::Uniform);
        let cc = ClusterConfig::new(1, pool(1, 2));
        let virt = run_virtual_cluster(&wl, &cc).unwrap();
        let cluster = Cluster::threaded(&cc, "opt-tiny", || {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 2,
                policy: SchedulerPolicy::RoundRobin,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            c
        })
        .unwrap();
        for (rid, (at_s, req)) in wl.generate().into_iter().enumerate() {
            match cluster.submit_at(at_s, req).unwrap() {
                Submitted::Shed { .. } => {
                    assert!(virt.records[rid].shed, "request {rid} shed only on threaded")
                }
                Submitted::Handle { replica, .. } => {
                    assert!(!virt.records[rid].shed, "request {rid} shed only on virtual");
                    assert_eq!(Some(replica), virt.records[rid].replica);
                }
            }
        }
        let s = cluster.metrics.snapshot();
        assert_eq!(s.tier_interactive_submitted, 40);
        assert_eq!(s.tier_interactive_shed as usize, virt.shed_interactive);
        cluster.shutdown();
    }

    #[test]
    fn threaded_factory_must_register_model() {
        let cc = ClusterConfig::new(1, pool(1, 2));
        let err = Cluster::threaded(&cc, "opt-tiny", || {
            Coordinator::new(CoordinatorConfig::default())
        })
        .map(|c| c.shutdown())
        .unwrap_err();
        assert!(err.contains("did not register"), "{err}");
    }

    fn replica_factory() -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 2,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
        c
    }

    #[test]
    fn virtual_failover_preserves_streams_and_leaks_no_kv() {
        // A crash plus a detected partition mid-run: every stream must
        // still complete, bit-identical to the fault-free fleet, with
        // zero KV held at drain — and the whole recovery must replay
        // identically on a rerun.
        let wl = cwl(3000.0, 60, 0.5, 30.0, ArrivalTrace::Uniform);
        let mut cc = ClusterConfig::new(3, pool(1, 4));
        cc.faults =
            ClusterFaultPlan::parse("probe=0.05,crash=0@0.005,partition=1@0.02..0.3")
                .unwrap();
        let faulty = run_virtual_cluster(&wl, &cc).unwrap();
        let mut clean_cc = cc.clone();
        clean_cc.faults = ClusterFaultPlan::default();
        let clean = run_virtual_cluster(&wl, &clean_cc).unwrap();
        assert_eq!(faulty.replica_crashes, 1);
        assert_eq!(faulty.partitions, 1);
        assert!(faulty.streams_failed_over > 0, "crash at 5ms must orphan work");
        assert_eq!(faulty.end_kv_blocks_in_use, 0);
        assert_eq!(faulty.records.len(), clean.records.len());
        for (f, c) in faulty.records.iter().zip(&clean.records) {
            assert!(f.completed(), "request {} lost under faults", f.request_id);
            assert_eq!(
                f.tokens, c.tokens,
                "request {} stream changed under faults",
                f.request_id
            );
        }
        let rerun = run_virtual_cluster(&wl, &cc).unwrap();
        assert_eq!(faulty.records, rerun.records, "recovery must be rerun-identical");
        assert_eq!(faulty.streams_failed_over, rerun.streams_failed_over);
    }

    #[test]
    fn virtual_hedging_duplicates_interactive_without_changing_streams() {
        let wl = cwl(5000.0, 40, 1.0, 5.0, ArrivalTrace::Uniform);
        let mut cc = ClusterConfig::new(2, pool(1, 4));
        cc.faults = ClusterFaultPlan::parse("slow=0x8").unwrap();
        cc.hedge_fraction = 0.01;
        let r = run_virtual_cluster(&wl, &cc).unwrap();
        assert!(r.hedges_issued > 0, "backlogged interactive arrivals must hedge");
        assert!(r.hedges_won <= r.hedges_issued);
        assert_eq!(
            r.records.iter().filter(|rec| rec.hedged).count(),
            r.hedges_issued,
            "hedged flags must match the issue counter"
        );
        assert_eq!(r.end_kv_blocks_in_use, 0, "losing duplicates must release KV");
        let mut nh = cc.clone();
        nh.hedge_fraction = 0.0;
        let base = run_virtual_cluster(&wl, &nh).unwrap();
        for (a, b) in r.records.iter().zip(&base.records) {
            if a.completed() && b.completed() {
                assert_eq!(a.tokens, b.tokens, "hedging changed stream {}", a.request_id);
            }
        }
        let rerun = run_virtual_cluster(&wl, &cc).unwrap();
        assert_eq!(r.records, rerun.records);
        assert_eq!(r.hedges_won, rerun.hedges_won);
    }

    #[test]
    fn stream_pump_is_transparent_when_no_fault_fires() {
        // An armed-but-never-firing plan routes every stream through
        // the pump wrapper; token delivery must be indistinguishable
        // from the unwrapped path.
        let wl = cwl(20_000.0, 30, 0.0, 0.0, ArrivalTrace::Uniform);
        let mut cc = ClusterConfig::new(2, pool(1, 2));
        cc.faults = ClusterFaultPlan::parse("crash=0@1000000").unwrap();
        let mut clean_cc = cc.clone();
        clean_cc.faults = ClusterFaultPlan::default();
        let base = run_virtual_cluster(&wl, &clean_cc).unwrap();
        let cluster = Cluster::threaded(&cc, "opt-tiny", replica_factory).unwrap();
        let lr = run_cluster_open_loop(&cluster, &wl).unwrap();
        assert_eq!(lr.failed, 0);
        assert_eq!(lr.completed, 30);
        for (rid, rec) in base.records.iter().enumerate() {
            assert_eq!(lr.token_streams[rid], rec.tokens, "stream {rid} diverged");
        }
        cluster.shutdown();
    }

    #[test]
    fn threaded_crash_failover_completes_streams_exactly_once() {
        // Kill replica 0 a third of the way through (on the planned
        // clock): every stream still completes, token values match the
        // fault-free virtual baseline (exactly-once: no duplicates, no
        // reorders), and the crash is visible in the fleet counters.
        let wl = cwl(800.0, 24, 0.0, 0.0, ArrivalTrace::Uniform);
        let mut cc = ClusterConfig::new(2, pool(1, 2));
        cc.faults = ClusterFaultPlan::parse("crash=0@0.01").unwrap();
        let mut clean_cc = cc.clone();
        clean_cc.faults = ClusterFaultPlan::default();
        let base = run_virtual_cluster(&wl, &clean_cc).unwrap();
        let cluster = Cluster::threaded(&cc, "opt-tiny", replica_factory).unwrap();
        let lr = run_cluster_open_loop(&cluster, &wl).unwrap();
        assert_eq!(lr.failed, 0, "failover must not surface stream errors");
        assert_eq!(lr.completed, 24);
        for (rid, rec) in base.records.iter().enumerate() {
            assert_eq!(lr.token_streams[rid], rec.tokens, "stream {rid} diverged");
        }
        let snap = cluster.metrics.snapshot();
        assert_eq!(snap.replica_crashes, 1);
        assert_eq!(cluster.replica_health(), vec![false, true]);
        cluster.shutdown();
    }
}
