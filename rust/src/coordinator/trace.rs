//! Deterministic request-lifecycle tracing shared by both serving paths.
//!
//! Every request carries a timeline of [`SpanEvent`]s — `Submitted →
//! Routed → Admitted | Shed{reason} → PrefillSpan* → DecodeStep* →
//! Preempted → Restored | Recomputed → Retry* → Failover → Hedged →
//! Finished | Failed{cause}` — recorded by the virtual event loop (on
//! the virtual clock) and the threaded worker loop (wall offsets from
//! the pool epoch). Timestamps differ across the two drivers, but the
//! per-seed event *sequence* (kinds + integer/float payloads) is
//! bit-identical: both paths emit from the same shared lane-core
//! decision points, extending the standing stream-identity invariant
//! (see `tests/invariants.rs::prop_trace_noninterference`).
//!
//! On top of the raw timelines sit three consumers:
//!
//! * [`Attribution`] — per-request latency decomposition whose seven
//!   components sum *bitwise* to the measured `ttft_s + decode_s`
//!   (residual construction: `decode_gap_s` absorbs float slack last in
//!   the canonical [`Attribution::component_sum`] order).
//! * [`perfetto_json`] — a Chrome/Perfetto `trace_events` exporter
//!   (`--trace-out FILE`): one track per worker/replica, one flow per
//!   request, instants for sheds/faults/hedges.
//! * [`Tracer`] — a bounded flight recorder for the server: a ring of
//!   the last-N completed timelines plus a shed-and-deadline-miss "why"
//!   digest, drained by the `trace` server op alongside `metrics`.
//!
//! Tracing is strictly observational: with the recorder off every hook
//! is an early-return no-op, and the noninterference property pins that
//! streams, counters, and report fields are bit-identical either way.

use crate::util::json::{obj, Json};
use crate::util::stats::LogHistogram;
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::Mutex;

/// Default flight-recorder capacity: sealed timelines kept by the
/// server's [`Tracer`] ring before the oldest rotates out.
pub const DEFAULT_TRACE_RING: usize = 256;

/// One lifecycle event kind with its payload. Payloads carry only
/// values that are deterministic per seed on *both* drivers (token
/// counts, block counts, shared-pricing seconds) so that cross-path
/// sequence comparison can use plain `==`.
#[derive(Clone, Debug, PartialEq)]
pub enum SpanEvent {
    /// Request entered the coordinator; `deadline_s` is the admission
    /// deadline in request-relative seconds (`f64::INFINITY` if none).
    Submitted {
        /// Relative admission deadline (infinite when absent).
        deadline_s: f64,
    },
    /// Router picked a worker (pool tier) or replica (cluster tier).
    Routed {
        /// Destination worker/replica index.
        worker: usize,
    },
    /// Scheduler admitted the request into a lane (fresh admission).
    Admitted,
    /// Request was dropped; terminal. Reasons: `deadline`, `kv_reject`,
    /// `preempt_livelock`, `slo_admission`.
    Shed {
        /// Why the request was dropped.
        reason: String,
    },
    /// One prefill chunk was absorbed.
    PrefillSpan {
        /// Prompt tokens fed in this chunk.
        len: usize,
        /// Prompt tokens skipped via the shared-prefix cache at
        /// admission (repeated on every chunk of the same request).
        cached_skip: usize,
    },
    /// One decode token was emitted (the first marks the TTFT edge).
    DecodeStep,
    /// Lane was evicted under KV pressure; blocks still held at the
    /// moment of preemption (about to demote/drop).
    Preempted {
        /// KV blocks held when preempted.
        demoted_blocks: usize,
    },
    /// Lane resumed from host-tier KV; `restore_s` is the shared
    /// `HostTierConfig::restore_s` pricing for the restored tokens.
    Restored {
        /// Modeled restore cost in seconds.
        restore_s: f64,
    },
    /// Lane resumed by recomputing its prefill (no host copy).
    Recomputed,
    /// A step failed with a transient fault and will be retried.
    Retry {
        /// Backoff before the retry attempt, in seconds.
        backoff_s: f64,
    },
    /// Worker/replica crash moved the lane to a sibling.
    Failover {
        /// Crashed source index.
        from: usize,
        /// Salvage destination index.
        to: usize,
    },
    /// A hedged duplicate resolved; `winner` is the replica whose
    /// stream was kept.
    Hedged {
        /// Winning replica index.
        winner: usize,
    },
    /// Request completed normally; terminal.
    Finished,
    /// Request ended without completing; terminal.
    Failed {
        /// Failure cause (`cancelled`, `retry_exhausted`,
        /// `crash_no_sibling`, or an error message).
        cause: String,
    },
}

impl SpanEvent {
    /// Short kind tag (used for JSON, Perfetto names, and digests).
    pub fn kind(&self) -> &'static str {
        match self {
            SpanEvent::Submitted { .. } => "submitted",
            SpanEvent::Routed { .. } => "routed",
            SpanEvent::Admitted => "admitted",
            SpanEvent::Shed { .. } => "shed",
            SpanEvent::PrefillSpan { .. } => "prefill_span",
            SpanEvent::DecodeStep => "decode_step",
            SpanEvent::Preempted { .. } => "preempted",
            SpanEvent::Restored { .. } => "restored",
            SpanEvent::Recomputed => "recomputed",
            SpanEvent::Retry { .. } => "retry",
            SpanEvent::Failover { .. } => "failover",
            SpanEvent::Hedged { .. } => "hedged",
            SpanEvent::Finished => "finished",
            SpanEvent::Failed { .. } => "failed",
        }
    }

    /// Terminal events close a timeline.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            SpanEvent::Shed { .. } | SpanEvent::Finished | SpanEvent::Failed { .. }
        )
    }

    fn payload_json(&self, o: &mut Vec<(&'static str, Json)>) {
        match self {
            SpanEvent::Submitted { deadline_s } => {
                if deadline_s.is_finite() {
                    o.push(("deadline_s", (*deadline_s).into()));
                }
            }
            SpanEvent::Routed { worker } => o.push(("worker", (*worker).into())),
            SpanEvent::Shed { reason } => o.push(("reason", reason.as_str().into())),
            SpanEvent::PrefillSpan { len, cached_skip } => {
                o.push(("len", (*len).into()));
                o.push(("cached_skip", (*cached_skip).into()));
            }
            SpanEvent::Preempted { demoted_blocks } => {
                o.push(("demoted_blocks", (*demoted_blocks).into()));
            }
            SpanEvent::Restored { restore_s } => o.push(("restore_s", (*restore_s).into())),
            SpanEvent::Retry { backoff_s } => o.push(("backoff_s", (*backoff_s).into())),
            SpanEvent::Failover { from, to } => {
                o.push(("from", (*from).into()));
                o.push(("to", (*to).into()));
            }
            SpanEvent::Hedged { winner } => o.push(("winner", (*winner).into())),
            SpanEvent::Admitted
            | SpanEvent::DecodeStep
            | SpanEvent::Recomputed
            | SpanEvent::Finished
            | SpanEvent::Failed { .. } => {}
        }
        if let SpanEvent::Failed { cause } = self {
            o.push(("cause", cause.as_str().into()));
        }
    }
}

/// A timestamped [`SpanEvent`]. `t_s` is seconds on the driver's clock:
/// the virtual clock in the simulator, wall offset from the pool epoch
/// in the threaded server.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Event timestamp in seconds.
    pub t_s: f64,
    /// The event itself.
    pub ev: SpanEvent,
}

/// The full recorded lifecycle of one request.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTimeline {
    /// Request id (pool tier) or synthetic id (cluster tier).
    pub request_id: u64,
    /// Events in recording order; timestamps are non-decreasing.
    pub events: Vec<TraceEvent>,
    /// Latency decomposition — present for finished requests that
    /// emitted at least one token (computed when the timeline closes).
    pub attribution: Option<Attribution>,
}

impl RequestTimeline {
    /// A fresh, open timeline.
    pub fn new(request_id: u64) -> RequestTimeline {
        RequestTimeline { request_id, events: Vec::new(), attribution: None }
    }

    /// Append one event.
    pub fn push(&mut self, t_s: f64, ev: SpanEvent) {
        self.events.push(TraceEvent { t_s, ev });
    }

    /// The terminal event, if the timeline is closed.
    pub fn terminal(&self) -> Option<&SpanEvent> {
        self.events.last().map(|e| &e.ev).filter(|e| e.is_terminal())
    }

    /// The payload-bearing event sequence with timestamps stripped —
    /// the unit of cross-path and rerun identity comparison.
    pub fn sequence(&self) -> Vec<SpanEvent> {
        self.events.iter().map(|e| e.ev.clone()).collect()
    }

    /// Worker/replica the request last ran on (after routing and any
    /// failovers); `None` before routing.
    pub fn final_worker(&self) -> Option<usize> {
        let mut w = None;
        for e in &self.events {
            match e.ev {
                SpanEvent::Routed { worker } => w = Some(worker),
                SpanEvent::Failover { to, .. } => w = Some(to),
                SpanEvent::Hedged { winner } => w = Some(winner),
                _ => {}
            }
        }
        w
    }

    /// Seal the timeline: compute attribution if eligible.
    pub fn seal(&mut self) {
        self.attribution = Attribution::from_timeline(self);
    }

    /// JSON form for the `trace` server op and report embedding.
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields: Vec<(&'static str, Json)> =
                    vec![("t_s", e.t_s.into()), ("ev", e.ev.kind().into())];
                e.ev.payload_json(&mut fields);
                obj(fields)
            })
            .collect::<Vec<_>>();
        let mut fields = vec![
            ("request_id", self.request_id.into()),
            ("events", Json::Arr(events)),
        ];
        if let Some(a) = &self.attribution {
            fields.push(("attribution", a.to_json()));
        }
        obj(fields)
    }
}

/// Canonical component names, in [`Attribution::component_sum`] order.
/// `decode_gap_s` is deliberately last: it is the residual that makes
/// the sum bitwise-equal to `ttft_s + decode_s`.
pub const COMPONENTS: [&str; 7] = [
    "queue_wait_s",
    "admission_delay_s",
    "prefill_s",
    "preempt_stall_s",
    "restore_s",
    "failover_s",
    "decode_gap_s",
];

/// Per-request latency decomposition. The identity
/// `component_sum() == ttft_s + decode_s` holds *bitwise* for every
/// attribution this module constructs (asserted by the invariant
/// harness on both serving paths).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Attribution {
    /// Submit → first decoded token.
    pub ttft_s: f64,
    /// First decoded token → last decoded token.
    pub decode_s: f64,
    /// Submit → routing decision.
    pub queue_wait_s: f64,
    /// Routing decision → first admission into a lane.
    pub admission_delay_s: f64,
    /// Time absorbed by prefill chunks.
    pub prefill_s: f64,
    /// Time parked after preemption before resuming (recompute path)
    /// plus re-admission waits.
    pub preempt_stall_s: f64,
    /// Time spent restoring demoted KV from the host tier.
    pub restore_s: f64,
    /// Time between a crash and resuming on the failover sibling.
    pub failover_s: f64,
    /// Decode-step gaps and everything else (residual component).
    pub decode_gap_s: f64,
}

impl Attribution {
    /// The measured total this decomposition must reproduce.
    pub fn total_s(&self) -> f64 {
        self.ttft_s + self.decode_s
    }

    /// Sum of the seven components in canonical order (`decode_gap_s`
    /// last). Bitwise-equal to [`Attribution::total_s`] by
    /// construction.
    pub fn component_sum(&self) -> f64 {
        self.queue_wait_s
            + self.admission_delay_s
            + self.prefill_s
            + self.preempt_stall_s
            + self.restore_s
            + self.failover_s
            + self.decode_gap_s
    }

    /// Component values in [`COMPONENTS`] order.
    pub fn components(&self) -> [f64; 7] {
        [
            self.queue_wait_s,
            self.admission_delay_s,
            self.prefill_s,
            self.preempt_stall_s,
            self.restore_s,
            self.failover_s,
            self.decode_gap_s,
        ]
    }

    /// Decompose a timeline. Returns `None` unless the request emitted
    /// at least one decode step (shed / pre-token failures have no
    /// TTFT to attribute).
    ///
    /// Construction: every inter-event gap up to the last decode step
    /// is attributed to a component keyed on the *later* event; the
    /// residual vs. `ttft_s + decode_s` is then folded into
    /// `decode_gap_s` with a bounded fix-up loop so the identity holds
    /// bitwise despite float non-associativity. The recomputation is a
    /// pure function of the event list, so identical timelines yield
    /// identical attributions.
    pub fn from_timeline(tl: &RequestTimeline) -> Option<Attribution> {
        let evs = &tl.events;
        let t_submit = evs.first()?.t_s;
        let first_decode = evs.iter().position(|e| matches!(e.ev, SpanEvent::DecodeStep))?;
        let last_decode = evs.iter().rposition(|e| matches!(e.ev, SpanEvent::DecodeStep))?;
        let ttft_s = evs[first_decode].t_s - t_submit;
        let decode_s = evs[last_decode].t_s - evs[first_decode].t_s;
        let target = ttft_s + decode_s;

        let mut queue_wait = 0.0f64;
        let mut admission_delay = 0.0f64;
        let mut prefill = 0.0f64;
        let mut preempt_stall = 0.0f64;
        let mut restore = 0.0f64;
        let mut failover = 0.0f64;
        let mut decode_gap = 0.0f64;
        let mut admitted_once = false;
        let mut parked = false; // between Preempted/Failover and resume
        for w in evs[..=last_decode].windows(2) {
            let gap = w[1].t_s - w[0].t_s;
            match &w[1].ev {
                SpanEvent::Routed { .. } => queue_wait += gap,
                SpanEvent::Admitted => {
                    if parked {
                        preempt_stall += gap;
                        parked = false;
                    } else if admitted_once {
                        decode_gap += gap;
                    } else {
                        admission_delay += gap;
                    }
                    admitted_once = true;
                }
                SpanEvent::Restored { .. } => {
                    restore += gap;
                    parked = false;
                    admitted_once = true;
                }
                SpanEvent::Recomputed => {
                    preempt_stall += gap;
                    parked = false;
                    admitted_once = true;
                }
                SpanEvent::PrefillSpan { .. } => prefill += gap,
                SpanEvent::DecodeStep => decode_gap += gap,
                SpanEvent::Failover { .. } => {
                    failover += gap;
                    parked = true;
                }
                SpanEvent::Preempted { .. } => {
                    decode_gap += gap;
                    parked = true;
                }
                SpanEvent::Retry { .. } | SpanEvent::Hedged { .. } => decode_gap += gap,
                SpanEvent::Submitted { .. }
                | SpanEvent::Shed { .. }
                | SpanEvent::Finished
                | SpanEvent::Failed { .. } => decode_gap += gap,
            }
        }

        let mut a = Attribution {
            ttft_s,
            decode_s,
            queue_wait_s: queue_wait,
            admission_delay_s: admission_delay,
            prefill_s: prefill,
            preempt_stall_s: preempt_stall,
            restore_s: restore,
            failover_s: failover,
            decode_gap_s: decode_gap,
        };
        // Fold the float residual into decode_gap_s until the identity
        // holds bitwise. Converges in one or two steps in practice; the
        // degenerate fallback (everything in decode_gap_s) is exact by
        // construction because the other six components are 0.0.
        let others = a.component_sum() - a.decode_gap_s;
        a.decode_gap_s = target - others;
        for _ in 0..64 {
            let miss = target - a.component_sum();
            if miss == 0.0 {
                break;
            }
            a.decode_gap_s += miss;
        }
        if a.component_sum() != target {
            a.queue_wait_s = 0.0;
            a.admission_delay_s = 0.0;
            a.prefill_s = 0.0;
            a.preempt_stall_s = 0.0;
            a.restore_s = 0.0;
            a.failover_s = 0.0;
            a.decode_gap_s = target;
        }
        Some(a)
    }

    /// JSON form: `ttft_s`, `decode_s`, then the seven components.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> =
            vec![("ttft_s", self.ttft_s.into()), ("decode_s", self.decode_s.into())];
        for (name, v) in COMPONENTS.iter().zip(self.components()) {
            fields.push((name, v.into()));
        }
        obj(fields)
    }
}

/// Aggregate of [`Attribution`]s for one tier: per-component counts,
/// means, and full log-spaced histograms (bounds + counts), so reports
/// expose the distribution of *where time went*, not just endpoint
/// percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct AttributionSummary {
    /// Requests aggregated.
    pub count: u64,
    sums: [f64; 7],
    hists: Vec<LogHistogram>,
}

impl Default for AttributionSummary {
    fn default() -> Self {
        AttributionSummary::new()
    }
}

impl AttributionSummary {
    /// An empty summary with the standard latency histogram bounds.
    pub fn new() -> AttributionSummary {
        AttributionSummary {
            count: 0,
            sums: [0.0; 7],
            hists: (0..COMPONENTS.len()).map(|_| LogHistogram::latency()).collect(),
        }
    }

    /// Fold one request's attribution in. Sub-resolution negative
    /// residuals (decode_gap_s can carry `-ε` float slack) clamp to 0
    /// for the histogram.
    pub fn add(&mut self, a: &Attribution) {
        self.count += 1;
        for (i, v) in a.components().into_iter().enumerate() {
            self.sums[i] += v;
            self.hists[i].add(v.max(0.0));
        }
    }

    /// Merge another summary (same bounds by construction).
    pub fn merge(&mut self, other: &AttributionSummary) {
        self.count += other.count;
        for i in 0..COMPONENTS.len() {
            self.sums[i] += other.sums[i];
            self.hists[i].merge(&other.hists[i]);
        }
    }

    /// `{"count": n, "<component>": {"mean_s": ..., "hist": {...}}}`.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("count", self.count.into())];
        for (i, name) in COMPONENTS.iter().enumerate() {
            let mean = if self.count == 0 { 0.0 } else { self.sums[i] / self.count as f64 };
            fields.push((
                name,
                obj(vec![("mean_s", mean.into()), ("hist", self.hists[i].to_json())]),
            ));
        }
        obj(fields)
    }
}

/// Single-threaded recorder for the virtual driver. With `on == false`
/// every method is a no-op, so an untraced run does zero extra work
/// (noninterference is pinned by proptest).
#[derive(Debug, Default)]
pub struct VTrace {
    on: bool,
    open: BTreeMap<u64, RequestTimeline>,
    done: Vec<RequestTimeline>,
}

impl VTrace {
    /// A recorder; `on == false` yields the no-op recorder.
    pub fn new(on: bool) -> VTrace {
        VTrace { on, open: BTreeMap::new(), done: Vec::new() }
    }

    /// Whether the recorder is active.
    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Record one event for `rid` at virtual time `t_s`. Terminal
    /// events seal the timeline and move it to the completed list.
    pub fn record(&mut self, rid: u64, t_s: f64, ev: SpanEvent) {
        if !self.on {
            return;
        }
        let terminal = ev.is_terminal();
        let tl = self.open.entry(rid).or_insert_with(|| RequestTimeline::new(rid));
        tl.push(t_s, ev);
        if terminal {
            let mut tl = self.open.remove(&rid).unwrap();
            tl.seal();
            self.done.push(tl);
        }
    }

    /// Close out: completed timelines sorted by request id (open
    /// timelines — e.g. requests orphaned by Halt — are dropped, as
    /// they have no terminal state to attribute).
    pub fn finish(mut self) -> Vec<RequestTimeline> {
        self.done.sort_by_key(|t| t.request_id);
        self.done
    }
}

/// Aggregate all finished-request attributions from a timeline set.
pub fn summarize(timelines: &[RequestTimeline]) -> AttributionSummary {
    let mut s = AttributionSummary::new();
    for tl in timelines {
        if let Some(a) = &tl.attribution {
            s.add(a);
        }
    }
    s
}

/// Shed-and-deadline-miss "why" digest kept by the flight recorder:
/// how many requests were dropped, for which reasons, and how many
/// finished requests blew their admission deadline anyway.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceDigest {
    /// Terminal-shed counts keyed by reason.
    pub sheds_by_reason: BTreeMap<String, u64>,
    /// Failure counts keyed by cause.
    pub failed_by_cause: BTreeMap<String, u64>,
    /// Finished requests whose first token landed past the deadline.
    pub deadline_misses: u64,
    /// Completed (terminal) timelines observed in total, including
    /// those that have since rotated out of the ring.
    pub completed: u64,
}

impl TraceDigest {
    /// Fold one sealed timeline into the digest.
    pub fn absorb(&mut self, tl: &RequestTimeline) {
        self.completed += 1;
        match tl.terminal() {
            Some(SpanEvent::Shed { reason }) => {
                *self.sheds_by_reason.entry(reason.clone()).or_insert(0) += 1;
            }
            Some(SpanEvent::Failed { cause }) => {
                *self.failed_by_cause.entry(cause.clone()).or_insert(0) += 1;
            }
            _ => {}
        }
        if let (Some(SpanEvent::Finished), Some(a)) = (tl.terminal(), &tl.attribution) {
            if let Some(TraceEvent { ev: SpanEvent::Submitted { deadline_s }, .. }) =
                tl.events.first()
            {
                if a.ttft_s > *deadline_s {
                    self.deadline_misses += 1;
                }
            }
        }
    }

    /// JSON form for the `trace` op.
    pub fn to_json(&self) -> Json {
        let m = |m: &BTreeMap<String, u64>| {
            Json::Obj({
                let mut o = crate::util::json::JsonObj::new();
                for (k, v) in m {
                    o.insert(k.clone(), (*v).into());
                }
                o
            })
        };
        obj(vec![
            ("completed", self.completed.into()),
            ("deadline_misses", self.deadline_misses.into()),
            ("sheds_by_reason", m(&self.sheds_by_reason)),
            ("failed_by_cause", m(&self.failed_by_cause)),
        ])
    }
}

/// Thread-safe flight recorder for the threaded coordinator: open
/// timelines keyed by request id, a bounded ring of the last-N sealed
/// timelines, and a cumulative [`TraceDigest`]. Off ⇒ all no-ops.
#[derive(Debug)]
pub struct Tracer {
    on: bool,
    inner: Mutex<TracerInner>,
}

#[derive(Debug)]
struct TracerInner {
    open: BTreeMap<u64, RequestTimeline>,
    ring: VecDeque<RequestTimeline>,
    cap: usize,
    digest: TraceDigest,
    summary: AttributionSummary,
}

impl Tracer {
    /// A recorder holding at most `ring_cap` sealed timelines.
    pub fn new(on: bool, ring_cap: usize) -> Tracer {
        Tracer {
            on,
            inner: Mutex::new(TracerInner {
                open: BTreeMap::new(),
                ring: VecDeque::new(),
                cap: ring_cap.max(1),
                digest: TraceDigest::default(),
                summary: AttributionSummary::new(),
            }),
        }
    }

    /// Whether the recorder is active.
    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Record one event for `request_id` at wall offset `t_s` seconds
    /// from the pool epoch. Terminal events seal the timeline into the
    /// ring (evicting the oldest past capacity) and update the digest.
    pub fn record(&self, request_id: u64, t_s: f64, ev: SpanEvent) {
        if !self.on {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let terminal = ev.is_terminal();
        let tl = inner
            .open
            .entry(request_id)
            .or_insert_with(|| RequestTimeline::new(request_id));
        tl.push(t_s, ev);
        if terminal {
            let mut tl = inner.open.remove(&request_id).unwrap();
            tl.seal();
            inner.digest.absorb(&tl);
            if let Some(a) = &tl.attribution {
                inner.summary.add(a);
            }
            if inner.ring.len() == inner.cap {
                inner.ring.pop_front();
            }
            inner.ring.push_back(tl);
        }
    }

    /// Snapshot the sealed timelines currently in the ring (oldest
    /// first) without draining them.
    pub fn completed(&self) -> Vec<RequestTimeline> {
        let inner = self.inner.lock().unwrap();
        inner.ring.iter().cloned().collect()
    }

    /// Cumulative attribution summary over all sealed timelines.
    pub fn attribution_summary(&self) -> AttributionSummary {
        self.inner.lock().unwrap().summary.clone()
    }

    /// Drain the ring (oldest first) and return it with a snapshot of
    /// the cumulative digest. The digest is *not* reset — it counts
    /// since process start, so repeated drains stay monotonic.
    pub fn drain(&self) -> (Vec<RequestTimeline>, TraceDigest) {
        let mut inner = self.inner.lock().unwrap();
        let drained = std::mem::take(&mut inner.ring).into_iter().collect();
        (drained, inner.digest.clone())
    }

    /// JSON body for the `trace` server op: drains the ring.
    pub fn drain_json(&self) -> Json {
        let (timelines, digest) = self.drain();
        obj(vec![
            ("enabled", self.on.into()),
            (
                "timelines",
                Json::Arr(timelines.iter().map(|t| t.to_json()).collect()),
            ),
            ("digest", digest.to_json()),
        ])
    }
}

/// Export timelines as a Chrome/Perfetto `trace_events` document:
/// `{"traceEvents": [...]}` with one track (`tid`) per worker/replica
/// plus a front-end track, one `X` span per request residency segment,
/// a `queue` span on the front-end track, `s`/`f` flow pairs tying
/// submit to completion, and `i` instants for sheds/faults/hedges.
/// Timestamps are microseconds (`ts = t_s * 1e6`).
pub fn perfetto_json(timelines: &[RequestTimeline]) -> Json {
    const PID: u64 = 1;
    let mut events: Vec<Json> = Vec::new();
    let mut tids: Vec<usize> = Vec::new();
    for tl in timelines {
        for e in &tl.events {
            match e.ev {
                SpanEvent::Routed { worker } => tids.push(worker + 1),
                SpanEvent::Failover { from, to } => {
                    tids.push(from + 1);
                    tids.push(to + 1);
                }
                SpanEvent::Hedged { winner } => tids.push(winner + 1),
                _ => {}
            }
        }
    }
    tids.push(0);
    tids.sort_unstable();
    tids.dedup();
    for tid in &tids {
        let name = if *tid == 0 {
            "frontend".to_string()
        } else {
            format!("worker {}", tid - 1)
        };
        events.push(obj(vec![
            ("ph", "M".into()),
            ("pid", PID.into()),
            ("tid", (*tid).into()),
            ("name", "thread_name".into()),
            ("args", obj(vec![("name", name.into())])),
        ]));
    }

    for tl in timelines {
        let Some(first) = tl.events.first() else { continue };
        let t0 = first.t_s;
        let ts = |t: f64| -> Json { (t * 1e6).into() };
        let rid = tl.request_id;
        let last = tl.events.last().unwrap();

        // Flow: opens at submission on the front-end track, binds
        // (enclosing) at the terminal event on the final worker track.
        let final_tid = tl.final_worker().map(|w| w + 1).unwrap_or(0);
        events.push(obj(vec![
            ("ph", "s".into()),
            ("cat", "req".into()),
            ("name", "req".into()),
            ("id", rid.into()),
            ("pid", PID.into()),
            ("tid", 0usize.into()),
            ("ts", ts(t0)),
        ]));
        events.push(obj(vec![
            ("ph", "f".into()),
            ("bp", "e".into()),
            ("cat", "req".into()),
            ("name", "req".into()),
            ("id", rid.into()),
            ("pid", PID.into()),
            ("tid", final_tid.into()),
            ("ts", ts(last.t_s)),
        ]));

        // Queue span on the front-end track: submit → first admission
        // (or terminal, for requests that never got in).
        let t_admit = tl
            .events
            .iter()
            .find(|e| {
                matches!(
                    e.ev,
                    SpanEvent::Admitted | SpanEvent::Restored { .. } | SpanEvent::Recomputed
                )
            })
            .map(|e| e.t_s)
            .unwrap_or(last.t_s);
        events.push(obj(vec![
            ("ph", "X".into()),
            ("cat", "queue".into()),
            ("name", format!("queue {rid}").into()),
            ("pid", PID.into()),
            ("tid", 0usize.into()),
            ("ts", ts(t0)),
            ("dur", ((t_admit - t0).max(0.0) * 1e6).into()),
        ]));

        // Residency spans: one X per contiguous stay on a worker
        // (split at Failover), carrying the attribution as args.
        let mut seg_start: Option<(usize, f64)> = None;
        let mut cur_worker = 0usize;
        for e in &tl.events {
            match e.ev {
                SpanEvent::Routed { worker } => cur_worker = worker,
                SpanEvent::Admitted | SpanEvent::Restored { .. } | SpanEvent::Recomputed => {
                    if seg_start.is_none() {
                        seg_start = Some((cur_worker, e.t_s));
                    }
                }
                SpanEvent::Failover { to, .. } => {
                    if let Some((w, t)) = seg_start.take() {
                        push_span(&mut events, PID, w + 1, rid, t, e.t_s, tl);
                    }
                    cur_worker = to;
                    seg_start = Some((to, e.t_s));
                }
                _ => {}
            }
        }
        if let Some((w, t)) = seg_start {
            push_span(&mut events, PID, w + 1, rid, t, last.t_s, tl);
        }

        // Instants for everything noteworthy.
        for e in &tl.events {
            let noteworthy = matches!(
                e.ev,
                SpanEvent::Shed { .. }
                    | SpanEvent::Preempted { .. }
                    | SpanEvent::Restored { .. }
                    | SpanEvent::Recomputed
                    | SpanEvent::Retry { .. }
                    | SpanEvent::Failover { .. }
                    | SpanEvent::Hedged { .. }
                    | SpanEvent::Failed { .. }
            );
            if !noteworthy {
                continue;
            }
            let mut fields: Vec<(&'static str, Json)> = Vec::new();
            e.ev.payload_json(&mut fields);
            events.push(obj(vec![
                ("ph", "i".into()),
                ("s", "t".into()),
                ("cat", "fault".into()),
                ("name", e.ev.kind().into()),
                ("pid", PID.into()),
                ("tid", final_tid.into()),
                ("ts", ts(e.t_s)),
                ("args", obj(fields)),
            ]));
        }
    }

    obj(vec![("traceEvents", Json::Arr(events))])
}

fn push_span(
    events: &mut Vec<Json>,
    pid: u64,
    tid: usize,
    rid: u64,
    t_start: f64,
    t_end: f64,
    tl: &RequestTimeline,
) {
    let mut fields = vec![
        ("ph", "X".into()),
        ("cat", "req".into()),
        ("name", format!("req {rid}").into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("ts", (t_start * 1e6).into()),
        ("dur", ((t_end - t_start).max(0.0) * 1e6).into()),
    ];
    if let Some(a) = &tl.attribution {
        fields.push(("args", a.to_json()));
    }
    events.push(obj(fields));
}

/// Validate an exported Perfetto document: parses, `traceEvents` is a
/// nonempty array, every flow-open (`s`) id has a matching flow-end
/// (`f`) and vice versa, and every `X` span has finite `ts` and
/// nonnegative `dur`. Returns the event count.
pub fn validate_perfetto(src: &str) -> Result<usize, String> {
    let doc = Json::parse(src).map_err(|e| format!("trace file is not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .as_arr()
        .ok_or("trace file has no traceEvents array")?;
    if events.is_empty() {
        return Err("traceEvents is empty".into());
    }
    let mut opens: Vec<u64> = Vec::new();
    let mut ends: Vec<u64> = Vec::new();
    for e in events {
        let ph = e.get("ph").as_str().ok_or("event missing ph")?;
        match ph {
            "s" | "f" => {
                let id = e.get("id").as_u64().ok_or("flow event missing id")?;
                if ph == "s" {
                    opens.push(id);
                } else {
                    ends.push(id);
                }
            }
            "X" => {
                let ts = e.get("ts").as_f64().ok_or("span missing ts")?;
                let dur = e.get("dur").as_f64().ok_or("span missing dur")?;
                if !ts.is_finite() || !dur.is_finite() || dur < 0.0 {
                    return Err(format!("span with bad ts/dur: ts={ts} dur={dur}"));
                }
            }
            _ => {}
        }
    }
    opens.sort_unstable();
    ends.sort_unstable();
    if opens != ends {
        return Err(format!(
            "unresolved flows: {} opens vs {} ends",
            opens.len(),
            ends.len()
        ));
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_timeline() -> RequestTimeline {
        let mut tl = RequestTimeline::new(7);
        tl.push(0.0, SpanEvent::Submitted { deadline_s: f64::INFINITY });
        tl.push(0.001, SpanEvent::Routed { worker: 2 });
        tl.push(0.013, SpanEvent::Admitted);
        tl.push(0.05, SpanEvent::PrefillSpan { len: 512, cached_skip: 0 });
        tl.push(0.09, SpanEvent::PrefillSpan { len: 512, cached_skip: 0 });
        tl.push(0.1, SpanEvent::DecodeStep);
        tl.push(0.11, SpanEvent::DecodeStep);
        tl.push(0.127, SpanEvent::DecodeStep);
        tl.push(0.127, SpanEvent::Finished);
        tl.seal();
        tl
    }

    #[test]
    fn attribution_identity_holds_bitwise() {
        let tl = sample_timeline();
        let a = tl.attribution.expect("finished timeline has attribution");
        assert_eq!(a.component_sum(), a.total_s());
        assert_eq!(a.ttft_s, 0.1);
        assert!(a.queue_wait_s > 0.0 && a.admission_delay_s > 0.0 && a.prefill_s > 0.0);
        // Pure function of the events: recomputation is equal.
        assert_eq!(Attribution::from_timeline(&tl), Some(a));
    }

    #[test]
    fn attribution_absent_without_decode() {
        let mut tl = RequestTimeline::new(1);
        tl.push(0.0, SpanEvent::Submitted { deadline_s: 0.5 });
        tl.push(0.6, SpanEvent::Shed { reason: "deadline".into() });
        tl.seal();
        assert!(tl.attribution.is_none());
        assert!(matches!(tl.terminal(), Some(SpanEvent::Shed { .. })));
    }

    #[test]
    fn tracer_ring_bounds_and_digest() {
        let tr = Tracer::new(true, 2);
        for rid in 0..5u64 {
            tr.record(rid, 0.0, SpanEvent::Submitted { deadline_s: 0.01 });
            tr.record(rid, 0.1, SpanEvent::DecodeStep);
            if rid == 4 {
                tr.record(rid, 0.2, SpanEvent::Shed { reason: "kv_reject".into() });
            } else {
                tr.record(rid, 0.2, SpanEvent::Finished);
            }
        }
        let (drained, digest) = tr.drain();
        assert_eq!(drained.len(), 2, "ring keeps only the last N");
        assert_eq!(drained[1].request_id, 4);
        assert_eq!(digest.completed, 5);
        assert_eq!(digest.sheds_by_reason.get("kv_reject"), Some(&1));
        assert_eq!(digest.deadline_misses, 4, "ttft 0.1 > deadline 0.01");
        let (again, _) = tr.drain();
        assert!(again.is_empty(), "drain empties the ring");
    }

    #[test]
    fn tracer_off_is_noop() {
        let tr = Tracer::new(false, 8);
        tr.record(1, 0.0, SpanEvent::Submitted { deadline_s: 1.0 });
        tr.record(1, 0.1, SpanEvent::Finished);
        let (drained, digest) = tr.drain();
        assert!(drained.is_empty());
        assert_eq!(digest, TraceDigest::default());
    }

    #[test]
    fn perfetto_roundtrip_validates() {
        let mut with_failover = RequestTimeline::new(9);
        with_failover.push(0.0, SpanEvent::Submitted { deadline_s: f64::INFINITY });
        with_failover.push(0.0, SpanEvent::Routed { worker: 0 });
        with_failover.push(0.01, SpanEvent::Admitted);
        with_failover.push(0.02, SpanEvent::DecodeStep);
        with_failover.push(0.03, SpanEvent::Failover { from: 0, to: 1 });
        with_failover.push(0.04, SpanEvent::Restored { restore_s: 0.004 });
        with_failover.push(0.05, SpanEvent::DecodeStep);
        with_failover.push(0.05, SpanEvent::Finished);
        with_failover.seal();
        let tls = vec![sample_timeline(), with_failover];
        let doc = perfetto_json(&tls);
        let src = doc.to_string_pretty();
        let n = validate_perfetto(&src).expect("exported trace validates");
        assert!(n > 8);
        // Timestamps are absolute microseconds; flows resolve per id.
        let parsed = Json::parse(&src).unwrap();
        let evs = parsed.get("traceEvents").as_arr().unwrap();
        assert!(evs.iter().any(|e| e.get("ph").as_str() == Some("i")));
        assert!(
            evs.iter()
                .filter(|e| e.get("ph").as_str() == Some("X"))
                .count()
                >= 4,
            "queue span + residency segments (split at failover)"
        );
    }

    #[test]
    fn validate_rejects_bad_documents() {
        assert!(validate_perfetto("not json").is_err());
        assert!(validate_perfetto("{\"traceEvents\": []}").is_err());
        assert!(
            validate_perfetto(
                "{\"traceEvents\": [{\"ph\": \"s\", \"id\": 3, \"ts\": 0}]}"
            )
            .is_err(),
            "unmatched flow open"
        );
    }

    #[test]
    fn summary_counts_components() {
        let tl = sample_timeline();
        let mut s = AttributionSummary::new();
        s.add(tl.attribution.as_ref().unwrap());
        s.add(tl.attribution.as_ref().unwrap());
        assert_eq!(s.count, 2);
        let j = s.to_json();
        assert_eq!(j.get("count").as_u64(), Some(2));
        assert!(j.get("prefill_s").get("mean_s").as_f64().unwrap() > 0.0);
        assert!(j.get("decode_gap_s").get("hist").get("counts").as_arr().is_some());
    }
}
