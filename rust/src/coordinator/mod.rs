//! The serving coordinator (HyperDex runtime layer).
//!
//! "HyperDex's runtime layer provides a collection of API for user
//! applications ... text generation, sampling, and streaming ... a device
//! driver beneath the runtime API ... extracts user-specified per-request
//! and per-core arguments ... monitoring tools that provide hardware-level
//! statistics."
//!
//! Architecture (std threads + channels; the environment has no tokio):
//!
//! ```text
//!   submit(Request) ──► Pool(model A) Router ──► worker-0 queue ─► worker 0 ─┐
//!                  │         (steering policy +  worker-1 queue ─► worker 1  ├─ Backend
//!                  │          prefix registry)      ▲ spill/steal ▲          │  (PJRT/sim)
//!                  └───► Pool(model B) Router ──► ...                        │
//!   TokenEvent stream ◄────────────────────────────── workers (mpsc per request)
//! ```
//!
//! Each pool routes submissions through a [`router::Router`] onto
//! **per-worker addressable queues** ([`router::PoolQueues`]): the
//! steering policy ([`CoordinatorConfig::router`]) is `round-robin`,
//! `least-loaded`, or `prefix-affinity` (steer to the worker whose
//! pager holds the deepest cached prefix for the prompt, tracked by a
//! pool-level [`router::PrefixRegistry`] fed from pager events). Each
//! queue keeps head-peek admission; an idle worker steals a steered job
//! after a bounded wait, so affinity never strands work behind a hot
//! worker. Routing changes placement and latency only — token streams
//! are identical under every policy.
//!
//! Each worker owns one [`backend::Backend`] and runs **continuous
//! batching**: it holds a slot table of concurrently active requests,
//! admits new requests *between fused decode steps* (admission bounded
//! by a KV-memory budget derived from the device HBM capacity), advances
//! a batch of slots per step under the configured
//! [`scheduler::SchedulerPolicy`], and retires finished slots with
//! `swap_remove` (mirrored into the scheduler so per-slot policy state
//! follows the churn). A fused step streams the weights once for every
//! lane in the batch — the batch-mode vecmat reuse the paper lists as
//! future work — so worker throughput grows with concurrency while
//! per-token latency degrades only by the per-lane KV terms. Sampling
//! runs in the coordinator with the same [`crate::numerics::Sampler`]
//! the VXE model uses.
//!
//! **The state machine itself lives in [`lane`]** — lane prefill/decode
//! transitions, KV admission and the single release choke point, and
//! fused-step composition ([`lane::plan_step`]) — and is shared verbatim
//! with the virtual-time harness ([`workload::run_virtual`]), so the
//! threaded and simulated paths cannot drift (the stream-agreement tests
//! then check equivalence rather than papering over divergence). This
//! module owns only what is genuinely threaded: the pool queue, worker
//! threads, client channels, wall-clock metrics, and the event fan-out.
//!
//! **Prefill** runs as multi-token spans. By default a prompt is fed in
//! a single pass (`prefill_chunk = 0`, the way the hardware executes a
//! prompt) — which makes a long prompt's step long and inflates
//! co-batched decode lanes' TPOT. Setting
//! [`CoordinatorConfig::prefill_chunk`] splits prefill into token-
//! budgeted chunks interleaved with decode steps (decode lanes always
//! advance; at most `prefill_chunk` prompt tokens run per step,
//! allocated most-starved-first), bounding neighbor TPOT while keeping
//! the prompt's TTFT within a small factor of single-pass. Spans change
//! only timing — token streams are bit-identical across chunk settings.
//!
//! KV memory is accounted per [`scheduler::KvPolicy`]: `Reserve` holds
//! the worst case (`prompt + max_new_tokens`) from admission, so the
//! active batch is sized by what requests *could* grow to; `Paged`
//! reserves fixed-size [`scheduler::KvPager`] blocks as each context
//! actually grows and, when growth outruns the budget, preempts the
//! lowest-progress slot — releasing its blocks and requeueing it at the
//! queue head for recompute-on-readmit (the prompt *and* the tokens it
//! already emitted are re-fed to rebuild KV; the client stream never
//! sees a duplicate token, and the carried sampler RNG keeps stochastic
//! sampling exact).
//!
//! With [`CoordinatorConfig::prefix_cache`] enabled (paged policy only),
//! pager blocks are shared across requests via a block-granular prefix
//! index: a request whose prompt prefix is resident starts prefill at
//! the cached position — one physical copy per distinct prefix, a
//! copy-on-write split when a lane would write into a shared tail
//! block, and LRU reclamation of cache-only blocks whenever live
//! traffic needs them. See `ARCHITECTURE.md`'s prefix-caching section
//! for the full lifecycle.

pub mod backend;
pub mod cluster;
pub mod faults;
pub mod lane;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod trace;
pub mod workload;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::numerics::SampleParams;

pub use backend::{Backend, BackendFactory, BatchLane, LaneWork, SimBackend, StepModel};
pub use cluster::{
    run_cluster_open_loop, run_virtual_cluster, run_virtual_cluster_plan, ArrivalTrace,
    AutoscaleConfig, Cluster, ClusterConfig, ClusterLoadReport, ClusterRecord,
    ClusterReport, ClusterWorkload, SloTier, SloTierSpec, Submitted,
};
pub use faults::{
    ClusterFaultPlan, CrashSpec, FaultKind, FaultPlan, FleetFault, PartitionSpec,
    ReplicaCrashSpec, ReplicaHealth, ReplicaSlowSpec, SlowSpec, DEFAULT_BACKOFF_BASE_S,
    DEFAULT_PROBE_INTERVAL_S, DEFAULT_RETRY_BUDGET, REINSTATE_PROBES,
};
pub use lane::{Absorbed, Admit, HoldsLane, KvState, Lane, ResumeState};
pub use metrics::{Metrics, Percentiles, PoolGauges};
pub use router::{
    PoolQueues, Popped, PrefixRegistry, Router, RouterPolicy, WorkerLoad,
    AFFINITY_IMBALANCE_LIMIT, DEFAULT_SPILL_AFTER_S,
};
pub use scheduler::{
    HostTierConfig, HostTierStats, KvBlockId, KvBudget, KvPager, KvPolicy, KvTier,
    PrefixCacheConfig, PrefixEvent, PrefixStats, Scheduler, SchedulerPolicy,
    DEFAULT_KV_BLOCK_TOKENS,
};
pub use trace::{
    perfetto_json, validate_perfetto, Attribution, AttributionSummary, RequestTimeline,
    SpanEvent, TraceDigest, TraceEvent, Tracer, DEFAULT_TRACE_RING,
};
pub use workload::{
    run_open_loop, run_virtual, run_virtual_plan, run_virtual_plan_jobs, LenDist, LoadReport,
    OrphanJob, PlanJob, PlanResume, PoolInterrupt, VirtualConfig, VirtualReport, Workload,
};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Model to route to (pool name).
    pub model: String,
    /// Prompt token ids (non-empty).
    pub prompt: Vec<i64>,
    /// Maximum tokens to generate (> 0).
    pub max_new_tokens: usize,
    /// Sampling parameters.
    pub params: SampleParams,
    /// Stop early on this token id.
    pub eos_token: Option<i64>,
    /// Sampling seed (reproducible streams).
    pub seed: u64,
    /// Queueing deadline, seconds from submission (`None` = no
    /// deadline). A request still queued when its deadline lapses is
    /// shed at admission with a visible `timeout` error (counted in
    /// `shed_expired`) instead of being started late — the minimal
    /// load-shedding hook for SLO-aware admission.
    pub deadline_s: Option<f64>,
}

impl Request {
    /// A greedy request with default parameters.
    pub fn greedy(model: &str, prompt: Vec<i64>, max_new_tokens: usize) -> Request {
        Request {
            model: model.to_string(),
            prompt,
            max_new_tokens,
            params: SampleParams::greedy(),
            eos_token: None,
            seed: 0,
            deadline_s: None,
        }
    }

    /// Validate shape and sampling parameters.
    pub fn validate(&self) -> Result<(), String> {
        if self.prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if self.max_new_tokens == 0 {
            return Err("max_new_tokens must be > 0".into());
        }
        if let Some(d) = self.deadline_s {
            if !d.is_finite() || d < 0.0 {
                return Err(format!("deadline_s must be finite and >= 0, got {d}"));
            }
        }
        self.params.validate()
    }

    /// Largest context this request can ever grow to, tokens. The
    /// reserve-policy admission gate reserves
    /// `worst_case_tokens × kv_bytes_per_token` bytes up front
    /// ([`lane::KvState::admit`]).
    pub fn worst_case_tokens(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

/// A streamed generation event.
#[derive(Clone, Debug, PartialEq)]
pub enum TokenEvent {
    /// One generated token (with its index in the completion).
    Token {
        /// The originating request.
        request_id: u64,
        /// Index of this token in the completion (0-based).
        index: usize,
        /// The sampled token id.
        token: i64,
    },
    /// Generation finished (all tokens already streamed).
    Done {
        /// The originating request.
        request_id: u64,
        /// The complete generated stream.
        tokens: Vec<i64>,
        /// Why generation stopped.
        reason: FinishReason,
    },
    /// The request failed.
    Error {
        /// The originating request.
        request_id: u64,
        /// Failure description.
        message: String,
    },
}

/// Why a stream completed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new_tokens` generated.
    Length,
    /// The EOS token was sampled.
    Eos,
}

/// Handle for consuming one request's event stream.
pub struct RequestHandle {
    /// The id assigned at submission (echoed in every event).
    pub request_id: u64,
    /// The event stream (tokens, then `Done` or `Error`).
    pub events: Receiver<TokenEvent>,
}

impl RequestHandle {
    /// Block until completion; returns the generated tokens.
    pub fn wait(self) -> Result<Vec<i64>, String> {
        for ev in self.events.iter() {
            match ev {
                TokenEvent::Done { tokens, .. } => return Ok(tokens),
                TokenEvent::Error { message, .. } => return Err(message),
                TokenEvent::Token { .. } => {}
            }
        }
        Err("stream closed without completion".into())
    }
}

/// A queued request: routing metadata plus (after a preemption) the
/// carried stream state for recompute-on-readmit.
struct Job {
    request_id: u64,
    request: Request,
    events: Sender<TokenEvent>,
    submitted: Instant,
    /// Present when this job was preempted mid-decode.
    resume: Option<ResumeState>,
    /// True when this job was salvaged from a crashed worker's slot
    /// table — readmission counts toward the failover restore/recompute
    /// split instead of the preemption one.
    failover: bool,
}

impl Job {
    /// Context tokens that must be (re)fed before new decoding.
    fn init_ctx(&self) -> usize {
        lane::init_context(&self.request, self.resume.as_ref())
    }
}

/// Per-model worker pool: per-worker queues behind a shared router.
struct Pool {
    /// Per-worker addressable job queues (head-peek + spill/steal).
    queues: Arc<PoolQueues<Job>>,
    /// Steering policy state + the cross-worker prefix registry.
    router: Arc<Mutex<Router>>,
    /// Per-pool prefill/prefix/worker gauges (the server's `metrics` op
    /// exposes them under `pools.<model>`).
    gauges: Arc<PoolGauges>,
    /// Pool epoch: queue timestamps (spill eligibility) are seconds
    /// since this instant, mirroring the virtual harness's clock shape.
    epoch: Instant,
    workers: Vec<JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max requests a worker holds in its slot table.
    pub max_active_per_worker: usize,
    /// Token-level scheduling policy for batch composition.
    pub policy: SchedulerPolicy,
    /// KV bytes one context token occupies (from
    /// [`crate::model::ModelConfig::kv_bytes_per_token`]); 0 disables
    /// KV admission control.
    pub kv_bytes_per_token: u64,
    /// Per-worker KV memory budget, bytes (`u64::MAX` = unbounded).
    pub kv_budget_bytes: u64,
    /// How the budget is accounted: worst-case reservation or paged
    /// reserve-as-you-grow with preemption.
    pub kv_policy: KvPolicy,
    /// Max lanes per fused decode step (hardware batch cap); 0 means
    /// `max_active_per_worker`.
    pub max_batch: usize,
    /// Chunked prefill: max prompt/recompute tokens per fused step
    /// across all prefilling lanes, allocated most-starved-first with
    /// decode lanes always advancing. 0 (default) = off: each prompt is
    /// prefilled in a single pass, which minimizes its own TTFT but can
    /// stall co-batched decode lanes for the span's full duration.
    pub prefill_chunk: usize,
    /// Copy-on-write prefix caching over the paged KV blocks
    /// (`--prefix-cache on|off[:capacity]`): requests whose prompt
    /// shares a block-aligned prefix with an earlier request hold one
    /// physical copy and skip that prefill. Off by default; only
    /// meaningful under [`KvPolicy::Paged`], and auto-disabled per
    /// worker when the backend cannot restore sessions at a cached
    /// position (PJRT).
    pub prefix_cache: PrefixCacheConfig,
    /// How each pool steers submissions onto its per-worker queues
    /// (`--router round-robin|least-loaded|prefix-affinity`).
    /// `prefix-affinity` pays off with [`CoordinatorConfig::prefix_cache`]
    /// enabled (it steers to the worker already holding a prompt's
    /// cached prefix blocks); without a registry it degrades to
    /// least-loaded. Routing changes placement and latency only — token
    /// streams are identical under every policy.
    pub router: RouterPolicy,
    /// How long a steered job may wait at its queue head before an idle
    /// sibling may steal it, seconds ([`DEFAULT_SPILL_AFTER_S`] by
    /// default). Tests pin placement by setting it larger than the run.
    pub spill_after_s: f64,
    /// Host (CPU-memory) KV tier under the pager (`--kv-host-mb`):
    /// preempted lanes and LRU-evicted prefixes demote their blocks to a
    /// bounded host pool instead of freeing them, and readmission
    /// restores the KV over the host link when the modeled restore cost
    /// beats recompute. Off by default; only meaningful under
    /// [`KvPolicy::Paged`], and auto-disabled per worker when the
    /// backend cannot restore sessions at a nonzero position (PJRT).
    pub host_tier: HostTierConfig,
    /// Deterministic fault-injection plan (`--fault-plan <spec>`).
    /// [`FaultPlan::default`] is inert; an active plan injects transient
    /// step errors, whole-worker crashes, and slow-worker degradation,
    /// and configures the bounded transient-retry budget/backoff. The
    /// virtual harness accepts the same plan ([`VirtualConfig`]) so
    /// recovery paths are testable off-thread.
    pub faults: FaultPlan,
    /// Record per-request lifecycle timelines into the coordinator's
    /// flight recorder ([`trace::Tracer`]). Off by default; strictly
    /// observational — streams, counters, and metrics are identical
    /// either way (the trace-noninterference property).
    pub trace: bool,
    /// Flight-recorder capacity: sealed timelines kept before the
    /// oldest rotates out ([`DEFAULT_TRACE_RING`] by default).
    pub trace_ring: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::Fcfs,
            kv_bytes_per_token: 0,
            kv_budget_bytes: u64::MAX,
            kv_policy: KvPolicy::Reserve,
            max_batch: 0,
            prefill_chunk: 0,
            prefix_cache: PrefixCacheConfig::off(),
            router: RouterPolicy::RoundRobin,
            spill_after_s: DEFAULT_SPILL_AFTER_S,
            host_tier: HostTierConfig::off(),
            faults: FaultPlan::default(),
            trace: false,
            trace_ring: DEFAULT_TRACE_RING,
        }
    }
}

impl CoordinatorConfig {
    /// Derive admission limits from a device + model pair: the KV budget
    /// is whatever HBM capacity remains after the resident weights.
    pub fn for_device(
        device: &crate::config::LpuConfig,
        model: &crate::model::ModelConfig,
        policy: SchedulerPolicy,
    ) -> CoordinatorConfig {
        let budget = device.hbm.capacity().saturating_sub(model.weight_bytes());
        CoordinatorConfig {
            max_active_per_worker: 8,
            policy,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            kv_budget_bytes: budget.max(1),
            kv_policy: KvPolicy::Reserve,
            max_batch: 0,
            prefill_chunk: 0,
            prefix_cache: PrefixCacheConfig::off(),
            router: RouterPolicy::RoundRobin,
            spill_after_s: DEFAULT_SPILL_AFTER_S,
            host_tier: HostTierConfig::off(),
            faults: FaultPlan::default(),
            trace: false,
            trace_ring: DEFAULT_TRACE_RING,
        }
    }
}

/// The serving coordinator: router + pools + metrics.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    pools: HashMap<String, Pool>,
    next_id: AtomicU64,
    /// Shared serving metrics (snapshot for the `/metrics`-style op).
    pub metrics: Arc<Metrics>,
    /// Request-lifecycle flight recorder (no-op unless
    /// [`CoordinatorConfig::trace`]); drained by the server's `trace`
    /// op.
    pub tracer: Arc<trace::Tracer>,
}

impl Coordinator {
    /// Build a coordinator with no pools registered yet.
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        let tracer = Arc::new(trace::Tracer::new(cfg.trace, cfg.trace_ring));
        Coordinator {
            cfg,
            pools: HashMap::new(),
            next_id: AtomicU64::new(1),
            metrics: Arc::new(Metrics::new()),
            tracer,
        }
    }

    /// The scheduling policy this coordinator's workers run.
    pub fn policy(&self) -> SchedulerPolicy {
        self.cfg.policy
    }

    /// Register a model pool with `n_workers` backend instances. The
    /// factory runs *inside* each worker thread (PJRT handles are not
    /// `Send`; each worker owns its own client). The pool gets one
    /// [`Router`] (policy from [`CoordinatorConfig::router`]) steering
    /// onto `n_workers` addressable queues.
    pub fn add_pool(&mut self, model: &str, n_workers: usize, factory: BackendFactory) {
        let n_workers = n_workers.max(1);
        let queues =
            Arc::new(PoolQueues::with_spill_after(n_workers, self.cfg.spill_after_s));
        let router = Arc::new(Mutex::new(Router::new(
            self.cfg.router,
            self.cfg.kv_policy.registry_block_tokens(),
        )));
        let gauges = Arc::new(PoolGauges::with_workers(n_workers));
        let epoch = Instant::now();
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let factory = factory.clone();
            let ctx = WorkerCtx {
                worker: w,
                queues: Arc::clone(&queues),
                router: Arc::clone(&router),
                epoch,
                metrics: Arc::clone(&self.metrics),
                pool_gauges: Arc::clone(&gauges),
                tracer: Arc::clone(&self.tracer),
                cfg: self.cfg.clone(),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lpu-worker-{model}-{w}"))
                    .spawn(move || worker_loop(ctx, factory))
                    .expect("spawn worker"),
            );
        }
        self.pools
            .insert(model.to_string(), Pool { queues, router, gauges, epoch, workers });
    }

    /// Models this coordinator serves.
    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.pools.keys().cloned().collect();
        m.sort();
        m
    }

    /// Per-pool gauge frames (model name → JSON), sorted by model, for
    /// the server's `metrics` op. Includes the live per-worker
    /// `queue_depth`/`active_lanes` gauges under `workers[i]`.
    pub fn pools_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::JsonObj::new();
        for model in self.models() {
            let pool = &self.pools[&model];
            o.insert(model.clone(), pool.gauges.to_json(&pool.queues.depths()));
        }
        crate::util::json::Json::Obj(o)
    }

    /// Submit a request; returns a streaming handle. The pool's router
    /// steers the job onto one worker's queue using the loads (queue
    /// depths + active lanes) at this instant.
    pub fn submit(&self, request: Request) -> Result<RequestHandle, String> {
        self.submit_inner(request, None)
    }

    /// Submit a request that continues a stream salvaged from another
    /// replica (the fleet failover path): the carried [`ResumeState`]
    /// routes the job through the same restore-vs-recompute readmission
    /// machinery a within-pool preemption uses, so already-delivered
    /// tokens are recomputed into KV but never re-emitted — token
    /// events continue from `resume.generated.len()`.
    pub(crate) fn submit_resumed(
        &self,
        request: Request,
        resume: ResumeState,
    ) -> Result<RequestHandle, String> {
        self.submit_inner(request, Some(resume))
    }

    fn submit_inner(
        &self,
        request: Request,
        resume: Option<ResumeState>,
    ) -> Result<RequestHandle, String> {
        request.validate()?;
        let pool = self
            .pools
            .get(&request.model)
            .ok_or_else(|| format!("unknown model '{}' (have: {:?})", request.model, self.models()))?;
        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.metrics.on_submit();
        let worker = {
            let mut router = pool.router.lock().unwrap();
            let loads: Vec<WorkerLoad> = if router.policy() == RouterPolicy::RoundRobin {
                // Round-robin ignores loads entirely: skip the queue
                // lock and gauge scan on the default hot path.
                vec![WorkerLoad::default(); pool.workers.len()]
            } else {
                pool.queues
                    .depths()
                    .into_iter()
                    .enumerate()
                    .map(|(i, queue_depth)| WorkerLoad {
                        queue_depth,
                        active_lanes: pool.gauges.active_lanes(i),
                    })
                    .collect()
            };
            router.route(&request.prompt, &loads)
        };
        let now_s = pool.epoch.elapsed().as_secs_f64();
        // Record BEFORE the push: once the job is queued a worker may
        // admit it concurrently, and its events must sort after these.
        self.tracer.record(
            request_id,
            now_s,
            trace::SpanEvent::Submitted {
                deadline_s: request.deadline_s.unwrap_or(f64::INFINITY),
            },
        );
        self.tracer.record(request_id, now_s, trace::SpanEvent::Routed { worker });
        pool.queues
            .push(
                worker,
                now_s,
                Job {
                    request_id,
                    request,
                    events: tx,
                    submitted: Instant::now(),
                    failover: resume.is_some(),
                    resume,
                },
            )
            .map_err(|_| "pool shut down".to_string())?;
        // Fold the post-push depth into the per-worker peak gauge (the
        // threaded mirror of the virtual harness's
        // `worker_peak_queue_depth` sampling).
        pool.gauges
            .note_queue_depth(worker, pool.queues.depths().get(worker).copied().unwrap_or(0));
        Ok(RequestHandle { request_id, events: rx })
    }

    /// Close pool queues and join workers (in-flight requests finish).
    pub fn shutdown(mut self) {
        let pools = std::mem::take(&mut self.pools);
        for (_, pool) in pools {
            pool.queues.close();
            for w in pool.workers {
                let _ = w.join();
            }
        }
    }
}

/// One active request's slot in a worker's table: the shared [`Lane`]
/// state machine plus the threaded-only pieces (client channel, wall
/// clock, backend session).
struct Slot {
    request_id: u64,
    events: Sender<TokenEvent>,
    submitted: Instant,
    session: Box<dyn std::any::Any>,
    lane: Lane,
}

impl HoldsLane for Slot {
    fn lane(&self) -> &Lane {
        &self.lane
    }
    fn lane_mut(&mut self) -> &mut Lane {
        &mut self.lane
    }
}

/// Why a slot leaves the table.
enum Retire {
    Done(FinishReason),
    Cancelled,
    Errored(String),
}

/// Everything one worker thread needs from its pool (bundled so the
/// loop has one coherent context instead of a parameter sprawl).
struct WorkerCtx {
    /// This worker's index (its queue in [`PoolQueues`], its gauges).
    worker: usize,
    queues: Arc<PoolQueues<Job>>,
    router: Arc<Mutex<Router>>,
    epoch: Instant,
    metrics: Arc<Metrics>,
    pool_gauges: Arc<PoolGauges>,
    tracer: Arc<trace::Tracer>,
    cfg: CoordinatorConfig,
}

impl WorkerCtx {
    /// Seconds since the pool epoch (queue timestamps / spill bound).
    fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Forward this worker's drained pager events to the pool router's
    /// prefix registry (cheap no-op when nothing changed). Called after
    /// admission (shares can evict), after `plan_step` (growth can
    /// evict), and after the absorb loop (prefill completion inserts) —
    /// the last one *before* `Done` events are sent, so a client that
    /// saw a request finish can rely on its prefix being registered.
    fn sync_registry(&self, kv: &mut KvState) {
        let events = kv.drain_prefix_events();
        if !events.is_empty() {
            self.router.lock().unwrap().note_prefix_events(self.worker, &events);
        }
    }
}

/// Whether a queued job's deadline lapsed before admission.
fn job_expired(job: &Job) -> bool {
    job.request.deadline_s.map_or(false, |d| job.submitted.elapsed().as_secs_f64() >= d)
}

fn worker_loop(ctx: WorkerCtx, factory: BackendFactory) {
    let mut backend = match factory.build() {
        Ok(b) => b,
        Err(e) => {
            // Drain jobs with errors so clients don't hang (own queue
            // first; leftovers steered here are also stolen by healthy
            // siblings after the spill bound).
            loop {
                match ctx.queues.pop_for(ctx.worker, ctx.now_s(), true, |_| Admit::Take) {
                    Popped::Job(job) | Popped::Rejected(job) => {
                        let _ = job.events.send(TokenEvent::Error {
                            request_id: job.request_id,
                            message: format!("backend init failed: {e}"),
                        });
                    }
                    Popped::None => {}
                    Popped::Closed => return,
                }
            }
        }
    };
    let mut scheduler = Scheduler::new(ctx.cfg.policy);
    let mut kv = KvState::with_prefix(
        ctx.cfg.kv_policy,
        ctx.cfg.kv_budget_bytes,
        ctx.cfg.kv_bytes_per_token,
        ctx.cfg.prefix_cache,
    );
    if kv.prefix_cache_enabled() && !backend.supports_session_restore() {
        // A hit is only real if the backend can attach the cached KV:
        // without session restore (PJRT), admission must never claim
        // one, or the lane would decode against missing context.
        kv.disable_prefix_cache();
    }
    kv.set_host_tier(ctx.cfg.host_tier);
    if kv.host_tier_enabled() && !backend.supports_session_restore() {
        // Same contract: a restore readmits the lane at a nonzero
        // position, which this backend cannot attach — the tier
        // self-disables and readmission falls back to recompute.
        kv.disable_host_tier();
    }
    // Cumulative pager counters; the delta after each admission feeds
    // the coordinator metrics and this pool's gauges.
    let mut prefix_seen = kv.prefix_stats();
    let mut host_seen = kv.host_stats();
    if let Some(capacity) = kv.capacity_blocks() {
        ctx.metrics.set_kv_capacity_blocks(capacity as u64);
    }
    if kv.host_tier_enabled() {
        ctx.metrics.set_kv_host_capacity_blocks(kv.host_capacity_blocks() as u64);
    }
    let mut slots: Vec<Slot> = Vec::new();
    let max_batch =
        if ctx.cfg.max_batch == 0 { ctx.cfg.max_active_per_worker } else { ctx.cfg.max_batch };
    // Parity with `run_virtual`'s preemption guard: the liveness
    // invariants rule out preempt/readmit livelock, but a future
    // regression should shed a request visibly instead of silently
    // spinning every client stream on this worker forever.
    let mut preempts_since_done: usize = 0;
    // Deterministic fault injection: decisions key on (worker, fused
    // step count, request id) — never wall time — so the same plan
    // reproduces the same recovery sequence across runs and drivers.
    let faults = ctx.cfg.faults.clone();
    let slow = faults.slow_factor(ctx.worker);
    let mut step_count: u64 = 0;

    loop {
        // ---- injected whole-worker crash: salvage the slot table to
        // healthy siblings and die. Every lane exits through
        // `release_lane` first, so a crash cannot leak KV budget;
        // queued jobs become stealable immediately (`mark_dead`), and
        // the router stops steering here (health mask) and forgets this
        // worker's cached prefixes (registry eviction).
        if faults.crashes_at(ctx.worker, step_count) {
            ctx.metrics.on_fault_injected();
            ctx.queues.mark_dead(ctx.worker);
            ctx.pool_gauges.set_unhealthy(ctx.worker);
            let n_workers = ctx.queues.depths().len();
            let targets: Vec<Option<usize>> = {
                let mut router = ctx.router.lock().unwrap();
                router.set_unhealthy(ctx.worker);
                (0..slots.len()).map(|k| router.failover_target(k, n_workers)).collect()
            };
            ctx.metrics.on_worker_crash(targets.iter().filter(|t| t.is_some()).count());
            let now_s = ctx.now_s();
            for (s, target) in slots.drain(..).zip(targets) {
                kv.release_lane(&s.lane);
                let Slot { request_id, events, submitted, lane, .. } = s;
                match target {
                    Some(t) => {
                        ctx.tracer.record(
                            request_id,
                            now_s,
                            trace::SpanEvent::Failover { from: ctx.worker, to: t },
                        );
                        let (request, resume) = lane.into_resume();
                        ctx.queues.push_front(
                            t,
                            now_s,
                            Job {
                                request_id,
                                request,
                                events,
                                submitted,
                                resume: Some(resume),
                                failover: true,
                            },
                        );
                    }
                    None => {
                        // Sole (or last healthy) worker: fail visibly,
                        // never strand the client stream.
                        ctx.metrics.on_error();
                        ctx.tracer.record(
                            request_id,
                            now_s,
                            trace::SpanEvent::Failed { cause: "crash_no_sibling".into() },
                        );
                        let _ = events.send(TokenEvent::Error {
                            request_id,
                            message: "worker crashed with no healthy sibling to fail over to"
                                .into(),
                        });
                    }
                }
            }
            // The registry already dropped this worker wholesale; the
            // release events above must not resurrect entries for it.
            kv.drain_prefix_events();
            ctx.pool_gauges.set_active_lanes(ctx.worker, 0);
            return;
        }
        // ---- admission: runs between every fused step, so requests
        // join mid-decode (continuous batching). This worker peeks its
        // own queue head (popping only on Take/Reject; a Later head
        // stays queued) and, when its own queue is empty, steals the
        // longest-waiting sibling head past the spill bound.
        while slots.len() < ctx.cfg.max_active_per_worker {
            let popped = ctx.queues.pop_for(ctx.worker, ctx.now_s(), slots.is_empty(), |job| {
                if job_expired(job) {
                    // Dequeue unconditionally so the shed below is
                    // visible; starting it late would be worse than
                    // any admission verdict.
                    return Admit::Take;
                }
                kv.admit(
                    &job.request.prompt,
                    job.init_ctx(),
                    job.request.worst_case_tokens(),
                    slots.iter().map(|s| &s.lane),
                )
            });
            match popped {
                Popped::Job(job) => {
                    if job_expired(&job) {
                        // Deadline lapsed while queued: shed instead of
                        // admitting late (no reservation was taken).
                        ctx.metrics.on_shed_expired();
                        ctx.metrics.on_error();
                        ctx.tracer.record(
                            job.request_id,
                            ctx.now_s(),
                            trace::SpanEvent::Shed { reason: "deadline".into() },
                        );
                        let _ = job.events.send(TokenEvent::Error {
                            request_id: job.request_id,
                            message: format!(
                                "timeout: deadline {:.3}s lapsed after {:.3}s in queue; \
                                 request shed before admission",
                                job.request.deadline_s.unwrap_or(0.0),
                                job.submitted.elapsed().as_secs_f64(),
                            ),
                        });
                        continue;
                    }
                    // A preempted job readmits through the host tier
                    // when its demoted KV is intact and the modeled
                    // restore beats recompute; fresh jobs (and tier-off
                    // readmissions) take the plain reservation path.
                    let holdings = match &job.resume {
                        Some(resume) => kv.reserve_resumed(
                            &job.request.prompt,
                            resume,
                            job.init_ctx(),
                            job.request.worst_case_tokens(),
                        ),
                        None => kv.reserve_admitted(
                            &job.request.prompt,
                            job.init_ctx(),
                            job.request.worst_case_tokens(),
                        ),
                    };
                    let stats = kv.prefix_stats();
                    let delta = stats.delta(&prefix_seen);
                    prefix_seen = stats;
                    ctx.metrics.on_prefix(&delta);
                    ctx.pool_gauges.on_prefix(&delta);
                    let hstats = kv.host_stats();
                    let hdelta = hstats.delta(&host_seen);
                    host_seen = hstats;
                    ctx.metrics.on_host_tier(&hdelta);
                    ctx.pool_gauges.on_host_tier(&hdelta);
                    // Peak occupancy can be set by admission itself
                    // (the virtual harness records it there too).
                    ctx.metrics.note_kv_blocks_in_use(kv.blocks_in_use() as u64);
                    // Sharing can reclaim (evict) cache entries; tell
                    // the pool registry.
                    ctx.sync_registry(&mut kv);
                    if job.failover {
                        // Restore-vs-recompute split for salvaged
                        // lanes: "restored" when any of its KV came
                        // back from the host tier or prefix cache.
                        ctx.metrics
                            .on_failover_readmit(holdings.restored > 0 || holdings.prefix_hit > 0);
                    }
                    match &job.resume {
                        // Readmission: name the path, with the shared
                        // host-tier pricing so the payload matches the
                        // virtual driver's bitwise.
                        Some(_) if holdings.restored > 0 => ctx.tracer.record(
                            job.request_id,
                            ctx.now_s(),
                            trace::SpanEvent::Restored {
                                restore_s: ctx.cfg.host_tier.restore_s(holdings.restored),
                            },
                        ),
                        Some(_) => ctx.tracer.record(
                            job.request_id,
                            ctx.now_s(),
                            trace::SpanEvent::Recomputed,
                        ),
                        None => ctx.tracer.record(
                            job.request_id,
                            ctx.now_s(),
                            trace::SpanEvent::Admitted,
                        ),
                    }
                    let Job { request_id, request, events, submitted, resume, .. } = job;
                    match backend.new_session_at(holdings.prefix_hit) {
                        Ok(session) => {
                            if resume.is_none() {
                                ctx.metrics.on_start(submitted.elapsed());
                            }
                            let seed = request.seed ^ request_id;
                            let lane = Lane::admitted(request, seed, resume, holdings);
                            slots.push(Slot { request_id, events, submitted, session, lane });
                            scheduler.reset_slot(slots.len() - 1);
                        }
                        Err(e) => {
                            kv.release_holdings(holdings);
                            ctx.metrics.on_error();
                            ctx.tracer.record(
                                request_id,
                                ctx.now_s(),
                                trace::SpanEvent::Failed { cause: format!("session: {e}") },
                            );
                            let _ = events.send(TokenEvent::Error {
                                request_id,
                                message: format!("session: {e}"),
                            });
                        }
                    }
                }
                Popped::Rejected(job) => {
                    // Can never fit, even on an empty device: refuse
                    // rather than deadlock the admission queue.
                    let message = kv.reject_reason(job.request.worst_case_tokens());
                    ctx.metrics.on_reject();
                    ctx.tracer.record(
                        job.request_id,
                        ctx.now_s(),
                        trace::SpanEvent::Shed { reason: "kv_reject".into() },
                    );
                    let _ = job
                        .events
                        .send(TokenEvent::Error { request_id: job.request_id, message });
                }
                Popped::None => break,
                Popped::Closed => {
                    if slots.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        ctx.pool_gauges.set_active_lanes(ctx.worker, slots.len());

        if slots.is_empty() {
            continue;
        }

        // ---- compose the fused step (shared logic: pick lanes, assign
        // prefill spans, secure paged-KV growth, preempt when growth
        // cannot be secured). Evicted slots come back with their blocks
        // already released; this loop decides their fate (requeue with
        // resume state, or shed visibly on suspected livelock).
        let (plan, evicted) =
            lane::plan_step(&mut scheduler, &mut kv, &mut slots, max_batch, ctx.cfg.prefill_chunk);
        for s in evicted {
            ctx.metrics.on_preempt(s.lane.tokens_emitted());
            preempts_since_done += 1;
            if preempts_since_done > 1000 + 100 * ctx.cfg.max_active_per_worker {
                ctx.metrics.on_shed_livelock();
                ctx.metrics.on_error();
                ctx.tracer.record(
                    s.request_id,
                    ctx.now_s(),
                    trace::SpanEvent::Shed { reason: "preempt_livelock".into() },
                );
                let _ = s.events.send(TokenEvent::Error {
                    request_id: s.request_id,
                    message: "preemption livelock suspected: request shed after repeated \
                              preemption without a completion"
                        .into(),
                });
            } else {
                ctx.tracer.record(
                    s.request_id,
                    ctx.now_s(),
                    trace::SpanEvent::Preempted { demoted_blocks: s.lane.kv_blocks() },
                );
                let (request, resume) = s.lane.into_resume();
                ctx.queues.push_front(
                    ctx.worker,
                    ctx.now_s(),
                    Job {
                        request_id: s.request_id,
                        request,
                        events: s.events,
                        submitted: s.submitted,
                        resume: Some(resume),
                        failover: false,
                    },
                );
            }
        }
        ctx.metrics.note_kv_blocks_in_use(kv.blocks_in_use() as u64);
        // Preemptions (and growth reclaiming cached prefixes) demote
        // blocks to the host tier; publish the delta.
        let hstats = kv.host_stats();
        let hdelta = hstats.delta(&host_seen);
        host_seen = hstats;
        ctx.metrics.on_host_tier(&hdelta);
        ctx.pool_gauges.on_host_tier(&hdelta);
        // Growth may have reclaimed cache-only blocks (evicting their
        // index entries); keep the pool registry in step.
        ctx.sync_registry(&mut kv);
        ctx.pool_gauges.set_active_lanes(ctx.worker, slots.len());
        if plan.is_empty() {
            continue;
        }

        // ---- one fused batched step over the planned lanes ----
        step_count += 1;
        // Transient injection is decided BEFORE any lane is fed: a
        // faulted lane skips the backend entirely this step (its state
        // machine does not advance), so the retry next step replans it
        // with identical state and the token stream cannot skew.
        let injected: Vec<bool> = plan
            .lanes
            .iter()
            .map(|p| faults.transient_at(ctx.worker, step_count, slots[p.slot].request_id))
            .collect();
        let step_started = Instant::now();
        let mut lanes: Vec<BatchLane> = Vec::with_capacity(plan.lanes.len());
        let mut fed: Vec<usize> = Vec::with_capacity(plan.lanes.len());
        for (j, p) in plan.lanes.iter().enumerate() {
            if injected[j] {
                continue;
            }
            let s = &mut slots[p.slot];
            if s.lane.in_prefill() {
                ctx.metrics.on_prefill(p.span);
                ctx.pool_gauges.on_prefill(p.span);
            }
            let tokens = s.lane.feed_span(p.span);
            let session = std::mem::replace(&mut s.session, Box::new(()));
            lanes.push(BatchLane { session, tokens });
            fed.push(j);
        }
        let results =
            if lanes.is_empty() { Vec::new() } else { backend.decode_batch(&mut lanes) };
        if !lanes.is_empty() {
            ctx.metrics.on_batch_step(lanes.len());
        }
        let step_elapsed = step_started.elapsed();
        if slow > 1.0 {
            // Injected degradation: stretch the wall-clock step by the
            // plan's factor (the virtual harness scales pricing the
            // same way).
            std::thread::sleep(step_elapsed.mul_f64(slow - 1.0));
        }

        debug_assert_eq!(results.len(), fed.len(), "backend lane-count contract");
        let mut retire: Vec<(usize, Retire)> = Vec::new();
        // Step failures — injected or real backend errors — funnel
        // through one taxonomy + bounded-retry path below.
        let mut failed: Vec<(usize, String)> = Vec::new();
        for ((lane_io, &j), result) in lanes.iter_mut().zip(&fed).zip(results) {
            let p = &plan.lanes[j];
            let i = p.slot;
            slots[i].session = std::mem::replace(&mut lane_io.session, Box::new(()));
            match result {
                Ok(logits) => {
                    let s = &mut slots[i];
                    let was_prefill = s.lane.in_prefill();
                    if was_prefill {
                        ctx.tracer.record(
                            s.request_id,
                            ctx.now_s(),
                            trace::SpanEvent::PrefillSpan {
                                len: p.span,
                                cached_skip: s.lane.prefix_hit(),
                            },
                        );
                    }
                    match s.lane.absorb(p.span, &logits) {
                        Absorbed::Prefilling => {
                            // Still prefilling: a pick without a token.
                            scheduler.note_progress(i, s.lane.tokens_emitted());
                        }
                        Absorbed::Token { token, finished } => {
                            if was_prefill {
                                // Initial context fully written: its
                                // block-aligned prompt prefix becomes
                                // shareable.
                                kv.on_prefill_complete(&s.lane);
                            }
                            if s.lane.tokens_emitted() == 1 {
                                // A resumed lane can't reach here (its
                                // stream starts non-empty), so TTFT
                                // counts each request once, at its true
                                // first emission.
                                ctx.metrics.on_first_token(s.submitted.elapsed());
                            }
                            ctx.metrics.on_token(step_elapsed);
                            ctx.tracer.record(
                                s.request_id,
                                ctx.now_s(),
                                trace::SpanEvent::DecodeStep,
                            );
                            scheduler.note_progress(i, s.lane.tokens_emitted());
                            let receiver_alive = s
                                .events
                                .send(TokenEvent::Token {
                                    request_id: s.request_id,
                                    index: s.lane.tokens_emitted() - 1,
                                    token,
                                })
                                .is_ok();
                            if !receiver_alive {
                                // Client went away mid-stream: cancel so
                                // the device stops burning tokens on it.
                                retire.push((i, Retire::Cancelled));
                            } else if let Some(reason) = finished {
                                retire.push((i, Retire::Done(reason)));
                            }
                        }
                    }
                }
                Err(e) => failed.push((i, e.to_string())),
            }
        }
        for (j, p) in plan.lanes.iter().enumerate() {
            if injected[j] {
                ctx.metrics.on_fault_injected();
                failed.push((p.slot, faults.transient_error(ctx.worker, step_count).to_string()));
            }
        }
        // Taxonomy: transient failures retry in place under the bounded
        // per-request budget (with exponential backoff); fatal ones —
        // and budget exhaustion — retire visibly through the normal
        // errored path, never a hang. An injected-transient lane was
        // never fed this step, so retrying is exact; a backend error
        // classified transient relies on the backend's contract that a
        // failed step consumed nothing.
        let mut backoff = 0.0f64;
        for (i, msg) in failed {
            match FaultKind::classify(&msg) {
                FaultKind::Fatal => retire.push((i, Retire::Errored(msg))),
                FaultKind::Transient => {
                    let attempt = slots[i].lane.note_retry();
                    if attempt <= faults.retry_budget {
                        ctx.metrics.on_retry();
                        ctx.tracer.record(
                            slots[i].request_id,
                            ctx.now_s(),
                            trace::SpanEvent::Retry { backoff_s: faults.backoff_s(attempt) },
                        );
                        backoff = backoff.max(faults.backoff_s(attempt));
                    } else {
                        retire.push((
                            i,
                            Retire::Errored(format!(
                                "{msg} (transient retry budget {} exhausted)",
                                faults.retry_budget
                            )),
                        ));
                    }
                }
            }
        }
        if backoff > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(backoff));
        }

        // Publish prefill-completion index inserts BEFORE any Done is
        // sent below: a client that saw its request finish may submit a
        // follow-up immediately and expects prefix-affinity routing to
        // already know where the prefix lives.
        ctx.sync_registry(&mut kv);

        // Retire in descending index order so swap_remove indices stay
        // valid; mirror every removal into the scheduler.
        retire.sort_by(|a, b| b.0.cmp(&a.0));
        for (i, why) in retire {
            let s = slots.swap_remove(i);
            scheduler.swap_remove(i);
            kv.release_lane(&s.lane);
            let Slot { request_id, events, submitted, lane, .. } = s;
            match why {
                Retire::Done(reason) => {
                    preempts_since_done = 0;
                    ctx.metrics.on_done(lane.tokens_emitted(), submitted.elapsed());
                    ctx.tracer.record(request_id, ctx.now_s(), trace::SpanEvent::Finished);
                    let _ = events.send(TokenEvent::Done {
                        request_id,
                        tokens: lane.into_finished(),
                        reason,
                    });
                }
                Retire::Cancelled => {
                    ctx.metrics.on_cancel(lane.tokens_emitted());
                    ctx.tracer.record(
                        request_id,
                        ctx.now_s(),
                        trace::SpanEvent::Failed { cause: "cancelled".into() },
                    );
                }
                Retire::Errored(message) => {
                    ctx.metrics.on_error();
                    ctx.tracer.record(
                        request_id,
                        ctx.now_s(),
                        trace::SpanEvent::Failed { cause: message.clone() },
                    );
                    let _ = events.send(TokenEvent::Error { request_id, message });
                }
            }
        }
        ctx.pool_gauges.set_active_lanes(ctx.worker, slots.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;

    fn sim_coord(max_active: usize) -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: max_active,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        c
    }

    #[test]
    fn single_request_completes() {
        let c = sim_coord(2);
        let h = c.submit(Request::greedy("opt-tiny", vec![1, 2, 3], 8)).unwrap();
        let tokens = h.wait().unwrap();
        assert_eq!(tokens.len(), 8);
        c.shutdown();
    }

    #[test]
    fn streaming_events_are_ordered() {
        let c = sim_coord(2);
        let h = c.submit(Request::greedy("opt-tiny", vec![5], 5)).unwrap();
        let mut indices = Vec::new();
        let mut done = false;
        for ev in h.events.iter() {
            match ev {
                TokenEvent::Token { index, .. } => indices.push(index),
                TokenEvent::Done { tokens, reason, .. } => {
                    assert_eq!(tokens.len(), 5);
                    assert_eq!(reason, FinishReason::Length);
                    done = true;
                }
                TokenEvent::Error { message, .. } => panic!("{message}"),
            }
        }
        assert!(done);
        assert_eq!(indices, vec![0, 1, 2, 3, 4]);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_finish() {
        let c = sim_coord(4);
        let handles: Vec<_> = (0..16)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![i as i64 + 1], 6)).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap().len(), 6);
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.completed, 16);
        assert_eq!(snap.tokens_out, 16 * 6);
        assert!(snap.batch_steps > 0);
        // Every request's prompt ran as exactly one single-pass span.
        assert_eq!(snap.prefill_spans, 16);
        assert_eq!(snap.prefill_tokens, 16);
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let c = sim_coord(1);
        let err = match c.submit(Request::greedy("gpt-5", vec![1], 1)) {
            Err(e) => e,
            Ok(_) => panic!("expected rejection"),
        };
        assert!(err.contains("unknown model"), "{err}");
        c.shutdown();
    }

    #[test]
    fn invalid_request_rejected() {
        let c = sim_coord(1);
        assert!(c.submit(Request::greedy("opt-tiny", vec![], 1)).is_err());
        let mut r = Request::greedy("opt-tiny", vec![1], 0);
        r.max_new_tokens = 0;
        assert!(c.submit(r).is_err());
        c.shutdown();
    }

    #[test]
    fn eos_stops_generation() {
        // SimBackend logits are deterministic; find which token greedy
        // picks first, then use it as EOS for a second request.
        let c = sim_coord(1);
        let h = c.submit(Request::greedy("opt-tiny", vec![9], 4)).unwrap();
        let toks = h.wait().unwrap();
        let mut r = Request::greedy("opt-tiny", vec![9], 100);
        r.eos_token = Some(toks[0]);
        let h2 = c.submit(r).unwrap();
        let toks2 = h2.wait().unwrap();
        assert_eq!(toks2.len(), 1);
        assert_eq!(toks2[0], toks[0]);
        c.shutdown();
    }

    #[test]
    fn client_disconnect_cancels_request() {
        let c = sim_coord(2);
        // Submit a long request and drop the handle immediately.
        let h = c.submit(Request::greedy("opt-tiny", vec![1], 100_000)).unwrap();
        drop(h);
        // A subsequent request must still be served promptly (the worker
        // did not spend 100k tokens on the orphan).
        let t0 = std::time::Instant::now();
        let toks = c.submit(Request::greedy("opt-tiny", vec![2], 4)).unwrap().wait().unwrap();
        assert_eq!(toks.len(), 4);
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        // Wait for the cancel to be recorded.
        for _ in 0..200 {
            if c.metrics.snapshot().cancelled >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(c.metrics.snapshot().cancelled, 1);
        c.shutdown();
    }

    #[test]
    fn deterministic_greedy_across_runs() {
        let c = sim_coord(2);
        let a = c.submit(Request::greedy("opt-tiny", vec![1, 2], 6)).unwrap().wait().unwrap();
        let b = c.submit(Request::greedy("opt-tiny", vec![1, 2], 6)).unwrap().wait().unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn batching_does_not_change_tokens() {
        // The same request must produce identical tokens whether it runs
        // alone (batch of 1) or interleaved with 7 neighbors.
        let solo = {
            let c = sim_coord(1);
            let t = c.submit(Request::greedy("opt-tiny", vec![3, 4], 10)).unwrap().wait().unwrap();
            c.shutdown();
            t
        };
        let c = sim_coord(8);
        let noise: Vec<_> = (0..7)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![40 + i], 10)).unwrap())
            .collect();
        let t = c.submit(Request::greedy("opt-tiny", vec![3, 4], 10)).unwrap().wait().unwrap();
        for h in noise {
            h.wait().unwrap();
        }
        assert_eq!(t, solo);
        c.shutdown();
    }

    #[test]
    fn chunked_prefill_does_not_change_tokens() {
        // Chunking changes step composition and timing only: the same
        // workload must stream identical tokens at any chunk setting.
        let run = |prefill_chunk: usize| -> Vec<Vec<i64>> {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                prefill_chunk,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            let handles: Vec<_> = (0..6)
                .map(|i| {
                    c.submit(Request::greedy("opt-tiny", vec![i as i64 + 1; 40], 8)).unwrap()
                })
                .collect();
            let out = handles.into_iter().map(|h| h.wait().unwrap()).collect();
            c.shutdown();
            out
        };
        let single_pass = run(0);
        for chunk in [1usize, 7, 64] {
            assert_eq!(run(chunk), single_pass, "chunk {chunk}");
        }
    }

    #[test]
    fn chunked_prefill_splits_spans() {
        // A 40-token prompt under an 8-token chunk budget must take
        // ceil(40/8) = 5 spans; single-pass takes exactly 1.
        for (chunk, want_spans) in [(0usize, 1u64), (8, 5)] {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 2,
                policy: SchedulerPolicy::RoundRobin,
                prefill_chunk: chunk,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            let toks =
                c.submit(Request::greedy("opt-tiny", vec![3; 40], 4)).unwrap().wait().unwrap();
            assert_eq!(toks.len(), 4);
            let snap = c.metrics.snapshot();
            assert_eq!(snap.prefill_spans, want_spans, "chunk {chunk}");
            assert_eq!(snap.prefill_tokens, 40, "chunk {chunk}");
            c.shutdown();
        }
    }

    #[test]
    fn kv_overflow_request_rejected_with_error() {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 1000,
            kv_budget_bytes: 10_000, // 10 tokens of KV
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
        // Needs (2 + 50) * 1000 B > 10_000 B: impossible even when idle.
        let h = c.submit(Request::greedy("opt-tiny", vec![1, 2], 50)).unwrap();
        let err = h.wait().unwrap_err();
        assert!(err.contains("KV"), "{err}");
        assert_eq!(c.metrics.snapshot().rejected, 1);
        // A request that fits still completes.
        let ok = c.submit(Request::greedy("opt-tiny", vec![1], 4)).unwrap().wait().unwrap();
        assert_eq!(ok.len(), 4);
        c.shutdown();
    }

    #[test]
    fn kv_budget_throttles_concurrency_without_losing_requests() {
        // Budget fits exactly two in-flight requests; submit six. All
        // must complete (head-peek admission), never more than two at
        // once.
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 6,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 100,
            kv_budget_bytes: 2 * (1 + 8) * 100,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
        let handles: Vec<_> = (0..6)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![i + 1], 8)).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap().len(), 8);
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.rejected, 0);
        // With ≤2 concurrent lanes, no fused step can exceed 2 lanes.
        assert!(snap.mean_batch_size <= 2.0 + 1e-9, "{}", snap.mean_batch_size);
        c.shutdown();
    }

    /// Drain one handle with a deadline so an accounting bug (leaked
    /// budget starving admission) fails the test instead of hanging it.
    fn wait_with_timeout(h: RequestHandle, secs: u64) -> Result<Vec<i64>, String> {
        let deadline = Instant::now() + std::time::Duration::from_secs(secs);
        loop {
            let remaining = deadline
                .checked_duration_since(Instant::now())
                .ok_or_else(|| "timed out waiting for completion".to_string())?;
            match h.events.recv_timeout(remaining) {
                Ok(TokenEvent::Done { tokens, .. }) => return Ok(tokens),
                Ok(TokenEvent::Error { message, .. }) => return Err(message),
                Ok(TokenEvent::Token { .. }) => {}
                Err(e) => return Err(format!("stream ended: {e}")),
            }
        }
    }

    #[test]
    fn paged_streams_identical_to_unbounded_run() {
        // Preemption + recompute-on-readmit must never change a token
        // stream: greedy decoding is a pure function of (model, prompt)
        // in the sim backend, so a run under a tight pager (which
        // preempts and recomputes) must emit exactly what an unbounded
        // run emits.
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request::greedy("opt-tiny", vec![i as i64 + 1; 8], 120))
            .collect();
        let run = |cfg: CoordinatorConfig| -> Vec<Vec<i64>> {
            let mut c = Coordinator::new(cfg);
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
            let handles: Vec<_> =
                reqs.iter().map(|r| c.submit(r.clone()).unwrap()).collect();
            let out = handles
                .into_iter()
                .map(|h| wait_with_timeout(h, 60).unwrap())
                .collect();
            c.shutdown();
            out
        };
        let unbounded = run(CoordinatorConfig {
            max_active_per_worker: 16,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        // 18-block pager (288 tokens of KV); every request grows to 128
        // tokens (8 blocks), so worst-case accounting would hold 2 at a
        // time while the pager holds 3 and preempts near the end of
        // concurrent growth.
        let paged = run(CoordinatorConfig {
            max_active_per_worker: 16,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 100,
            kv_budget_bytes: 288 * 100,
            kv_policy: KvPolicy::Paged { block_tokens: 16 },
            ..CoordinatorConfig::default()
        });
        assert_eq!(paged, unbounded);
        assert!(paged.iter().all(|t| t.len() == 120));
    }

    /// A host tier priced so restore always beats recompute (cheap
    /// link, expensive refeed) — the decision itself is under test
    /// elsewhere; here we want the swap path exercised.
    fn cheap_host_tier(capacity_blocks: usize) -> HostTierConfig {
        HostTierConfig {
            capacity_blocks,
            restore_s_per_token: 1e-9,
            kv_read_s_per_pos: 1e-6,
            weight_stream_s: 1e-3,
        }
    }

    #[test]
    fn host_tier_restores_preempted_work_and_streams_match() {
        // The tight pager from paged_streams_identical_to_unbounded_run
        // forces preempt/readmit churn; with the host tier on, the
        // evicted lanes' KV demotes and readmission restores it instead
        // of recomputing — with byte-identical client streams.
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request::greedy("opt-tiny", vec![i as i64 + 1; 8], 120))
            .collect();
        let run = |host_tier: HostTierConfig| {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 16,
                policy: SchedulerPolicy::RoundRobin,
                kv_bytes_per_token: 100,
                kv_budget_bytes: 288 * 100,
                kv_policy: KvPolicy::Paged { block_tokens: 16 },
                host_tier,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
            let handles: Vec<_> =
                reqs.iter().map(|r| c.submit(r.clone()).unwrap()).collect();
            let streams: Vec<Vec<i64>> = handles
                .into_iter()
                .map(|h| wait_with_timeout(h, 60).unwrap())
                .collect();
            let snap = c.metrics.snapshot();
            c.shutdown();
            (streams, snap)
        };
        let (off_streams, off_snap) = run(HostTierConfig::off());
        let (on_streams, on_snap) = run(cheap_host_tier(64));
        assert_eq!(on_streams, off_streams, "host tier must not change streams");
        assert!(on_streams.iter().all(|t| t.len() == 120));
        assert!(off_snap.preemptions > 0 && on_snap.preemptions > 0);
        assert_eq!(off_snap.kv_demoted_blocks, 0);
        assert_eq!(off_snap.kv_restored_blocks, 0);
        assert_eq!(off_snap.kv_host_capacity_blocks, 0);
        assert!(on_snap.kv_demoted_blocks > 0, "preempted lanes never demoted");
        assert!(on_snap.kv_restored_blocks > 0, "readmission never restored");
        assert!(on_snap.kv_restored_tokens > 0);
        assert_eq!(on_snap.kv_host_capacity_blocks, 64);
    }

    #[test]
    fn host_tier_self_disables_without_session_restore() {
        // A backend that cannot reopen a session at a nonzero position
        // cannot attach restored KV: the tier must turn itself off and
        // every preemption must fall back to recompute — streams intact.
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 16,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 100,
            kv_budget_bytes: 288 * 100,
            kv_policy: KvPolicy::Paged { block_tokens: 16 },
            host_tier: cheap_host_tier(64),
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim_no_restore("opt-tiny", 64));
        let handles: Vec<_> = (0..12)
            .map(|i| {
                c.submit(Request::greedy("opt-tiny", vec![i as i64 + 1; 8], 120)).unwrap()
            })
            .collect();
        for h in handles {
            assert_eq!(wait_with_timeout(h, 60).unwrap().len(), 120);
        }
        let snap = c.metrics.snapshot();
        assert!(snap.preemptions > 0, "scenario must still churn the pager");
        assert_eq!(snap.kv_demoted_blocks, 0, "disabled tier must not demote");
        assert_eq!(snap.kv_restored_blocks, 0);
        assert_eq!(snap.kv_host_capacity_blocks, 0, "disabled tier exports no capacity");
        c.shutdown();
    }

    #[test]
    fn prefix_cache_shares_blocks_and_streams_stay_identical() {
        // Three sequential identical-prompt requests under paged KV:
        // with the prefix cache on, the 2nd and 3rd skip most of their
        // prefill (hit tokens + shared blocks + a CoW tail split each),
        // and every stream is bit-identical to a cache-off run.
        let prompt: Vec<i64> = (0..64).map(|i| (i % 32) as i64).collect();
        let run = |prefix_cache: PrefixCacheConfig| {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                kv_bytes_per_token: 100,
                kv_budget_bytes: 64 * 16 * 100, // 64 blocks of 16 tokens
                kv_policy: KvPolicy::Paged { block_tokens: 16 },
                prefix_cache,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 512));
            // Strictly sequential: each request completes before the
            // next submits, so later prompts can only be served from a
            // registered prefix.
            let streams: Vec<Vec<i64>> = (0..3)
                .map(|_| {
                    c.submit(Request::greedy("opt-tiny", prompt.clone(), 8))
                        .unwrap()
                        .wait()
                        .unwrap()
                })
                .collect();
            let snap = c.metrics.snapshot();
            c.shutdown();
            (streams, snap)
        };
        let (off_streams, off_snap) = run(PrefixCacheConfig::off());
        let (on_streams, on_snap) = run(PrefixCacheConfig::on());
        assert_eq!(on_streams, off_streams, "prefix cache must not change streams");
        assert_eq!(off_snap.prefix_hit_tokens, 0);
        // 64-token prompt = 4 full 16-token blocks. Each hit request
        // skips 63 tokens (one token must be fed for logits), shares 3
        // blocks, and CoW-splits the written tail block.
        assert_eq!(on_snap.prefix_hit_tokens, 2 * 63);
        assert_eq!(on_snap.shared_blocks, 2 * 3);
        assert_eq!(on_snap.cow_splits, 2);
        // The skipped prefill is real work not done.
        assert_eq!(off_snap.prefill_tokens, 3 * 64);
        assert_eq!(on_snap.prefill_tokens, 64 + 2);
    }

    #[test]
    fn affinity_router_steers_repeat_prompts_to_cached_worker() {
        // Strictly sequential identical-prompt requests on a 2-worker
        // pool: under prefix-affinity every repeat is steered to the
        // worker already holding the cached prefix; round-robin
        // steering alternates workers and forfeits one of the hits.
        let prompt: Vec<i64> = (0..64).map(|i| (i % 32) as i64).collect();
        let run = |router: RouterPolicy| -> u64 {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                kv_bytes_per_token: 100,
                kv_budget_bytes: 64 * 16 * 100,
                kv_policy: KvPolicy::Paged { block_tokens: 16 },
                prefix_cache: PrefixCacheConfig::on(),
                router,
                // Pin placement: no stealing, so the exact hit counts
                // below cannot be perturbed by a descheduled worker
                // letting the spill window lapse.
                spill_after_s: 3600.0,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
            // Each request completes — and registers its prefix, which
            // the worker publishes before the Done event — before the
            // next routing decision runs.
            for _ in 0..3 {
                c.submit(Request::greedy("opt-tiny", prompt.clone(), 8))
                    .unwrap()
                    .wait()
                    .unwrap();
            }
            let hits = c.metrics.snapshot().prefix_hit_tokens;
            c.shutdown();
            hits
        };
        // 64-token prompt: a hit skips 63 tokens (one must be fed for
        // logits). Affinity: requests 2 and 3 both hit. Round-robin:
        // request 2 lands on the cold sibling, request 3 returns to a
        // cached worker — exactly one hit, whichever worker served the
        // first request.
        assert_eq!(run(RouterPolicy::PrefixAffinity), 2 * 63);
        assert_eq!(run(RouterPolicy::RoundRobin), 63);
    }

    #[test]
    fn affinity_overload_spills_to_idle_workers() {
        // max_active 1 turns the affinity target into a bottleneck: the
        // pile-up must drain anyway (imbalance cap at routing + idle
        // siblings stealing past the spill bound), never starve.
        let prompt: Vec<i64> = vec![3; 32];
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 1,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 100,
            kv_budget_bytes: 64 * 16 * 100,
            kv_policy: KvPolicy::Paged { block_tokens: 16 },
            prefix_cache: PrefixCacheConfig::on(),
            router: RouterPolicy::PrefixAffinity,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        c.submit(Request::greedy("opt-tiny", prompt.clone(), 4)).unwrap().wait().unwrap();
        let handles: Vec<_> = (0..6)
            .map(|_| c.submit(Request::greedy("opt-tiny", prompt.clone(), 4)).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap().len(), 4);
        }
        assert_eq!(c.metrics.snapshot().completed, 7);
        c.shutdown();
    }

    #[test]
    fn failing_slots_release_kv_budget() {
        // Regression (error/cancel-path audit): a slot that errors
        // mid-decode must release its reservation — or blocks — or the
        // budget leaks and every later request starves at admission.
        // The budget fits exactly one worst-case request at a time, so
        // a single leak would block request N+1 forever; the timeout
        // turns that hang into a failure.
        for kv_policy in [KvPolicy::Reserve, KvPolicy::Paged { block_tokens: 4 }] {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                kv_bytes_per_token: 100,
                kv_budget_bytes: 16 * 100,
                kv_policy,
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 1, BackendFactory::sim_failing("opt-tiny", 64, 4));
            for i in 0..8i64 {
                let h = c.submit(Request::greedy("opt-tiny", vec![1, i + 1], 14)).unwrap();
                let err = wait_with_timeout(h, 30).unwrap_err();
                assert!(err.contains("injected fault"), "{kv_policy:?}: {err}");
            }
            let snap = c.metrics.snapshot();
            assert_eq!(snap.errors, 8, "{kv_policy:?}");
            assert_eq!(snap.rejected, 0, "{kv_policy:?}");
            c.shutdown();
        }
    }

    #[test]
    fn for_device_budget_subtracts_weights() {
        let device = crate::config::LpuConfig::asic_3_28tbs();
        let model = crate::model::by_name("opt-6.7b").unwrap();
        let cfg = CoordinatorConfig::for_device(&device, &model, SchedulerPolicy::RoundRobin);
        assert_eq!(
            cfg.kv_budget_bytes,
            device.hbm.capacity() - model.weight_bytes()
        );
        assert_eq!(cfg.kv_bytes_per_token, model.kv_bytes_per_token());
        // Sanity: the budget admits many full-length contexts.
        let per_ctx = model.kv_capacity_bytes(model.max_seq);
        assert!(cfg.kv_budget_bytes / per_ctx >= 8);
    }

    #[test]
    fn invalid_deadline_rejected() {
        let c = sim_coord(1);
        for bad in [-1.0, f64::NAN, f64::INFINITY] {
            let mut r = Request::greedy("opt-tiny", vec![1], 4);
            r.deadline_s = Some(bad);
            assert!(c.submit(r).is_err(), "deadline {bad} must be rejected");
        }
        c.shutdown();
    }

    #[test]
    fn deadline_expired_request_is_shed_with_timeout() {
        let c = sim_coord(2);
        // Already expired at submission: the worker must shed it at
        // admission, visibly, without reserving anything.
        let mut r = Request::greedy("opt-tiny", vec![1, 2], 8);
        r.deadline_s = Some(0.0);
        let err = c.submit(r).unwrap().wait().unwrap_err();
        assert!(err.contains("timeout"), "{err}");
        // A generous deadline changes nothing.
        let mut ok = Request::greedy("opt-tiny", vec![3], 4);
        ok.deadline_s = Some(3600.0);
        assert_eq!(c.submit(ok).unwrap().wait().unwrap().len(), 4);
        let snap = c.metrics.snapshot();
        assert_eq!(snap.shed_expired, 1);
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.completed, 1);
        c.shutdown();
    }

    /// Run `reqs` to completion under `cfg` on a 2-worker sim pool.
    fn run_streams(cfg: CoordinatorConfig, reqs: &[Request]) -> (Vec<Vec<i64>>, metrics::Snapshot) {
        let mut c = Coordinator::new(cfg);
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        let handles: Vec<_> = reqs.iter().map(|r| c.submit(r.clone()).unwrap()).collect();
        let streams =
            handles.into_iter().map(|h| wait_with_timeout(h, 60).unwrap()).collect();
        let snap = c.metrics.snapshot();
        c.shutdown();
        (streams, snap)
    }

    #[test]
    fn worker_crash_fails_over_lanes_and_streams_match() {
        // Kill worker 0 after 3 fused steps, mid-stream: its in-flight
        // lanes fail over to worker 1 and every request still completes
        // with a stream bit-identical to the fault-free run.
        let reqs: Vec<Request> =
            (0..8).map(|i| Request::greedy("opt-tiny", vec![i as i64 + 1], 12)).collect();
        let (baseline, base_snap) = run_streams(CoordinatorConfig::default(), &reqs);
        assert_eq!(base_snap.worker_crashes, 0);
        let (faulted, snap) = run_streams(
            CoordinatorConfig {
                faults: FaultPlan::parse("crash=0@3").unwrap(),
                ..CoordinatorConfig::default()
            },
            &reqs,
        );
        assert_eq!(faulted, baseline, "failover must not change any stream");
        assert!(faulted.iter().all(|t| t.len() == 12));
        assert_eq!(snap.worker_crashes, 1);
        assert!(snap.failovers >= 1, "crash must have salvaged at least one lane");
        assert_eq!(
            snap.failovers,
            snap.lanes_restored_on_failover + snap.lanes_recomputed_on_failover
        );
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.completed, 8);
    }

    #[test]
    fn transient_faults_retry_in_place_and_streams_match() {
        // A generous retry budget turns every injected transient into a
        // retried (delayed) step: all streams must match the fault-free
        // run exactly, with zero client-visible errors.
        let reqs: Vec<Request> =
            (0..6).map(|i| Request::greedy("opt-tiny", vec![i as i64 + 1; 4], 16)).collect();
        let (baseline, _) = run_streams(CoordinatorConfig::default(), &reqs);
        let (faulted, snap) = run_streams(
            CoordinatorConfig {
                faults: FaultPlan::parse(
                    "seed=11,transient=0.2,retries=1000000,backoff=0.00001",
                )
                .unwrap(),
                ..CoordinatorConfig::default()
            },
            &reqs,
        );
        assert_eq!(faulted, baseline, "retried transients must not change streams");
        assert!(snap.faults_injected > 0, "rate 0.2 over ~100 lane-steps must fire");
        assert_eq!(snap.retries, snap.faults_injected);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.completed, 6);
    }

    #[test]
    fn transient_retry_budget_exhaustion_fails_visibly() {
        // transient=1.0 faults every step: attempts 1 and 2 retry, the
        // third exceeds the budget and must surface as an error — never
        // a hang.
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 2,
            policy: SchedulerPolicy::RoundRobin,
            faults: FaultPlan::parse("transient=1.0,retries=2,backoff=0.00001").unwrap(),
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
        let h = c.submit(Request::greedy("opt-tiny", vec![1], 4)).unwrap();
        let err = wait_with_timeout(h, 30).unwrap_err();
        assert!(err.contains("retry budget"), "{err}");
        let snap = c.metrics.snapshot();
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.retries, 2);
        assert_eq!(snap.faults_injected, 3);
        c.shutdown();
    }

    #[test]
    fn crash_failover_with_fatal_errors_releases_kv_budget() {
        // Extends the sim_failing leak audit across a worker crash: the
        // backend fatally errors every lane at position 4, worker 0
        // crashes after 2 fused steps (salvaging its lane + stranding
        // its queue for steal), and the budget fits exactly one
        // worst-case request — one leaked reservation anywhere and a
        // later admission hangs (the timeout turns that into a fail).
        for kv_policy in [KvPolicy::Reserve, KvPolicy::Paged { block_tokens: 4 }] {
            let mut c = Coordinator::new(CoordinatorConfig {
                max_active_per_worker: 4,
                policy: SchedulerPolicy::RoundRobin,
                kv_bytes_per_token: 100,
                kv_budget_bytes: 16 * 100,
                kv_policy,
                faults: FaultPlan::parse("crash=0@2").unwrap(),
                ..CoordinatorConfig::default()
            });
            c.add_pool("opt-tiny", 2, BackendFactory::sim_failing("opt-tiny", 64, 4));
            let handles: Vec<_> = (0..8)
                .map(|i| c.submit(Request::greedy("opt-tiny", vec![1, i + 1], 14)).unwrap())
                .collect();
            for h in handles {
                let err = wait_with_timeout(h, 30).unwrap_err();
                assert!(err.contains("injected fault"), "{kv_policy:?}: {err}");
            }
            let snap = c.metrics.snapshot();
            assert_eq!(snap.errors, 8, "{kv_policy:?}");
            assert_eq!(snap.rejected, 0, "{kv_policy:?}");
            assert_eq!(snap.worker_crashes, 1, "{kv_policy:?}");
            c.shutdown();
        }
    }
}
