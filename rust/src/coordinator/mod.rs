//! The serving coordinator (HyperDex runtime layer).
//!
//! "HyperDex's runtime layer provides a collection of API for user
//! applications ... text generation, sampling, and streaming ... a device
//! driver beneath the runtime API ... extracts user-specified per-request
//! and per-core arguments ... monitoring tools that provide hardware-level
//! statistics."
//!
//! Architecture (std threads + channels; the environment has no tokio):
//!
//! ```text
//!   submit(Request) ──► Router ──► Pool(model A) ─► worker 0 ─┐
//!                          │                      └ worker 1  ├─ Backend
//!                          └─────► Pool(model B) ─► worker 0 ─┘  (PJRT or sim)
//!   TokenEvent stream ◄────────────── workers (mpsc per request)
//! ```
//!
//! Each worker owns one [`backend::Backend`] and runs **continuous
//! batching**: it holds a slot table of concurrently active requests,
//! admits new requests *between fused decode steps* (admission bounded
//! by a KV-memory budget derived from the device HBM capacity), advances
//! a batch of slots per step under the configured
//! [`scheduler::SchedulerPolicy`], and retires finished slots with
//! `swap_remove` (mirrored into the scheduler so per-slot policy state
//! follows the churn). A fused step streams the weights once for every
//! lane in the batch — the batch-mode vecmat reuse the paper lists as
//! future work — so worker throughput grows with concurrency while
//! per-token latency degrades only by the per-lane KV terms. Sampling
//! runs in the coordinator with the same [`crate::numerics::Sampler`]
//! the VXE model uses.

pub mod backend;
pub mod metrics;
pub mod scheduler;
pub mod workload;

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::numerics::{SampleParams, Sampler};

pub use backend::{Backend, BackendFactory, BatchLane, SimBackend, StepModel};
pub use metrics::{Metrics, Percentiles};
pub use scheduler::{KvBudget, Scheduler, SchedulerPolicy};
pub use workload::{
    run_open_loop, run_virtual, LenDist, LoadReport, VirtualConfig, VirtualReport, Workload,
};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Model to route to (pool name).
    pub model: String,
    pub prompt: Vec<i64>,
    pub max_new_tokens: usize,
    pub params: SampleParams,
    /// Stop early on this token id.
    pub eos_token: Option<i64>,
    /// Sampling seed (reproducible streams).
    pub seed: u64,
}

impl Request {
    pub fn greedy(model: &str, prompt: Vec<i64>, max_new_tokens: usize) -> Request {
        Request {
            model: model.to_string(),
            prompt,
            max_new_tokens,
            params: SampleParams::greedy(),
            eos_token: None,
            seed: 0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if self.max_new_tokens == 0 {
            return Err("max_new_tokens must be > 0".into());
        }
        self.params.validate()
    }

    /// Worst-case KV bytes this request can grow to (what admission
    /// control reserves up front).
    pub fn kv_need(&self, kv_bytes_per_token: u64) -> u64 {
        (self.prompt.len() + self.max_new_tokens) as u64 * kv_bytes_per_token
    }
}

/// A streamed generation event.
#[derive(Clone, Debug, PartialEq)]
pub enum TokenEvent {
    /// One generated token (with its index in the completion).
    Token { request_id: u64, index: usize, token: i64 },
    /// Generation finished (all tokens already streamed).
    Done { request_id: u64, tokens: Vec<i64>, reason: FinishReason },
    /// The request failed.
    Error { request_id: u64, message: String },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    Eos,
}

/// Handle for consuming one request's event stream.
pub struct RequestHandle {
    pub request_id: u64,
    pub events: Receiver<TokenEvent>,
}

impl RequestHandle {
    /// Block until completion; returns the generated tokens.
    pub fn wait(self) -> Result<Vec<i64>, String> {
        for ev in self.events.iter() {
            match ev {
                TokenEvent::Done { tokens, .. } => return Ok(tokens),
                TokenEvent::Error { message, .. } => return Err(message),
                TokenEvent::Token { .. } => {}
            }
        }
        Err("stream closed without completion".into())
    }
}

struct Job {
    request_id: u64,
    request: Request,
    events: Sender<TokenEvent>,
    submitted: Instant,
}

/// Decision an admission closure returns after peeking the queue head.
enum Admit {
    /// Pop it; the caller will admit it into a slot.
    Take,
    /// Pop it; the caller will refuse it (can never fit anywhere).
    Reject,
    /// Leave it at the head for a sibling worker with more headroom.
    Later,
}

/// Result of a peek-then-pop attempt on the pool queue.
enum Popped {
    Job(Job),
    Rejected(Job),
    None,
    Closed,
}

struct JobQueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Shared pool queue with head-peek admission. A worker inspects the
/// head job and only pops it if it can actually take (or must reject)
/// it; a job the worker cannot admit right now stays at the head for a
/// sibling with free KV — FIFO order is preserved and a saturated
/// worker never strands work another worker could serve.
struct JobQueue {
    state: Mutex<JobQueueState>,
    cv: Condvar,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue {
            state: Mutex::new(JobQueueState { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a job; `Err(job)` if the pool already shut down.
    fn push(&self, job: Job) -> Result<(), Job> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(job);
        }
        st.jobs.push_back(job);
        self.cv.notify_one();
        Ok(())
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Peek the head job with `decide` and pop it if taken/rejected.
    /// With `wait`, parks up to ~10ms for work when the queue is empty
    /// (the condvar releases the lock while parked, so producers and
    /// sibling workers are never blocked by an idle waiter).
    fn pop_with(&self, wait: bool, mut decide: impl FnMut(&Job) -> Admit) -> Popped {
        let mut st = self.state.lock().unwrap();
        if wait && st.jobs.is_empty() && !st.closed {
            st = self
                .cv
                .wait_timeout(st, std::time::Duration::from_millis(10))
                .unwrap()
                .0;
        }
        let decision = match st.jobs.front() {
            None => return if st.closed { Popped::Closed } else { Popped::None },
            Some(job) => decide(job),
        };
        match decision {
            Admit::Take => Popped::Job(st.jobs.pop_front().expect("head exists")),
            Admit::Reject => Popped::Rejected(st.jobs.pop_front().expect("head exists")),
            Admit::Later => Popped::None,
        }
    }
}

/// Per-model worker pool.
struct Pool {
    queue: Arc<JobQueue>,
    workers: Vec<JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max requests a worker holds in its slot table.
    pub max_active_per_worker: usize,
    pub policy: SchedulerPolicy,
    /// KV bytes one context token occupies (from
    /// [`crate::model::ModelConfig::kv_bytes_per_token`]); 0 disables
    /// KV admission control.
    pub kv_bytes_per_token: u64,
    /// Per-worker KV memory budget, bytes (`u64::MAX` = unbounded).
    pub kv_budget_bytes: u64,
    /// Max lanes per fused decode step (hardware batch cap); 0 means
    /// `max_active_per_worker`.
    pub max_batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::Fcfs,
            kv_bytes_per_token: 0,
            kv_budget_bytes: u64::MAX,
            max_batch: 0,
        }
    }
}

impl CoordinatorConfig {
    /// Derive admission limits from a device + model pair: the KV budget
    /// is whatever HBM capacity remains after the resident weights.
    pub fn for_device(
        device: &crate::config::LpuConfig,
        model: &crate::model::ModelConfig,
        policy: SchedulerPolicy,
    ) -> CoordinatorConfig {
        let budget = device.hbm.capacity().saturating_sub(model.weight_bytes());
        CoordinatorConfig {
            max_active_per_worker: 8,
            policy,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            kv_budget_bytes: budget.max(1),
            max_batch: 0,
        }
    }
}

/// The serving coordinator: router + pools + metrics.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    pools: HashMap<String, Pool>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator {
            cfg,
            pools: HashMap::new(),
            next_id: AtomicU64::new(1),
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// The scheduling policy this coordinator's workers run.
    pub fn policy(&self) -> SchedulerPolicy {
        self.cfg.policy
    }

    /// Register a model pool with `n_workers` backend instances. The
    /// factory runs *inside* each worker thread (PJRT handles are not
    /// `Send`; each worker owns its own client).
    pub fn add_pool(&mut self, model: &str, n_workers: usize, factory: BackendFactory) {
        let queue = Arc::new(JobQueue::new());
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let queue = Arc::clone(&queue);
            let factory = factory.clone();
            let metrics = Arc::clone(&self.metrics);
            let cfg = self.cfg.clone();
            let model = model.to_string();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lpu-worker-{model}-{w}"))
                    .spawn(move || worker_loop(queue, factory, metrics, cfg))
                    .expect("spawn worker"),
            );
        }
        self.pools.insert(model.to_string(), Pool { queue, workers });
    }

    /// Models this coordinator serves.
    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.pools.keys().cloned().collect();
        m.sort();
        m
    }

    /// Submit a request; returns a streaming handle.
    pub fn submit(&self, request: Request) -> Result<RequestHandle, String> {
        request.validate()?;
        let pool = self
            .pools
            .get(&request.model)
            .ok_or_else(|| format!("unknown model '{}' (have: {:?})", request.model, self.models()))?;
        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.metrics.on_submit();
        pool.queue
            .push(Job { request_id, request, events: tx, submitted: Instant::now() })
            .map_err(|_| "pool shut down".to_string())?;
        Ok(RequestHandle { request_id, events: rx })
    }

    /// Close pool queues and join workers (in-flight requests finish).
    pub fn shutdown(mut self) {
        let pools = std::mem::take(&mut self.pools);
        for (_, pool) in pools {
            pool.queue.close();
            for w in pool.workers {
                let _ = w.join();
            }
        }
    }
}

/// One active request's slot in a worker's table.
struct Slot {
    job: Job,
    session: Box<dyn Any>,
    sampler: Sampler,
    generated: Vec<i64>,
    prompt_fed: usize,
    /// KV bytes reserved at admission, released at retirement.
    kv_reserved: u64,
}

/// Why a slot leaves the table.
enum Retire {
    Done(FinishReason),
    Cancelled,
    Errored(String),
}

fn worker_loop(
    queue: Arc<JobQueue>,
    factory: BackendFactory,
    metrics: Arc<Metrics>,
    cfg: CoordinatorConfig,
) {
    let mut backend = match factory.build() {
        Ok(b) => b,
        Err(e) => {
            // Drain jobs with errors so clients don't hang.
            loop {
                match queue.pop_with(true, |_| Admit::Take) {
                    Popped::Job(job) | Popped::Rejected(job) => {
                        let _ = job.events.send(TokenEvent::Error {
                            request_id: job.request_id,
                            message: format!("backend init failed: {e}"),
                        });
                    }
                    Popped::None => {}
                    Popped::Closed => return,
                }
            }
        }
    };

    let mut scheduler = Scheduler::new(cfg.policy);
    let mut kv = KvBudget::new(cfg.kv_budget_bytes);
    let mut slots: Vec<Slot> = Vec::new();
    let max_batch =
        if cfg.max_batch == 0 { cfg.max_active_per_worker } else { cfg.max_batch };

    loop {
        // ---- admission: runs between every fused step, so requests
        // join mid-decode (continuous batching). The queue pops the
        // head only if this worker can take it (or it can never fit);
        // otherwise it stays at the head for a sibling with free KV.
        while slots.len() < cfg.max_active_per_worker {
            let popped = queue.pop_with(slots.is_empty(), |job| {
                let need = job.request.kv_need(cfg.kv_bytes_per_token);
                if need > kv.capacity() {
                    Admit::Reject
                } else if need <= kv.capacity().saturating_sub(kv.reserved()) {
                    Admit::Take
                } else {
                    Admit::Later
                }
            });
            match popped {
                Popped::Job(job) => {
                    let need = job.request.kv_need(cfg.kv_bytes_per_token);
                    let reserved = kv.try_reserve(need);
                    debug_assert!(reserved, "queue handed out a job beyond the KV budget");
                    match backend.new_session() {
                        Ok(session) => {
                            metrics.on_start(job.submitted.elapsed());
                            let seed = job.request.seed ^ job.request_id;
                            slots.push(Slot {
                                job,
                                session,
                                sampler: Sampler::new(seed),
                                generated: Vec::new(),
                                prompt_fed: 0,
                                kv_reserved: need,
                            });
                            scheduler.reset_slot(slots.len() - 1);
                        }
                        Err(e) => {
                            kv.release(need);
                            metrics.on_error();
                            let _ = job.events.send(TokenEvent::Error {
                                request_id: job.request_id,
                                message: format!("session: {e}"),
                            });
                        }
                    }
                }
                Popped::Rejected(job) => {
                    // Can never fit, even on an empty device: refuse
                    // rather than deadlock the admission queue.
                    let need = job.request.kv_need(cfg.kv_bytes_per_token);
                    metrics.on_reject();
                    let _ = job.events.send(TokenEvent::Error {
                        request_id: job.request_id,
                        message: format!(
                            "request needs {need} B of KV cache but the device budget is {} B",
                            kv.capacity()
                        ),
                    });
                }
                Popped::None => break,
                Popped::Closed => {
                    if slots.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }

        if slots.is_empty() {
            continue;
        }

        // ---- one fused batched step over the scheduled lanes ----
        let picked = scheduler.pick_batch(slots.len(), max_batch);
        let step_started = Instant::now();
        let mut lanes: Vec<BatchLane> = Vec::with_capacity(picked.len());
        for &i in &picked {
            let s = &mut slots[i];
            let token = if s.prompt_fed < s.job.request.prompt.len() {
                s.job.request.prompt[s.prompt_fed]
            } else {
                *s.generated.last().expect("generated nonempty after prompt")
            };
            let session = std::mem::replace(&mut s.session, Box::new(()));
            lanes.push(BatchLane { session, token });
        }
        let results = backend.decode_batch(&mut lanes);
        metrics.on_batch_step(picked.len());
        let step_elapsed = step_started.elapsed();

        debug_assert_eq!(results.len(), picked.len(), "backend lane-count contract");
        let mut retire: Vec<(usize, Retire)> = Vec::new();
        for ((lane, &i), result) in lanes.iter_mut().zip(&picked).zip(results) {
            slots[i].session = std::mem::replace(&mut lane.session, Box::new(()));
            match result {
                Ok(logits) => {
                    let s = &mut slots[i];
                    if s.prompt_fed < s.job.request.prompt.len() {
                        s.prompt_fed += 1;
                        if s.prompt_fed < s.job.request.prompt.len() {
                            // Still prefilling: a pick without a token.
                            scheduler.note_progress(i, s.generated.len());
                            continue;
                        }
                    }
                    let token = s.sampler.sample(&logits, &s.job.request.params) as i64;
                    s.generated.push(token);
                    if s.generated.len() == 1 {
                        metrics.on_first_token(s.job.submitted.elapsed());
                    }
                    metrics.on_token(step_elapsed);
                    scheduler.note_progress(i, s.generated.len());
                    let receiver_alive = s
                        .job
                        .events
                        .send(TokenEvent::Token {
                            request_id: s.job.request_id,
                            index: s.generated.len() - 1,
                            token,
                        })
                        .is_ok();
                    if !receiver_alive {
                        // Client went away mid-stream: cancel so the
                        // device stops burning tokens on it.
                        retire.push((i, Retire::Cancelled));
                        continue;
                    }
                    let eos_hit = s.job.request.eos_token == Some(token);
                    let len_hit = s.generated.len() >= s.job.request.max_new_tokens;
                    if eos_hit || len_hit {
                        let reason =
                            if eos_hit { FinishReason::Eos } else { FinishReason::Length };
                        retire.push((i, Retire::Done(reason)));
                    }
                }
                Err(e) => retire.push((i, Retire::Errored(e.to_string()))),
            }
        }

        // Retire in descending index order so swap_remove indices stay
        // valid; mirror every removal into the scheduler.
        retire.sort_by(|a, b| b.0.cmp(&a.0));
        for (i, why) in retire {
            let s = slots.swap_remove(i);
            scheduler.swap_remove(i);
            kv.release(s.kv_reserved);
            match why {
                Retire::Done(reason) => {
                    metrics.on_done(s.generated.len(), s.job.submitted.elapsed());
                    let _ = s.job.events.send(TokenEvent::Done {
                        request_id: s.job.request_id,
                        tokens: s.generated,
                        reason,
                    });
                }
                Retire::Cancelled => metrics.on_cancel(s.generated.len()),
                Retire::Errored(message) => {
                    metrics.on_error();
                    let _ = s
                        .job
                        .events
                        .send(TokenEvent::Error { request_id: s.job.request_id, message });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;

    fn sim_coord(max_active: usize) -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: max_active,
            policy: SchedulerPolicy::RoundRobin,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        c
    }

    #[test]
    fn single_request_completes() {
        let c = sim_coord(2);
        let h = c.submit(Request::greedy("opt-tiny", vec![1, 2, 3], 8)).unwrap();
        let tokens = h.wait().unwrap();
        assert_eq!(tokens.len(), 8);
        c.shutdown();
    }

    #[test]
    fn streaming_events_are_ordered() {
        let c = sim_coord(2);
        let h = c.submit(Request::greedy("opt-tiny", vec![5], 5)).unwrap();
        let mut indices = Vec::new();
        let mut done = false;
        for ev in h.events.iter() {
            match ev {
                TokenEvent::Token { index, .. } => indices.push(index),
                TokenEvent::Done { tokens, reason, .. } => {
                    assert_eq!(tokens.len(), 5);
                    assert_eq!(reason, FinishReason::Length);
                    done = true;
                }
                TokenEvent::Error { message, .. } => panic!("{message}"),
            }
        }
        assert!(done);
        assert_eq!(indices, vec![0, 1, 2, 3, 4]);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_finish() {
        let c = sim_coord(4);
        let handles: Vec<_> = (0..16)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![i as i64 + 1], 6)).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap().len(), 6);
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.completed, 16);
        assert_eq!(snap.tokens_out, 16 * 6);
        assert!(snap.batch_steps > 0);
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let c = sim_coord(1);
        let err = match c.submit(Request::greedy("gpt-5", vec![1], 1)) {
            Err(e) => e,
            Ok(_) => panic!("expected rejection"),
        };
        assert!(err.contains("unknown model"), "{err}");
        c.shutdown();
    }

    #[test]
    fn invalid_request_rejected() {
        let c = sim_coord(1);
        assert!(c.submit(Request::greedy("opt-tiny", vec![], 1)).is_err());
        let mut r = Request::greedy("opt-tiny", vec![1], 0);
        r.max_new_tokens = 0;
        assert!(c.submit(r).is_err());
        c.shutdown();
    }

    #[test]
    fn eos_stops_generation() {
        // SimBackend logits are deterministic; find which token greedy
        // picks first, then use it as EOS for a second request.
        let c = sim_coord(1);
        let h = c.submit(Request::greedy("opt-tiny", vec![9], 4)).unwrap();
        let toks = h.wait().unwrap();
        let mut r = Request::greedy("opt-tiny", vec![9], 100);
        r.eos_token = Some(toks[0]);
        let h2 = c.submit(r).unwrap();
        let toks2 = h2.wait().unwrap();
        assert_eq!(toks2.len(), 1);
        assert_eq!(toks2[0], toks[0]);
        c.shutdown();
    }

    #[test]
    fn client_disconnect_cancels_request() {
        let c = sim_coord(2);
        // Submit a long request and drop the handle immediately.
        let h = c.submit(Request::greedy("opt-tiny", vec![1], 100_000)).unwrap();
        drop(h);
        // A subsequent request must still be served promptly (the worker
        // did not spend 100k tokens on the orphan).
        let t0 = std::time::Instant::now();
        let toks = c.submit(Request::greedy("opt-tiny", vec![2], 4)).unwrap().wait().unwrap();
        assert_eq!(toks.len(), 4);
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        // Wait for the cancel to be recorded.
        for _ in 0..200 {
            if c.metrics.snapshot().cancelled >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(c.metrics.snapshot().cancelled, 1);
        c.shutdown();
    }

    #[test]
    fn deterministic_greedy_across_runs() {
        let c = sim_coord(2);
        let a = c.submit(Request::greedy("opt-tiny", vec![1, 2], 6)).unwrap().wait().unwrap();
        let b = c.submit(Request::greedy("opt-tiny", vec![1, 2], 6)).unwrap().wait().unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn batching_does_not_change_tokens() {
        // The same request must produce identical tokens whether it runs
        // alone (batch of 1) or interleaved with 7 neighbors.
        let solo = {
            let c = sim_coord(1);
            let t = c.submit(Request::greedy("opt-tiny", vec![3, 4], 10)).unwrap().wait().unwrap();
            c.shutdown();
            t
        };
        let c = sim_coord(8);
        let noise: Vec<_> = (0..7)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![40 + i], 10)).unwrap())
            .collect();
        let t = c.submit(Request::greedy("opt-tiny", vec![3, 4], 10)).unwrap().wait().unwrap();
        for h in noise {
            h.wait().unwrap();
        }
        assert_eq!(t, solo);
        c.shutdown();
    }

    #[test]
    fn kv_overflow_request_rejected_with_error() {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 1000,
            kv_budget_bytes: 10_000, // 10 tokens of KV
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
        // Needs (2 + 50) * 1000 B > 10_000 B: impossible even when idle.
        let h = c.submit(Request::greedy("opt-tiny", vec![1, 2], 50)).unwrap();
        let err = h.wait().unwrap_err();
        assert!(err.contains("KV"), "{err}");
        assert_eq!(c.metrics.snapshot().rejected, 1);
        // A request that fits still completes.
        let ok = c.submit(Request::greedy("opt-tiny", vec![1], 4)).unwrap().wait().unwrap();
        assert_eq!(ok.len(), 4);
        c.shutdown();
    }

    #[test]
    fn kv_budget_throttles_concurrency_without_losing_requests() {
        // Budget fits exactly two in-flight requests; submit six. All
        // must complete (head-peek admission), never more than two at
        // once.
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 6,
            policy: SchedulerPolicy::RoundRobin,
            kv_bytes_per_token: 100,
            kv_budget_bytes: 2 * (1 + 8) * 100,
            ..CoordinatorConfig::default()
        });
        c.add_pool("opt-tiny", 1, BackendFactory::sim("opt-tiny", 64));
        let handles: Vec<_> = (0..6)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![i + 1], 8)).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap().len(), 8);
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.rejected, 0);
        // With ≤2 concurrent lanes, no fused step can exceed 2 lanes.
        assert!(snap.mean_batch_size <= 2.0 + 1e-9, "{}", snap.mean_batch_size);
        c.shutdown();
    }

    #[test]
    fn for_device_budget_subtracts_weights() {
        let device = crate::config::LpuConfig::asic_3_28tbs();
        let model = crate::model::by_name("opt-6.7b").unwrap();
        let cfg = CoordinatorConfig::for_device(&device, &model, SchedulerPolicy::RoundRobin);
        assert_eq!(
            cfg.kv_budget_bytes,
            device.hbm.capacity() - model.weight_bytes()
        );
        assert_eq!(cfg.kv_bytes_per_token, model.kv_bytes_per_token());
        // Sanity: the budget admits many full-length contexts.
        let per_ctx = model.kv_capacity_bytes(model.max_seq);
        assert!(cfg.kv_budget_bytes / per_ctx >= 8);
    }
}
