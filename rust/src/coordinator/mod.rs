//! The serving coordinator (HyperDex runtime layer).
//!
//! "HyperDex's runtime layer provides a collection of API for user
//! applications ... text generation, sampling, and streaming ... a device
//! driver beneath the runtime API ... extracts user-specified per-request
//! and per-core arguments ... monitoring tools that provide hardware-level
//! statistics."
//!
//! Architecture (std threads + channels; the environment has no tokio):
//!
//! ```text
//!   submit(Request) ──► Router ──► Pool(model A) ─► worker 0 ─┐
//!                          │                      └ worker 1  ├─ Backend
//!                          └─────► Pool(model B) ─► worker 0 ─┘  (PJRT or sim)
//!   TokenEvent stream ◄────────────── workers (mpsc per request)
//! ```
//!
//! Each worker owns one [`backend::Backend`] (a PJRT engine or the cycle
//! simulator) and interleaves active requests **token by token**
//! (continuous batching at the token level — the scheduling granularity
//! the LPU's single-token latency makes natural). Sampling runs in the
//! coordinator with the same [`crate::numerics::Sampler`] the VXE model
//! uses.

pub mod backend;
pub mod metrics;
pub mod scheduler;
pub mod workload;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::numerics::{SampleParams, Sampler};

pub use backend::{Backend, BackendFactory, SimBackend};
pub use metrics::Metrics;
pub use scheduler::{Scheduler, SchedulerPolicy};
pub use workload::{run_open_loop, LenDist, LoadReport, Workload};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Model to route to (pool name).
    pub model: String,
    pub prompt: Vec<i64>,
    pub max_new_tokens: usize,
    pub params: SampleParams,
    /// Stop early on this token id.
    pub eos_token: Option<i64>,
    /// Sampling seed (reproducible streams).
    pub seed: u64,
}

impl Request {
    pub fn greedy(model: &str, prompt: Vec<i64>, max_new_tokens: usize) -> Request {
        Request {
            model: model.to_string(),
            prompt,
            max_new_tokens,
            params: SampleParams::greedy(),
            eos_token: None,
            seed: 0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.prompt.is_empty() {
            return Err("empty prompt".into());
        }
        if self.max_new_tokens == 0 {
            return Err("max_new_tokens must be > 0".into());
        }
        self.params.validate()
    }
}

/// A streamed generation event.
#[derive(Clone, Debug, PartialEq)]
pub enum TokenEvent {
    /// One generated token (with its index in the completion).
    Token { request_id: u64, index: usize, token: i64 },
    /// Generation finished (all tokens already streamed).
    Done { request_id: u64, tokens: Vec<i64>, reason: FinishReason },
    /// The request failed.
    Error { request_id: u64, message: String },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    Eos,
}

/// Handle for consuming one request's event stream.
pub struct RequestHandle {
    pub request_id: u64,
    pub events: Receiver<TokenEvent>,
}

impl RequestHandle {
    /// Block until completion; returns the generated tokens.
    pub fn wait(self) -> Result<Vec<i64>, String> {
        for ev in self.events.iter() {
            match ev {
                TokenEvent::Done { tokens, .. } => return Ok(tokens),
                TokenEvent::Error { message, .. } => return Err(message),
                TokenEvent::Token { .. } => {}
            }
        }
        Err("stream closed without completion".into())
    }
}

struct Job {
    request_id: u64,
    request: Request,
    events: Sender<TokenEvent>,
    submitted: Instant,
}

/// Per-model worker pool.
struct Pool {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max requests a worker interleaves concurrently.
    pub max_active_per_worker: usize,
    pub policy: SchedulerPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { max_active_per_worker: 4, policy: SchedulerPolicy::Fcfs }
    }
}

/// The serving coordinator: router + pools + metrics.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    pools: HashMap<String, Pool>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator {
            cfg,
            pools: HashMap::new(),
            next_id: AtomicU64::new(1),
            metrics: Arc::new(Metrics::new()),
        }
    }

    /// Register a model pool with `n_workers` backend instances. The
    /// factory runs *inside* each worker thread (PJRT handles are not
    /// `Send`; each worker owns its own client).
    pub fn add_pool(&mut self, model: &str, n_workers: usize, factory: BackendFactory) {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let rx = Arc::clone(&rx);
            let factory = factory.clone();
            let metrics = Arc::clone(&self.metrics);
            let cfg = self.cfg.clone();
            let model = model.to_string();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lpu-worker-{model}-{w}"))
                    .spawn(move || worker_loop(rx, factory, metrics, cfg))
                    .expect("spawn worker"),
            );
        }
        self.pools.insert(model.to_string(), Pool { tx, workers });
    }

    /// Models this coordinator serves.
    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.pools.keys().cloned().collect();
        m.sort();
        m
    }

    /// Submit a request; returns a streaming handle.
    pub fn submit(&self, request: Request) -> Result<RequestHandle, String> {
        request.validate()?;
        let pool = self
            .pools
            .get(&request.model)
            .ok_or_else(|| format!("unknown model '{}' (have: {:?})", request.model, self.models()))?;
        let request_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.metrics.on_submit();
        pool.tx
            .send(Job { request_id, request, events: tx, submitted: Instant::now() })
            .map_err(|_| "pool shut down".to_string())?;
        Ok(RequestHandle { request_id, events: rx })
    }

    /// Drop pool senders and join workers.
    pub fn shutdown(mut self) {
        let pools = std::mem::take(&mut self.pools);
        for (_, pool) in pools {
            drop(pool.tx);
            for w in pool.workers {
                let _ = w.join();
            }
        }
    }
}

struct Active {
    job: Job,
    session: Box<dyn std::any::Any>,
    sampler: Sampler,
    generated: Vec<i64>,
    prompt_fed: usize,
    first_token_at: Option<Instant>,
}

fn worker_loop(
    rx: Arc<std::sync::Mutex<Receiver<Job>>>,
    factory: BackendFactory,
    metrics: Arc<Metrics>,
    cfg: CoordinatorConfig,
) {
    let mut backend = match factory.build() {
        Ok(b) => b,
        Err(e) => {
            // Drain jobs with errors so clients don't hang.
            while let Ok(job) = rx.lock().unwrap().recv() {
                let _ = job.events.send(TokenEvent::Error {
                    request_id: job.request_id,
                    message: format!("backend init failed: {e}"),
                });
            }
            return;
        }
    };

    let mut scheduler = Scheduler::new(cfg.policy);
    let mut active: Vec<Active> = Vec::new();

    enum Got {
        Job(Job),
        Nothing,
        Shutdown,
    }

    loop {
        // Admit new work. The queue mutex must never be held across a
        // blocking recv (it would starve sibling workers), so idle
        // workers poll with a short recv_timeout instead.
        while active.len() < cfg.max_active_per_worker {
            let got = if !active.is_empty() {
                // Busy workers must never wait on the queue mutex (an
                // idle sibling may be parked in recv_timeout holding it):
                // opportunistic try_lock + try_recv only.
                match rx.try_lock() {
                    Ok(guard) => match guard.try_recv() {
                        Ok(j) => Got::Job(j),
                        Err(_) => Got::Nothing,
                    },
                    Err(_) => Got::Nothing,
                }
            } else {
                let guard = rx.lock().unwrap();
                match guard.recv_timeout(std::time::Duration::from_millis(10)) {
                    Ok(j) => Got::Job(j),
                    Err(mpsc::RecvTimeoutError::Timeout) => Got::Nothing,
                    Err(mpsc::RecvTimeoutError::Disconnected) => Got::Shutdown,
                }
            };
            let job = match got {
                Got::Job(j) => j,
                Got::Nothing => break,
                Got::Shutdown => return,
            };
            match backend.new_session() {
                Ok(session) => {
                    metrics.on_start(job.submitted.elapsed());
                    let seed = job.request.seed ^ job.request_id;
                    active.push(Active {
                        job,
                        session,
                        sampler: Sampler::new(seed),
                        generated: Vec::new(),
                        prompt_fed: 0,
                        first_token_at: None,
                    });
                }
                Err(e) => {
                    let _ = job.events.send(TokenEvent::Error {
                        request_id: job.request_id,
                        message: format!("session: {e}"),
                    });
                }
            }
        }

        if active.is_empty() {
            continue;
        }

        // One token of progress for the scheduled request.
        let idx = scheduler.pick(active.len());
        let a = &mut active[idx];
        let step_started = Instant::now();
        let next_input = if a.prompt_fed < a.job.request.prompt.len() {
            a.job.request.prompt[a.prompt_fed]
        } else {
            *a.generated.last().expect("generated nonempty after prompt")
        };

        let result = backend.decode(&mut a.session, next_input);
        match result {
            Ok(logits) => {
                if a.prompt_fed < a.job.request.prompt.len() {
                    a.prompt_fed += 1;
                    // Emit the first generated token when prompt completes.
                    if a.prompt_fed < a.job.request.prompt.len() {
                        continue;
                    }
                }
                let token = a.sampler.sample(&logits, &a.job.request.params) as i64;
                a.generated.push(token);
                if a.first_token_at.is_none() {
                    a.first_token_at = Some(Instant::now());
                    metrics.on_first_token(a.job.submitted.elapsed());
                }
                metrics.on_token(step_started.elapsed());
                let receiver_alive = a
                    .job
                    .events
                    .send(TokenEvent::Token {
                        request_id: a.job.request_id,
                        index: a.generated.len() - 1,
                        token,
                    })
                    .is_ok();
                if !receiver_alive {
                    // Client went away mid-stream: cancel the request so
                    // the device stops burning tokens on it.
                    let a = active.swap_remove(idx);
                    metrics.on_cancel(a.generated.len());
                    continue;
                }
                let eos_hit = a.job.request.eos_token == Some(token);
                let len_hit = a.generated.len() >= a.job.request.max_new_tokens;
                if eos_hit || len_hit {
                    let a = active.swap_remove(idx);
                    metrics.on_done(a.generated.len(), a.job.submitted.elapsed());
                    let _ = a.job.events.send(TokenEvent::Done {
                        request_id: a.job.request_id,
                        tokens: a.generated,
                        reason: if eos_hit { FinishReason::Eos } else { FinishReason::Length },
                    });
                }
            }
            Err(e) => {
                let a = active.swap_remove(idx);
                metrics.on_error();
                let _ = a.job.events.send(TokenEvent::Error {
                    request_id: a.job.request_id,
                    message: e.to_string(),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;

    fn sim_coord(max_active: usize) -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: max_active,
            policy: SchedulerPolicy::RoundRobin,
        });
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        c
    }

    #[test]
    fn single_request_completes() {
        let c = sim_coord(2);
        let h = c.submit(Request::greedy("opt-tiny", vec![1, 2, 3], 8)).unwrap();
        let tokens = h.wait().unwrap();
        assert_eq!(tokens.len(), 8);
        c.shutdown();
    }

    #[test]
    fn streaming_events_are_ordered() {
        let c = sim_coord(2);
        let h = c.submit(Request::greedy("opt-tiny", vec![5], 5)).unwrap();
        let mut indices = Vec::new();
        let mut done = false;
        for ev in h.events.iter() {
            match ev {
                TokenEvent::Token { index, .. } => indices.push(index),
                TokenEvent::Done { tokens, reason, .. } => {
                    assert_eq!(tokens.len(), 5);
                    assert_eq!(reason, FinishReason::Length);
                    done = true;
                }
                TokenEvent::Error { message, .. } => panic!("{message}"),
            }
        }
        assert!(done);
        assert_eq!(indices, vec![0, 1, 2, 3, 4]);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_finish() {
        let c = sim_coord(4);
        let handles: Vec<_> = (0..16)
            .map(|i| c.submit(Request::greedy("opt-tiny", vec![i as i64 + 1], 6)).unwrap())
            .collect();
        for h in handles {
            assert_eq!(h.wait().unwrap().len(), 6);
        }
        let snap = c.metrics.snapshot();
        assert_eq!(snap.completed, 16);
        assert_eq!(snap.tokens_out, 16 * 6);
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let c = sim_coord(1);
        let err = match c.submit(Request::greedy("gpt-5", vec![1], 1)) {
            Err(e) => e,
            Ok(_) => panic!("expected rejection"),
        };
        assert!(err.contains("unknown model"), "{err}");
        c.shutdown();
    }

    #[test]
    fn invalid_request_rejected() {
        let c = sim_coord(1);
        assert!(c.submit(Request::greedy("opt-tiny", vec![], 1)).is_err());
        let mut r = Request::greedy("opt-tiny", vec![1], 0);
        r.max_new_tokens = 0;
        assert!(c.submit(r).is_err());
        c.shutdown();
    }

    #[test]
    fn eos_stops_generation() {
        // SimBackend logits are deterministic; find which token greedy
        // picks first, then use it as EOS for a second request.
        let c = sim_coord(1);
        let h = c.submit(Request::greedy("opt-tiny", vec![9], 4)).unwrap();
        let toks = h.wait().unwrap();
        let mut r = Request::greedy("opt-tiny", vec![9], 100);
        r.eos_token = Some(toks[0]);
        let h2 = c.submit(r).unwrap();
        let toks2 = h2.wait().unwrap();
        assert_eq!(toks2.len(), 1);
        assert_eq!(toks2[0], toks[0]);
        c.shutdown();
    }

    #[test]
    fn client_disconnect_cancels_request() {
        let c = sim_coord(2);
        // Submit a long request and drop the handle immediately.
        let h = c.submit(Request::greedy("opt-tiny", vec![1], 100_000)).unwrap();
        drop(h);
        // A subsequent request must still be served promptly (the worker
        // did not spend 100k tokens on the orphan).
        let t0 = std::time::Instant::now();
        let toks = c.submit(Request::greedy("opt-tiny", vec![2], 4)).unwrap().wait().unwrap();
        assert_eq!(toks.len(), 4);
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        // Wait for the cancel to be recorded.
        for _ in 0..200 {
            if c.metrics.snapshot().cancelled >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(c.metrics.snapshot().cancelled, 1);
        c.shutdown();
    }

    #[test]
    fn deterministic_greedy_across_runs() {
        let c = sim_coord(2);
        let a = c.submit(Request::greedy("opt-tiny", vec![1, 2], 6)).unwrap().wait().unwrap();
        let b = c.submit(Request::greedy("opt-tiny", vec![1, 2], 6)).unwrap().wait().unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }
}
