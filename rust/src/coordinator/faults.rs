//! Deterministic fault injection for the serving stack: the seeded
//! [`FaultPlan`] and the error taxonomy ([`FaultKind`]) the recovery
//! machinery classifies against.
//!
//! A fleet's defining property is that workers fail mid-stream, and a
//! recovery path that can only be exercised by real hardware falling
//! over can never be tested. This module makes failure a *pure function
//! of the plan*: every injection decision is keyed on deterministic
//! progress indices — a worker's fused-step count and a lane's request
//! id — never on wall time, so the threaded worker loop and the
//! virtual-time harness consult the same plan and reach the same
//! decisions, and the same seed replays the same crash, the same
//! transient faults, and the same recovery placements run after run.
//!
//! The plan injects three failure shapes:
//!
//! * **Transient step errors** (`transient=RATE`): a planned lane's
//!   share of a fused step errors before it is fed (the feed never
//!   happens, so the backend session does not advance and an in-place
//!   retry re-feeds the identical span). Recovery: bounded per-request
//!   retries with exponential backoff; exhaustion is a visible failure,
//!   never a hang.
//! * **Whole-worker crashes** (`crash=WORKER@STEP`): the worker dies
//!   when its fused-step count reaches `STEP`. Recovery: its in-flight
//!   lanes release all KV through the usual choke point and fail over
//!   to healthy siblings as resumable jobs; its queue is marked dead
//!   (stealable immediately) and the [`super::router::Router`] health
//!   mask excludes it from steering.
//! * **Slow-worker degradation** (`slow=WORKERxFACTOR`): the worker's
//!   fused steps take `FACTOR`× their modeled/measured time — the
//!   degraded-but-alive node whose traffic the load-aware policies
//!   route around.
//!
//! Because token streams are a pure function of (model, prompt, sampler
//! seed) — scheduling only moves *when* tokens happen, never *which* —
//! every request that survives recovery emits a stream bit-identical to
//! the fault-free run. The fault-streams proptests and the
//! `fault_recovery` bench cell pin exactly that.

use crate::err;
use crate::util::error::Result;

/// Default per-request in-place retry budget for transient step faults.
pub const DEFAULT_RETRY_BUDGET: u32 = 3;

/// Default base of the exponential retry backoff, seconds (doubles per
/// attempt: 1 ms, 2 ms, 4 ms, ...). Virtual seconds in the harness,
/// wall seconds on the threaded path.
pub const DEFAULT_BACKOFF_BASE_S: f64 = 0.001;

/// The two-point error taxonomy recovery classifies every lane error
/// into — injected or organic (a real [`super::backend::Backend`]
/// refusing a step).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Worth retrying in place: the step itself failed, not the lane's
    /// state. Retried under the bounded budget with backoff.
    Transient,
    /// The lane cannot make progress (poisoned session, refused
    /// restore): released through the KV choke point and failed
    /// visibly.
    Fatal,
}

impl FaultKind {
    /// Classify a backend error message. Errors carrying the
    /// "transient" marker — the plan's injected step faults — retry;
    /// everything else (e.g. the sim's position faults, a foreign
    /// session, a refused restore) is state corruption and fatal.
    pub fn classify(message: &str) -> FaultKind {
        if message.contains("transient") {
            FaultKind::Transient
        } else {
            FaultKind::Fatal
        }
    }
}

/// A whole-worker crash point: the worker dies when its fused-step
/// count reaches `at_step`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrashSpec {
    /// Worker index that crashes.
    pub worker: usize,
    /// Fused-step count (per that worker) at which it dies.
    pub at_step: u64,
}

/// A slow-worker degradation: every fused step on `worker` takes
/// `factor`× its normal time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlowSpec {
    /// Worker index that degrades.
    pub worker: usize,
    /// Latency multiplier (>= 1 is a slowdown; values below 1 are
    /// clamped to 1 at query time).
    pub factor: f64,
}

/// A seeded, deterministic fault-injection plan, shared verbatim by the
/// threaded worker loop and the virtual harness. Parsed from the
/// `--fault-plan` CLI spec; see [`FaultPlan::parse`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the transient-fault hash (and nothing else: crash and
    /// slow points are explicit, not sampled).
    pub seed: u64,
    /// Per (worker, step, lane) probability that the lane's share of
    /// that fused step errors transiently. 0 disables.
    pub transient_rate: f64,
    /// Max in-place retries per request before the failure is surfaced.
    pub retry_budget: u32,
    /// Base of the exponential backoff, seconds (doubles per attempt).
    pub backoff_base_s: f64,
    /// At most one whole-worker crash per plan.
    pub crash: Option<CrashSpec>,
    /// At most one degraded worker per plan.
    pub slow: Option<SlowSpec>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            transient_rate: 0.0,
            retry_budget: DEFAULT_RETRY_BUDGET,
            backoff_base_s: DEFAULT_BACKOFF_BASE_S,
            crash: None,
            slow: None,
        }
    }
}

impl FaultPlan {
    /// Parse a `--fault-plan` spec: comma-separated `key=value` fields,
    /// any subset of
    ///
    /// ```text
    /// seed=U64            transient-fault hash seed        (default 0)
    /// transient=RATE      per-lane-step fault probability  (default 0)
    /// retries=N           per-request retry budget         (default 3)
    /// backoff=SECONDS     backoff base, doubles per try    (default 0.001)
    /// crash=WORKER@STEP   kill worker at its fused step count
    /// slow=WORKERxFACTOR  multiply a worker's step latency
    /// ```
    ///
    /// e.g. `seed=7,transient=0.01,crash=1@40,slow=2x3.0`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for field in spec.split(',').map(str::trim).filter(|f| !f.is_empty()) {
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| err!("fault-plan field `{field}` is not key=value"))?;
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| err!("fault-plan seed `{value}` is not a u64"))?;
                }
                "transient" => {
                    let rate: f64 = value
                        .parse()
                        .map_err(|_| err!("fault-plan transient rate `{value}`"))?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(err!("fault-plan transient rate {rate} not in [0, 1]"));
                    }
                    plan.transient_rate = rate;
                }
                "retries" => {
                    plan.retry_budget = value
                        .parse()
                        .map_err(|_| err!("fault-plan retries `{value}` is not a u32"))?;
                }
                "backoff" => {
                    let base: f64 = value
                        .parse()
                        .map_err(|_| err!("fault-plan backoff `{value}`"))?;
                    if !base.is_finite() || base < 0.0 {
                        return Err(err!("fault-plan backoff {base} must be finite and >= 0"));
                    }
                    plan.backoff_base_s = base;
                }
                "crash" => {
                    let (w, s) = value
                        .split_once('@')
                        .ok_or_else(|| err!("fault-plan crash `{value}` is not WORKER@STEP"))?;
                    plan.crash = Some(CrashSpec {
                        worker: w
                            .parse()
                            .map_err(|_| err!("fault-plan crash worker `{w}`"))?,
                        at_step: s
                            .parse()
                            .map_err(|_| err!("fault-plan crash step `{s}`"))?,
                    });
                }
                "slow" => {
                    let (w, f) = value
                        .split_once('x')
                        .ok_or_else(|| err!("fault-plan slow `{value}` is not WORKERxFACTOR"))?;
                    let factor: f64 =
                        f.parse().map_err(|_| err!("fault-plan slow factor `{f}`"))?;
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(err!("fault-plan slow factor {factor} must be positive"));
                    }
                    plan.slow = Some(SlowSpec {
                        worker: w.parse().map_err(|_| err!("fault-plan slow worker `{w}`"))?,
                        factor,
                    });
                }
                other => return Err(err!("unknown fault-plan field `{other}`")),
            }
        }
        Ok(plan)
    }

    /// Whether the plan can inject anything at all (a no-op plan lets
    /// callers skip the fault bookkeeping entirely).
    pub fn is_active(&self) -> bool {
        self.transient_rate > 0.0 || self.crash.is_some() || self.slow.is_some()
    }

    /// Whether the lane serving `request_id` errors transiently on
    /// `worker`'s fused step number `step`. Pure in its arguments: both
    /// drivers ask with their own progress counters and a rerun with
    /// the same seed asks the same questions and gets the same answers.
    pub fn transient_at(&self, worker: usize, step: u64, request_id: u64) -> bool {
        if self.transient_rate <= 0.0 {
            return false;
        }
        if self.transient_rate >= 1.0 {
            return true;
        }
        let h = mix(self.seed ^ mix((worker as u64) << 32 ^ step) ^ mix(request_id));
        ((h >> 11) as f64 / (1u64 << 53) as f64) < self.transient_rate
    }

    /// Whether `worker` is (past) its crash point at fused step `step`.
    /// `>=`, not `==`: a worker that idles across its exact crash step
    /// still dies the next time it would do work.
    pub fn crashes_at(&self, worker: usize, step: u64) -> bool {
        self.crash.map_or(false, |c| c.worker == worker && step >= c.at_step)
    }

    /// Latency multiplier for `worker`'s fused steps (1.0 = healthy).
    pub fn slow_factor(&self, worker: usize) -> f64 {
        match self.slow {
            Some(s) if s.worker == worker => s.factor.max(1.0),
            _ => 1.0,
        }
    }

    /// Backoff before retry number `attempt` (1-based), seconds:
    /// `base × 2^(attempt-1)`, exponent capped so a misconfigured
    /// budget cannot overflow into a multi-hour sleep.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        self.backoff_base_s * f64::from(1u32 << attempt.saturating_sub(1).min(16))
    }

    /// The injected transient error for `worker`'s step `step` — the
    /// message carries the marker [`FaultKind::classify`] keys on.
    pub fn transient_error(&self, worker: usize, step: u64) -> crate::util::error::Error {
        err!("transient fault injected on worker {worker} at step {step}")
    }
}

/// splitmix64 finalizer: the stateless hash behind
/// [`FaultPlan::transient_at`]. Self-contained so the decision function
/// can never drift with an RNG implementation.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_roundtrips_fields() {
        let p = FaultPlan::parse("seed=7,transient=0.25,retries=5,backoff=0.002,crash=1@40,slow=2x3.0")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.transient_rate, 0.25);
        assert_eq!(p.retry_budget, 5);
        assert_eq!(p.backoff_base_s, 0.002);
        assert_eq!(p.crash, Some(CrashSpec { worker: 1, at_step: 40 }));
        assert_eq!(p.slow, Some(SlowSpec { worker: 2, factor: 3.0 }));
        assert!(p.is_active());
    }

    #[test]
    fn parse_empty_spec_is_the_inactive_default() {
        let p = FaultPlan::parse("").unwrap();
        assert_eq!(p, FaultPlan::default());
        assert!(!p.is_active());
        assert!(!p.transient_at(0, 0, 0));
        assert!(!p.crashes_at(0, 1_000_000));
        assert_eq!(p.slow_factor(3), 1.0);
    }

    #[test]
    fn parse_rejects_malformed_fields() {
        for bad in [
            "bogus=1",
            "transient=1.5",
            "transient=-0.1",
            "crash=1",
            "crash=x@2",
            "slow=1",
            "slow=1x0",
            "backoff=-1",
            "seed",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec `{bad}` must be refused");
        }
    }

    #[test]
    fn transient_decisions_are_deterministic_and_rate_bounded() {
        let p = FaultPlan { transient_rate: 0.2, seed: 42, ..FaultPlan::default() };
        let q = FaultPlan { transient_rate: 0.2, seed: 42, ..FaultPlan::default() };
        let mut hits = 0usize;
        let trials = 4000usize;
        for i in 0..trials {
            let (w, s, r) = (i % 4, (i / 4) as u64, (i * 31) as u64);
            assert_eq!(p.transient_at(w, s, r), q.transient_at(w, s, r), "same seed, same answer");
            if p.transient_at(w, s, r) {
                hits += 1;
            }
        }
        let observed = hits as f64 / trials as f64;
        assert!((0.1..0.3).contains(&observed), "rate 0.2 observed {observed}");
        // A different seed answers differently somewhere.
        let r = FaultPlan { seed: 43, ..p.clone() };
        assert!((0..trials).any(|i| {
            let (w, s, rid) = (i % 4, (i / 4) as u64, (i * 31) as u64);
            p.transient_at(w, s, rid) != r.transient_at(w, s, rid)
        }));
        // Rate extremes.
        let none = FaultPlan::default();
        let all = FaultPlan { transient_rate: 1.0, ..FaultPlan::default() };
        assert!(!none.transient_at(0, 0, 0));
        assert!(all.transient_at(0, 0, 0));
    }

    #[test]
    fn crash_point_is_sticky_past_its_step() {
        let p = FaultPlan::parse("crash=2@10").unwrap();
        assert!(!p.crashes_at(2, 9));
        assert!(p.crashes_at(2, 10));
        assert!(p.crashes_at(2, 11), "an idle worker still dies at its next step");
        assert!(!p.crashes_at(1, 10), "only the named worker crashes");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = FaultPlan { backoff_base_s: 0.001, ..FaultPlan::default() };
        assert_eq!(p.backoff_s(1), 0.001);
        assert_eq!(p.backoff_s(2), 0.002);
        assert_eq!(p.backoff_s(3), 0.004);
        assert!(p.backoff_s(10_000) <= 0.001 * 65_536.0 + 1e-12, "exponent capped");
    }

    #[test]
    fn taxonomy_classifies_injected_vs_organic_errors() {
        let p = FaultPlan::default();
        let injected = format!("{}", p.transient_error(1, 7));
        assert_eq!(FaultKind::classify(&injected), FaultKind::Transient);
        assert_eq!(FaultKind::classify("injected fault at position 3"), FaultKind::Fatal);
        assert_eq!(FaultKind::classify("foreign session type"), FaultKind::Fatal);
    }
}
