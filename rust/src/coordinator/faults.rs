//! Deterministic fault injection for the serving stack: the seeded
//! [`FaultPlan`] and the error taxonomy ([`FaultKind`]) the recovery
//! machinery classifies against.
//!
//! A fleet's defining property is that workers fail mid-stream, and a
//! recovery path that can only be exercised by real hardware falling
//! over can never be tested. This module makes failure a *pure function
//! of the plan*: every injection decision is keyed on deterministic
//! progress indices — a worker's fused-step count and a lane's request
//! id — never on wall time, so the threaded worker loop and the
//! virtual-time harness consult the same plan and reach the same
//! decisions, and the same seed replays the same crash, the same
//! transient faults, and the same recovery placements run after run.
//!
//! The plan injects three failure shapes:
//!
//! * **Transient step errors** (`transient=RATE`): a planned lane's
//!   share of a fused step errors before it is fed (the feed never
//!   happens, so the backend session does not advance and an in-place
//!   retry re-feeds the identical span). Recovery: bounded per-request
//!   retries with exponential backoff; exhaustion is a visible failure,
//!   never a hang.
//! * **Whole-worker crashes** (`crash=WORKER@STEP`): the worker dies
//!   when its fused-step count reaches `STEP`. Recovery: its in-flight
//!   lanes release all KV through the usual choke point and fail over
//!   to healthy siblings as resumable jobs; its queue is marked dead
//!   (stealable immediately) and the [`super::router::Router`] health
//!   mask excludes it from steering.
//! * **Slow-worker degradation** (`slow=WORKERxFACTOR`): the worker's
//!   fused steps take `FACTOR`× their modeled/measured time — the
//!   degraded-but-alive node whose traffic the load-aware policies
//!   route around.
//!
//! Because token streams are a pure function of (model, prompt, sampler
//! seed) — scheduling only moves *when* tokens happen, never *which* —
//! every request that survives recovery emits a stream bit-identical to
//! the fault-free run. The fault-streams proptests and the
//! `fault_recovery` bench cell pin exactly that.

use crate::err;
use crate::util::error::Result;

/// Default per-request in-place retry budget for transient step faults.
pub const DEFAULT_RETRY_BUDGET: u32 = 3;

/// Default base of the exponential retry backoff, seconds (doubles per
/// attempt: 1 ms, 2 ms, 4 ms, ...). Virtual seconds in the harness,
/// wall seconds on the threaded path.
pub const DEFAULT_BACKOFF_BASE_S: f64 = 0.001;

/// The two-point error taxonomy recovery classifies every lane error
/// into — injected or organic (a real [`super::backend::Backend`]
/// refusing a step).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Worth retrying in place: the step itself failed, not the lane's
    /// state. Retried under the bounded budget with backoff.
    Transient,
    /// The lane cannot make progress (poisoned session, refused
    /// restore): released through the KV choke point and failed
    /// visibly.
    Fatal,
}

impl FaultKind {
    /// Classify a backend error message. Errors carrying the
    /// "transient" marker — the plan's injected step faults — retry;
    /// everything else (e.g. the sim's position faults, a foreign
    /// session, a refused restore) is state corruption and fatal.
    pub fn classify(message: &str) -> FaultKind {
        if message.contains("transient") {
            FaultKind::Transient
        } else {
            FaultKind::Fatal
        }
    }
}

/// A whole-worker crash point: the worker dies when its fused-step
/// count reaches `at_step`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrashSpec {
    /// Worker index that crashes.
    pub worker: usize,
    /// Fused-step count (per that worker) at which it dies.
    pub at_step: u64,
}

/// A slow-worker degradation: every fused step on `worker` takes
/// `factor`× its normal time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlowSpec {
    /// Worker index that degrades.
    pub worker: usize,
    /// Latency multiplier (>= 1 is a slowdown; values below 1 are
    /// clamped to 1 at query time).
    pub factor: f64,
}

/// A seeded, deterministic fault-injection plan, shared verbatim by the
/// threaded worker loop and the virtual harness. Parsed from the
/// `--fault-plan` CLI spec; see [`FaultPlan::parse`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the transient-fault hash (and nothing else: crash and
    /// slow points are explicit, not sampled).
    pub seed: u64,
    /// Per (worker, step, lane) probability that the lane's share of
    /// that fused step errors transiently. 0 disables.
    pub transient_rate: f64,
    /// Max in-place retries per request before the failure is surfaced.
    pub retry_budget: u32,
    /// Base of the exponential backoff, seconds (doubles per attempt).
    pub backoff_base_s: f64,
    /// At most one whole-worker crash per plan.
    pub crash: Option<CrashSpec>,
    /// At most one degraded worker per plan.
    pub slow: Option<SlowSpec>,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            transient_rate: 0.0,
            retry_budget: DEFAULT_RETRY_BUDGET,
            backoff_base_s: DEFAULT_BACKOFF_BASE_S,
            crash: None,
            slow: None,
        }
    }
}

impl FaultPlan {
    /// Parse a `--fault-plan` spec: comma-separated `key=value` fields,
    /// any subset of
    ///
    /// ```text
    /// seed=U64            transient-fault hash seed        (default 0)
    /// transient=RATE      per-lane-step fault probability  (default 0)
    /// retries=N           per-request retry budget         (default 3)
    /// backoff=SECONDS     backoff base, doubles per try    (default 0.001)
    /// crash=WORKER@STEP   kill worker at its fused step count
    /// slow=WORKERxFACTOR  multiply a worker's step latency
    /// ```
    ///
    /// e.g. `seed=7,transient=0.01,crash=1@40,slow=2x3.0`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for field in spec.split(',').map(str::trim).filter(|f| !f.is_empty()) {
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| err!("fault-plan field `{field}` is not key=value"))?;
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| err!("fault-plan seed `{value}` is not a u64"))?;
                }
                "transient" => {
                    let rate: f64 = value
                        .parse()
                        .map_err(|_| err!("fault-plan transient rate `{value}`"))?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(err!("fault-plan transient rate {rate} not in [0, 1]"));
                    }
                    plan.transient_rate = rate;
                }
                "retries" => {
                    plan.retry_budget = value
                        .parse()
                        .map_err(|_| err!("fault-plan retries `{value}` is not a u32"))?;
                }
                "backoff" => {
                    let base: f64 = value
                        .parse()
                        .map_err(|_| err!("fault-plan backoff `{value}`"))?;
                    if !base.is_finite() || base < 0.0 {
                        return Err(err!("fault-plan backoff {base} must be finite and >= 0"));
                    }
                    plan.backoff_base_s = base;
                }
                "crash" => {
                    let (w, s) = value
                        .split_once('@')
                        .ok_or_else(|| err!("fault-plan crash `{value}` is not WORKER@STEP"))?;
                    plan.crash = Some(CrashSpec {
                        worker: w
                            .parse()
                            .map_err(|_| err!("fault-plan crash worker `{w}`"))?,
                        at_step: s
                            .parse()
                            .map_err(|_| err!("fault-plan crash step `{s}`"))?,
                    });
                }
                "slow" => {
                    let (w, f) = value
                        .split_once('x')
                        .ok_or_else(|| err!("fault-plan slow `{value}` is not WORKERxFACTOR"))?;
                    let factor: f64 =
                        f.parse().map_err(|_| err!("fault-plan slow factor `{f}`"))?;
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(err!("fault-plan slow factor {factor} must be positive"));
                    }
                    plan.slow = Some(SlowSpec {
                        worker: w.parse().map_err(|_| err!("fault-plan slow worker `{w}`"))?,
                        factor,
                    });
                }
                other => return Err(err!("unknown fault-plan field `{other}`")),
            }
        }
        Ok(plan)
    }

    /// Whether the plan can inject anything at all (a no-op plan lets
    /// callers skip the fault bookkeeping entirely).
    pub fn is_active(&self) -> bool {
        self.transient_rate > 0.0 || self.crash.is_some() || self.slow.is_some()
    }

    /// Whether the lane serving `request_id` errors transiently on
    /// `worker`'s fused step number `step`. Pure in its arguments: both
    /// drivers ask with their own progress counters and a rerun with
    /// the same seed asks the same questions and gets the same answers.
    pub fn transient_at(&self, worker: usize, step: u64, request_id: u64) -> bool {
        if self.transient_rate <= 0.0 {
            return false;
        }
        if self.transient_rate >= 1.0 {
            return true;
        }
        let h = mix(self.seed ^ mix((worker as u64) << 32 ^ step) ^ mix(request_id));
        ((h >> 11) as f64 / (1u64 << 53) as f64) < self.transient_rate
    }

    /// Whether `worker` is (past) its crash point at fused step `step`.
    /// `>=`, not `==`: a worker that idles across its exact crash step
    /// still dies the next time it would do work.
    pub fn crashes_at(&self, worker: usize, step: u64) -> bool {
        self.crash.map_or(false, |c| c.worker == worker && step >= c.at_step)
    }

    /// Latency multiplier for `worker`'s fused steps (1.0 = healthy).
    pub fn slow_factor(&self, worker: usize) -> f64 {
        match self.slow {
            Some(s) if s.worker == worker => s.factor.max(1.0),
            _ => 1.0,
        }
    }

    /// Backoff before retry number `attempt` (1-based), seconds:
    /// `base × 2^(attempt-1)`, exponent capped so a misconfigured
    /// budget cannot overflow into a multi-hour sleep.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        self.backoff_base_s * f64::from(1u32 << attempt.saturating_sub(1).min(16))
    }

    /// The injected transient error for `worker`'s step `step` — the
    /// message carries the marker [`FaultKind::classify`] keys on.
    pub fn transient_error(&self, worker: usize, step: u64) -> crate::util::error::Error {
        err!("transient fault injected on worker {worker} at step {step}")
    }
}

// ---------------------------------------------------------------------
// Fleet-tier fault domains
// ---------------------------------------------------------------------

/// Default health-probe interval, seconds (virtual seconds in the
/// harness, planned-arrival seconds on the threaded path). Detection
/// latency for a partition is one probe interval: the first missed
/// probe moves the replica to probation, the second ejects it.
pub const DEFAULT_PROBE_INTERVAL_S: f64 = 0.25;

/// Consecutive successful probes a healed replica must answer before
/// the front-end trusts it with admissions again.
pub const REINSTATE_PROBES: u32 = 2;

/// Front-end health verdict for one replica at one instant — a pure
/// function of the [`ClusterFaultPlan`] and the decision time, so both
/// drivers (virtual clock, planned arrival timestamps) agree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Answering probes; receives admissions.
    Healthy,
    /// Suspect (first missed probe) or freshly healed (reinstatement
    /// probes still running): receives no new admissions, but its
    /// in-flight work is left alone and it still counts as capacity.
    Probation,
    /// Declared down: in-flight streams are failed over, the autoscaler
    /// stops counting it, and only a full probe sequence readmits it.
    Ejected,
}

/// A whole-replica crash point: the replica dies at fleet time `at_s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaCrashSpec {
    /// Replica (front-end slot) index that crashes.
    pub replica: usize,
    /// Fleet time of death, seconds.
    pub at_s: f64,
}

/// A network partition: the replica stays alive but is unreachable on
/// `[from_s, until_s)` — accepted work stalls until the heal, and the
/// front-end ejects it one probe interval after onset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PartitionSpec {
    /// Replica (front-end slot) index that is cut off.
    pub replica: usize,
    /// Partition onset, fleet seconds.
    pub from_s: f64,
    /// Heal time, fleet seconds (exclusive; must be > `from_s`).
    pub until_s: f64,
}

/// A degraded replica: every request it serves costs `factor`× the
/// modeled time. The front-end reprices its advertised capacity once
/// the first probe measures the degradation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaSlowSpec {
    /// Replica (front-end slot) index that degrades.
    pub replica: usize,
    /// Latency multiplier (>= 1 is a slowdown; clamped at query time).
    pub factor: f64,
}

/// One fleet fault edge the dispatcher must act on (in-flight streams
/// re-homed, counters bumped). Produced sorted by time from
/// [`ClusterFaultPlan::fault_events`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetFault {
    /// The replica died: fail over everything it held, forever.
    Crash {
        /// Crashed replica index.
        replica: usize,
    },
    /// The replica was declared unreachable (partition detection edge):
    /// fail over everything it held; it may be reinstated after heal.
    Eject {
        /// Ejected replica index.
        replica: usize,
    },
}

/// Deterministic replica-level fault plan — the fleet analog of
/// [`FaultPlan`], parsed from the `--cluster-fault-plan` CLI spec.
///
/// The same contract holds one tier up: every injection is a pure
/// function of (plan, replica index, fleet time), where fleet time is
/// the virtual clock in the harness and the *planned* arrival
/// timestamps on the threaded dispatcher — never wall time. Both
/// drivers consult the same plan and reach the same routing, ejection,
/// and failover decisions, so a rerun replays the same recovery.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterFaultPlan {
    /// Reserved for sampled fleet faults; accepted by the parser for
    /// forward compatibility (crash, partition, and slow points are
    /// explicit schedules, so nothing consumes it today).
    pub seed: u64,
    /// Health-probe interval, seconds (detection + reinstatement
    /// granularity).
    pub probe_interval_s: f64,
    /// Replica crash points (at most one effective per replica: the
    /// earliest wins).
    pub crashes: Vec<ReplicaCrashSpec>,
    /// Network partitions (repeatable, may name several replicas).
    pub partitions: Vec<PartitionSpec>,
    /// Degraded replicas (at most one factor per replica: the largest
    /// wins).
    pub slow: Vec<ReplicaSlowSpec>,
}

impl Default for ClusterFaultPlan {
    fn default() -> ClusterFaultPlan {
        ClusterFaultPlan {
            seed: 0,
            probe_interval_s: DEFAULT_PROBE_INTERVAL_S,
            crashes: Vec::new(),
            partitions: Vec::new(),
            slow: Vec::new(),
        }
    }
}

impl ClusterFaultPlan {
    /// Parse a `--cluster-fault-plan` spec: comma-separated `key=value`
    /// fields, any subset of
    ///
    /// ```text
    /// seed=U64              reserved (accepted, unused)       (default 0)
    /// probe=SECONDS         health-probe interval             (default 0.25)
    /// crash=R@T             kill replica R at fleet time T    (repeatable)
    /// partition=R@T1..T2    cut replica R off on [T1, T2)     (repeatable)
    /// slow=RxF              multiply replica R's service time (repeatable)
    /// ```
    ///
    /// e.g. `crash=1@4.0,partition=2@2.0..6.0,slow=0x3`.
    pub fn parse(spec: &str) -> Result<ClusterFaultPlan> {
        let mut plan = ClusterFaultPlan::default();
        for field in spec.split(',').map(str::trim).filter(|f| !f.is_empty()) {
            let (key, value) = field
                .split_once('=')
                .ok_or_else(|| err!("cluster-fault-plan field `{field}` is not key=value"))?;
            match key {
                "seed" => {
                    plan.seed = value.parse().map_err(|_| {
                        err!("cluster-fault-plan seed `{value}` is not a u64")
                    })?;
                }
                "probe" => {
                    let p: f64 = value.parse().map_err(|_| {
                        err!("cluster-fault-plan probe interval `{value}`")
                    })?;
                    if !p.is_finite() || p <= 0.0 {
                        return Err(err!(
                            "cluster-fault-plan probe interval {p} must be finite and > 0"
                        ));
                    }
                    plan.probe_interval_s = p;
                }
                "crash" => {
                    let (r, t) = value.split_once('@').ok_or_else(|| {
                        err!("cluster-fault-plan crash `{value}` is not REPLICA@TIME")
                    })?;
                    let at_s: f64 = t
                        .parse()
                        .map_err(|_| err!("cluster-fault-plan crash time `{t}`"))?;
                    if !at_s.is_finite() || at_s < 0.0 {
                        return Err(err!(
                            "cluster-fault-plan crash time {at_s} must be finite and >= 0"
                        ));
                    }
                    plan.crashes.push(ReplicaCrashSpec {
                        replica: r.parse().map_err(|_| {
                            err!("cluster-fault-plan crash replica `{r}`")
                        })?,
                        at_s,
                    });
                }
                "partition" => {
                    let (r, window) = value.split_once('@').ok_or_else(|| {
                        err!("cluster-fault-plan partition `{value}` is not REPLICA@FROM..UNTIL")
                    })?;
                    let (from, until) = window.split_once("..").ok_or_else(|| {
                        err!("cluster-fault-plan partition window `{window}` is not FROM..UNTIL")
                    })?;
                    let from_s: f64 = from.parse().map_err(|_| {
                        err!("cluster-fault-plan partition start `{from}`")
                    })?;
                    let until_s: f64 = until.parse().map_err(|_| {
                        err!("cluster-fault-plan partition end `{until}`")
                    })?;
                    if !from_s.is_finite() || from_s < 0.0 || !until_s.is_finite() {
                        return Err(err!(
                            "cluster-fault-plan partition window {from_s}..{until_s} must be finite and >= 0"
                        ));
                    }
                    if until_s <= from_s {
                        return Err(err!(
                            "cluster-fault-plan partition end {until_s} must be > start {from_s}"
                        ));
                    }
                    plan.partitions.push(PartitionSpec {
                        replica: r.parse().map_err(|_| {
                            err!("cluster-fault-plan partition replica `{r}`")
                        })?,
                        from_s,
                        until_s,
                    });
                }
                "slow" => {
                    let (r, f) = value.split_once('x').ok_or_else(|| {
                        err!("cluster-fault-plan slow `{value}` is not REPLICAxFACTOR")
                    })?;
                    let factor: f64 = f
                        .parse()
                        .map_err(|_| err!("cluster-fault-plan slow factor `{f}`"))?;
                    if !factor.is_finite() || factor <= 0.0 {
                        return Err(err!(
                            "cluster-fault-plan slow factor {factor} must be positive"
                        ));
                    }
                    plan.slow.push(ReplicaSlowSpec {
                        replica: r.parse().map_err(|_| {
                            err!("cluster-fault-plan slow replica `{r}`")
                        })?,
                        factor,
                    });
                }
                other => return Err(err!("unknown cluster-fault-plan field `{other}`")),
            }
        }
        Ok(plan)
    }

    /// Whether the plan injects anything (an inert plan lets the
    /// dispatcher skip all fleet fault bookkeeping, including stream
    /// wrapping on the threaded path).
    pub fn is_active(&self) -> bool {
        !self.crashes.is_empty() || !self.partitions.is_empty() || !self.slow.is_empty()
    }

    /// Refuse replica indices outside the fleet. `slots` is the
    /// front-end slot count (max_replicas under autoscale).
    pub fn validate(&self, slots: usize) -> Result<()> {
        let over = self
            .crashes
            .iter()
            .map(|c| c.replica)
            .chain(self.partitions.iter().map(|p| p.replica))
            .chain(self.slow.iter().map(|s| s.replica))
            .find(|&r| r >= slots);
        if let Some(r) = over {
            return Err(err!(
                "cluster-fault-plan names replica {r} but the fleet has {slots} slots"
            ));
        }
        Ok(())
    }

    /// The replica's (earliest) crash time, if any.
    pub fn crash_at(&self, replica: usize) -> Option<f64> {
        self.crashes
            .iter()
            .filter(|c| c.replica == replica)
            .map(|c| c.at_s)
            .min_by(f64::total_cmp)
    }

    /// Partition windows cutting `replica` off, `(from_s, until_s)`.
    pub fn partitions_of(&self, replica: usize) -> Vec<(f64, f64)> {
        let mut w: Vec<(f64, f64)> = self
            .partitions
            .iter()
            .filter(|p| p.replica == replica)
            .map(|p| (p.from_s, p.until_s))
            .collect();
        w.sort_by(|a, b| a.0.total_cmp(&b.0));
        w
    }

    /// Latency multiplier for `replica` (1.0 = healthy). Like the
    /// pool-tier [`FaultPlan::slow_factor`], the degradation covers the
    /// whole run.
    pub fn slow_factor(&self, replica: usize) -> f64 {
        self.slow
            .iter()
            .filter(|s| s.replica == replica)
            .map(|s| s.factor.max(1.0))
            .fold(1.0, f64::max)
    }

    /// The slow factor the front-end *knows about* at time `t`: probes
    /// need one interval to measure the degradation, so repricing
    /// starts at `probe_interval_s` and admissions before that still
    /// see the healthy price (the window deadline-fraction hedging
    /// exists to cover).
    pub fn advertised_slow_factor(&self, replica: usize, t: f64) -> f64 {
        if t >= self.probe_interval_s {
            self.slow_factor(replica)
        } else {
            1.0
        }
    }

    /// Health verdict for `replica` at fleet time `t` — the front-end
    /// state machine (healthy → probation → ejected → probation →
    /// healthy) evaluated as a pure timeline function:
    ///
    /// * a crash ejects at its instant and forever (a reset connection
    ///   is a hard signal; no probe latency);
    /// * a partition puts the replica on probation at onset (first
    ///   missed probe), ejects one probe interval later, and after the
    ///   heal holds it on probation for [`REINSTATE_PROBES`] successful
    ///   probes before readmitting it.
    pub fn health_at(&self, replica: usize, t: f64) -> ReplicaHealth {
        if let Some(tc) = self.crash_at(replica) {
            if t >= tc {
                return ReplicaHealth::Ejected;
            }
        }
        let reinstate_s = self.probe_interval_s * f64::from(REINSTATE_PROBES);
        let mut verdict = ReplicaHealth::Healthy;
        for (from_s, until_s) in self.partitions_of(replica) {
            let eject_s = from_s + self.probe_interval_s;
            let v = if t < from_s {
                ReplicaHealth::Healthy
            } else if t < eject_s.min(until_s) {
                ReplicaHealth::Probation
            } else if t < until_s {
                ReplicaHealth::Ejected
            } else if t < until_s + reinstate_s {
                ReplicaHealth::Probation
            } else {
                ReplicaHealth::Healthy
            };
            verdict = match (verdict, v) {
                (ReplicaHealth::Ejected, _) | (_, ReplicaHealth::Ejected) => {
                    ReplicaHealth::Ejected
                }
                (ReplicaHealth::Probation, _) | (_, ReplicaHealth::Probation) => {
                    ReplicaHealth::Probation
                }
                _ => ReplicaHealth::Healthy,
            };
        }
        verdict
    }

    /// Whether the front-end may route new work to `replica` at `t`.
    pub fn routable(&self, replica: usize, t: f64) -> bool {
        self.health_at(replica, t) == ReplicaHealth::Healthy
    }

    /// Every fault edge the dispatcher must act on, sorted by time
    /// (ties broken by replica index): replica crashes at their
    /// instant, partition ejections one probe interval past onset. A
    /// partition shorter than the probe interval heals before
    /// detection and produces no edge — its accepted work just stalls.
    pub fn fault_events(&self) -> Vec<(f64, FleetFault)> {
        let mut ev: Vec<(f64, FleetFault)> = Vec::new();
        for c in &self.crashes {
            ev.push((c.at_s, FleetFault::Crash { replica: c.replica }));
        }
        for p in &self.partitions {
            let eject_s = p.from_s + self.probe_interval_s;
            if eject_s < p.until_s {
                ev.push((eject_s, FleetFault::Eject { replica: p.replica }));
            }
        }
        ev.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then_with(|| fault_replica(&a.1).cmp(&fault_replica(&b.1)))
        });
        ev
    }
}

/// The replica a fleet fault edge names (sort key).
fn fault_replica(f: &FleetFault) -> usize {
    match f {
        FleetFault::Crash { replica } | FleetFault::Eject { replica } => *replica,
    }
}

/// splitmix64 finalizer: the stateless hash behind
/// [`FaultPlan::transient_at`]. Self-contained so the decision function
/// can never drift with an RNG implementation.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_roundtrips_fields() {
        let p = FaultPlan::parse("seed=7,transient=0.25,retries=5,backoff=0.002,crash=1@40,slow=2x3.0")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.transient_rate, 0.25);
        assert_eq!(p.retry_budget, 5);
        assert_eq!(p.backoff_base_s, 0.002);
        assert_eq!(p.crash, Some(CrashSpec { worker: 1, at_step: 40 }));
        assert_eq!(p.slow, Some(SlowSpec { worker: 2, factor: 3.0 }));
        assert!(p.is_active());
    }

    #[test]
    fn parse_empty_spec_is_the_inactive_default() {
        let p = FaultPlan::parse("").unwrap();
        assert_eq!(p, FaultPlan::default());
        assert!(!p.is_active());
        assert!(!p.transient_at(0, 0, 0));
        assert!(!p.crashes_at(0, 1_000_000));
        assert_eq!(p.slow_factor(3), 1.0);
    }

    #[test]
    fn parse_rejects_malformed_fields() {
        for bad in [
            "bogus=1",
            "transient=1.5",
            "transient=-0.1",
            "crash=1",
            "crash=x@2",
            "slow=1",
            "slow=1x0",
            "backoff=-1",
            "seed",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec `{bad}` must be refused");
        }
    }

    #[test]
    fn transient_decisions_are_deterministic_and_rate_bounded() {
        let p = FaultPlan { transient_rate: 0.2, seed: 42, ..FaultPlan::default() };
        let q = FaultPlan { transient_rate: 0.2, seed: 42, ..FaultPlan::default() };
        let mut hits = 0usize;
        let trials = 4000usize;
        for i in 0..trials {
            let (w, s, r) = (i % 4, (i / 4) as u64, (i * 31) as u64);
            assert_eq!(p.transient_at(w, s, r), q.transient_at(w, s, r), "same seed, same answer");
            if p.transient_at(w, s, r) {
                hits += 1;
            }
        }
        let observed = hits as f64 / trials as f64;
        assert!((0.1..0.3).contains(&observed), "rate 0.2 observed {observed}");
        // A different seed answers differently somewhere.
        let r = FaultPlan { seed: 43, ..p.clone() };
        assert!((0..trials).any(|i| {
            let (w, s, rid) = (i % 4, (i / 4) as u64, (i * 31) as u64);
            p.transient_at(w, s, rid) != r.transient_at(w, s, rid)
        }));
        // Rate extremes.
        let none = FaultPlan::default();
        let all = FaultPlan { transient_rate: 1.0, ..FaultPlan::default() };
        assert!(!none.transient_at(0, 0, 0));
        assert!(all.transient_at(0, 0, 0));
    }

    #[test]
    fn crash_point_is_sticky_past_its_step() {
        let p = FaultPlan::parse("crash=2@10").unwrap();
        assert!(!p.crashes_at(2, 9));
        assert!(p.crashes_at(2, 10));
        assert!(p.crashes_at(2, 11), "an idle worker still dies at its next step");
        assert!(!p.crashes_at(1, 10), "only the named worker crashes");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = FaultPlan { backoff_base_s: 0.001, ..FaultPlan::default() };
        assert_eq!(p.backoff_s(1), 0.001);
        assert_eq!(p.backoff_s(2), 0.002);
        assert_eq!(p.backoff_s(3), 0.004);
        assert!(p.backoff_s(10_000) <= 0.001 * 65_536.0 + 1e-12, "exponent capped");
    }

    #[test]
    fn cluster_parse_full_spec_roundtrips_fields() {
        let p = ClusterFaultPlan::parse(
            "seed=9,probe=0.5,crash=1@4.0,partition=2@2.0..6.0,slow=0x3,crash=3@8",
        )
        .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.probe_interval_s, 0.5);
        assert_eq!(p.crashes.len(), 2);
        assert_eq!(p.crash_at(1), Some(4.0));
        assert_eq!(p.crash_at(3), Some(8.0));
        assert_eq!(p.partitions_of(2), vec![(2.0, 6.0)]);
        assert_eq!(p.slow_factor(0), 3.0);
        assert_eq!(p.slow_factor(1), 1.0);
        assert!(p.is_active());
        assert!(p.validate(4).is_ok());
        assert!(p.validate(3).is_err(), "replica 3 outside a 3-slot fleet");
    }

    #[test]
    fn cluster_parse_empty_spec_is_the_inactive_default() {
        let p = ClusterFaultPlan::parse("").unwrap();
        assert_eq!(p, ClusterFaultPlan::default());
        assert!(!p.is_active());
        assert!(p.fault_events().is_empty());
        assert_eq!(p.health_at(0, 1e9), ReplicaHealth::Healthy);
        assert_eq!(p.slow_factor(5), 1.0);
    }

    #[test]
    fn cluster_parse_rejects_malformed_fields_by_name() {
        for (bad, field) in [
            ("bogus=1", "bogus"),
            ("crash=1", "crash"),
            ("crash=x@2", "crash"),
            ("crash=1@-3", "crash"),
            ("partition=1@5", "partition"),
            ("partition=1@6..5", "partition"),
            ("partition=z@1..2", "partition"),
            ("slow=1", "slow"),
            ("slow=1x0", "slow"),
            ("probe=0", "probe"),
            ("probe=nan", "probe"),
            ("seed", "key=value"),
        ] {
            let e = ClusterFaultPlan::parse(bad).unwrap_err().to_string();
            assert!(
                e.contains(field),
                "spec `{bad}` must be refused with an error naming `{field}`, got: {e}"
            );
        }
    }

    #[test]
    fn cluster_health_walks_the_state_machine_deterministically() {
        let p = ClusterFaultPlan::parse("probe=0.25,partition=1@2.0..4.0,crash=2@3.0").unwrap();
        // Partitioned replica: healthy -> probation (first missed
        // probe) -> ejected -> probation (reinstatement probes) ->
        // healthy. Pure timeline: two evaluations at the same t agree.
        use ReplicaHealth::*;
        for (t, want) in [
            (0.0, Healthy),
            (1.99, Healthy),
            (2.0, Probation),
            (2.24, Probation),
            (2.25, Ejected),
            (3.99, Ejected),
            (4.0, Probation),
            (4.49, Probation),
            (4.5, Healthy),
            (100.0, Healthy),
        ] {
            assert_eq!(p.health_at(1, t), want, "replica 1 at t={t}");
            assert_eq!(p.health_at(1, t), p.health_at(1, t), "pure function");
        }
        // Crashed replica: ejected at its instant, forever.
        assert_eq!(p.health_at(2, 2.99), Healthy);
        assert_eq!(p.health_at(2, 3.0), Ejected);
        assert_eq!(p.health_at(2, 1e6), Ejected);
        // Untouched replica: always healthy and routable.
        assert!(p.routable(0, 3.0));
        // Fault edges in time order: crash at 3.0 after the partition
        // ejection at 2.25.
        let ev = p.fault_events();
        assert_eq!(
            ev,
            vec![
                (2.25, FleetFault::Eject { replica: 1 }),
                (3.0, FleetFault::Crash { replica: 2 }),
            ]
        );
    }

    #[test]
    fn cluster_short_partition_heals_before_detection() {
        let p = ClusterFaultPlan::parse("probe=0.5,partition=0@1.0..1.2").unwrap();
        assert!(p.fault_events().is_empty(), "no ejection edge for a sub-probe partition");
        assert_eq!(p.health_at(0, 1.1), ReplicaHealth::Probation);
        assert_eq!(p.health_at(0, 2.3), ReplicaHealth::Healthy);
    }

    #[test]
    fn cluster_slow_repricing_waits_for_the_first_probe() {
        let p = ClusterFaultPlan::parse("slow=1x4").unwrap();
        assert_eq!(p.advertised_slow_factor(1, 0.0), 1.0, "undetected before the first probe");
        assert_eq!(p.advertised_slow_factor(1, DEFAULT_PROBE_INTERVAL_S), 4.0);
        assert_eq!(p.advertised_slow_factor(0, 10.0), 1.0);
    }

    #[test]
    fn taxonomy_classifies_injected_vs_organic_errors() {
        let p = FaultPlan::default();
        let injected = format!("{}", p.transient_error(1, 7));
        assert_eq!(FaultKind::classify(&injected), FaultKind::Transient);
        assert_eq!(FaultKind::classify("injected fault at position 3"), FaultKind::Fatal);
        assert_eq!(FaultKind::classify("foreign session type"), FaultKind::Fatal);
    }
}
