//! Serving workload generation and load studies.
//!
//! The paper evaluates fixed-shape generation (in=32, out=2016); a
//! datacenter deployment also needs the latency-vs-load curve. This
//! module provides an open-loop Poisson request generator with
//! configurable prompt/output length distributions and a load-sweep
//! runner that reports throughput and latency percentiles per offered
//! rate — the serving study behind the `perf_hotpath` load table.

use std::time::{Duration, Instant};

use crate::numerics::SampleParams;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::{Coordinator, Request, RequestHandle, TokenEvent};

/// Length distribution for prompts/outputs.
#[derive(Clone, Copy, Debug)]
pub enum LenDist {
    Fixed(usize),
    /// Uniform in [lo, hi].
    Uniform(usize, usize),
    /// Geometric-ish: min + exponential tail with the given mean extra.
    LongTail { min: usize, mean_extra: f64, cap: usize },
}

impl LenDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, hi) => rng.range(lo, hi + 1),
            LenDist::LongTail { min, mean_extra, cap } => {
                (min + rng.exp(1.0 / mean_extra.max(1e-9)) as usize).min(cap)
            }
        }
    }
}

/// Workload specification.
#[derive(Clone, Debug)]
pub struct Workload {
    pub model: String,
    /// Offered request rate, requests/second (open loop).
    pub rate: f64,
    pub n_requests: usize,
    pub prompt_len: LenDist,
    pub output_len: LenDist,
    pub vocab: usize,
    pub seed: u64,
}

impl Workload {
    /// Generate the request list with Poisson inter-arrival offsets.
    pub fn generate(&self) -> Vec<(Duration, Request)> {
        let mut rng = Rng::new(self.seed);
        let mut at = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                at += rng.exp(self.rate);
                let p_len = self.prompt_len.sample(&mut rng);
                let o_len = self.output_len.sample(&mut rng).max(1);
                let prompt =
                    (0..p_len.max(1)).map(|_| rng.range(0, self.vocab) as i64).collect();
                let req = Request {
                    model: self.model.clone(),
                    prompt,
                    max_new_tokens: o_len,
                    params: SampleParams::greedy(),
                    eos_token: None,
                    seed: self.seed ^ i as u64,
                };
                (Duration::from_secs_f64(at), req)
            })
            .collect()
    }
}

/// Results of one load point.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub offered_rate: f64,
    pub completed: usize,
    pub wall_s: f64,
    /// Achieved output tokens/second.
    pub tokens_per_s: f64,
    /// Time to first token, seconds.
    pub ttft: Summary,
    /// End-to-end request latency, seconds.
    pub request_latency: Summary,
}

/// Run an open-loop load test against a coordinator. The submitting
/// thread honors arrival times; each request's event stream is drained
/// by its own collector thread so TTFT/latency are timestamped at
/// *emission*, not at batched readback.
pub fn run_open_loop(coord: &Coordinator, wl: &Workload) -> Result<LoadReport, String> {
    type PerReq = Result<(f64, f64, usize), String>; // (ttft, latency, tokens)
    fn collect(submitted: Instant, handle: RequestHandle) -> PerReq {
        let mut first: Option<Duration> = None;
        for ev in handle.events.iter() {
            match ev {
                TokenEvent::Token { index: 0, .. } => first = Some(submitted.elapsed()),
                TokenEvent::Token { .. } => {}
                TokenEvent::Done { tokens, .. } => {
                    let lat = submitted.elapsed().as_secs_f64();
                    let ttft = first.unwrap_or_else(|| submitted.elapsed()).as_secs_f64();
                    return Ok((ttft, lat, tokens.len()));
                }
                TokenEvent::Error { message, .. } => return Err(message),
            }
        }
        Err("stream closed without completion".into())
    }

    let plan = wl.generate();
    let t0 = Instant::now();
    let mut collectors = Vec::with_capacity(plan.len());
    for (at, req) in plan {
        if let Some(sleep) = at.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let submitted = Instant::now();
        let handle = coord.submit(req)?;
        collectors.push(
            std::thread::Builder::new()
                .name("lpu-load-collect".into())
                .spawn(move || collect(submitted, handle))
                .map_err(|e| e.to_string())?,
        );
    }
    let mut ttfts = Vec::with_capacity(collectors.len());
    let mut lats = Vec::with_capacity(collectors.len());
    let mut tokens = 0usize;
    for c in collectors {
        let (ttft, lat, n) = c.join().map_err(|_| "collector panicked")??;
        ttfts.push(ttft);
        lats.push(lat);
        tokens += n;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(LoadReport {
        offered_rate: wl.rate,
        completed: lats.len(),
        wall_s,
        tokens_per_s: tokens as f64 / wall_s,
        ttft: Summary::of(&ttfts),
        request_latency: Summary::of(&lats),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendFactory, CoordinatorConfig, SchedulerPolicy};

    fn wl(rate: f64, n: usize) -> Workload {
        Workload {
            model: "opt-tiny".into(),
            rate,
            n_requests: n,
            prompt_len: LenDist::Uniform(1, 6),
            output_len: LenDist::Fixed(5),
            vocab: 512,
            seed: 99,
        }
    }

    fn coord() -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            max_active_per_worker: 4,
            policy: SchedulerPolicy::RoundRobin,
        });
        c.add_pool("opt-tiny", 2, BackendFactory::sim("opt-tiny", 512));
        c
    }

    #[test]
    fn generator_is_deterministic_and_ordered() {
        let a = wl(100.0, 20).generate();
        let b = wl(100.0, 20).generate();
        assert_eq!(a.len(), 20);
        for ((ta, ra), (tb, rb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(ra.prompt, rb.prompt);
        }
        // Arrival times strictly increase.
        assert!(a.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn poisson_mean_interarrival_matches_rate() {
        let plan = Workload { n_requests: 4000, ..wl(200.0, 4000) }.generate();
        let total = plan.last().unwrap().0.as_secs_f64();
        let mean = total / plan.len() as f64;
        assert!((mean - 1.0 / 200.0).abs() < 0.0008, "mean inter-arrival {mean}");
    }

    #[test]
    fn len_dists_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let u = LenDist::Uniform(3, 9).sample(&mut rng);
            assert!((3..=9).contains(&u));
            let t = LenDist::LongTail { min: 4, mean_extra: 10.0, cap: 64 }.sample(&mut rng);
            assert!((4..=64).contains(&t));
        }
        assert_eq!(LenDist::Fixed(7).sample(&mut rng), 7);
    }

    #[test]
    fn open_loop_run_conserves_and_reports() {
        let c = coord();
        let r = run_open_loop(&c, &wl(500.0, 30)).unwrap();
        assert_eq!(r.completed, 30);
        assert_eq!((r.tokens_per_s * r.wall_s).round() as usize, 30 * 5);
        assert!(r.ttft.mean > 0.0);
        assert!(r.request_latency.p99 >= r.request_latency.p50);
        c.shutdown();
    }

    #[test]
    fn higher_load_does_not_lose_requests() {
        let c = coord();
        for rate in [100.0, 2000.0] {
            let r = run_open_loop(&c, &wl(rate, 25)).unwrap();
            assert_eq!(r.completed, 25, "rate {rate}");
        }
        c.shutdown();
    }
}
